"""Table 7: EM iteration count — error keeps improving slightly up to 100."""
from __future__ import annotations

from benchmarks.common import bench_problem, row, timed
from repro.core import hessian as hes
from repro.core.bpv import VQConfig
from repro.core.gptvq import gptvq_quantize_matrix, layer_error


def run():
    W, H = bench_problem(r=128, c=512)
    U = hes.inv_hessian_cholesky(H)
    out = []
    for iters in (10, 30, 50, 75, 100):
        cfg = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=iters,
                       codebook_update_iters=0)
        res, us = timed(gptvq_quantize_matrix, W, U, cfg)
        e = float(layer_error(W, res.arrays.Q, H))
        out.append(row(f"tab7/em_iters_{iters}", us, f"layer_err={e:.5f}"))
    return out


if __name__ == "__main__":
    run()
