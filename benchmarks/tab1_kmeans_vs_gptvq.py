"""Table 1: k-Means VQ (data-free), k-Means + input data (EM w/ Hessian),
and the full GPTVQ sweep, 2D VQ on the bench LM, perplexity.

Paper claim ordering: kmeans > kmeans+data > GPTVQ (lower ppl better),
with the gap exploding at 2 bits per dim.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import (calib_tokens, eval_ppl, get_model_and_params,
                               row, timed)
from repro.core.bpv import VQConfig
from repro.core.pipeline import quantize_model


def run():
    model, params = get_model_and_params()
    calib = calib_tokens()
    out = [row("tab1/fp_baseline", 0.0, f"ppl={eval_ppl(model, params):.3f}")]
    for b in (2, 3, 4):
        cfg = VQConfig(d=2, bits_per_dim=b, group_size=2048, em_iters=25,
                       codebook_update_iters=0)
        for method, tag in (("kmeans", "kmeans"),
                            ("kmeans_data", "kmeans+data"),
                            ("gptvq", "gptvq")):
            (qp, _), us = timed(
                quantize_model, model, params, calib, method, cfg, chunk=16)
            out.append(row(f"tab1/{tag}_2d_{b}b", us,
                           f"ppl={eval_ppl(model, qp):.3f}"))
    return out


if __name__ == "__main__":
    run()
