"""Shared benchmark infrastructure.

A small llama-style LM is trained once on the synthetic corpus (weights
cached under artifacts/bench_model) and reused by every table benchmark;
paper tables are then reproduced *qualitatively* on it (DESIGN.md §6.3 —
WikiText-2/Llama weights are unavailable offline, so we validate orderings
and trends rather than absolute perplexities).
"""
from __future__ import annotations

import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core import hessian as hes
from repro.data.synthetic import SyntheticStream, sample_batch
from repro.models import model_zoo
from repro.train import optimizer as opt
from repro.train.loss import perplexity
from repro.train.train_step import init_state, make_train_step

ART = os.path.join(os.path.dirname(__file__), "../artifacts/bench_model")

BENCH_CFG = ModelConfig(
    name="bench-lm", family="dense",
    n_layers=4, d_model=128, n_heads=4, n_kv_heads=2, head_dim=32,
    d_ff=384, vocab_size=512, max_seq_len=256, activation="swiglu",
    dtype="float32", vocab_pad_multiple=64,
)
SEQ = 64
TRAIN_STEPS = 500


def get_model_and_params(retrain: bool = False):
    model = model_zoo.build(BENCH_CFG)
    path = os.path.join(ART, "params.npz")
    if os.path.exists(path) and not retrain:
        data = np.load(path)
        shapes = model_zoo.abstract_params(model)
        flat, treedef = jax.tree_util.tree_flatten(shapes)
        leaves = [jnp.asarray(data[f"p{i}"]) for i in range(len(flat))]
        return model, jax.tree.unflatten(treedef, leaves)
    ocfg = opt.OptConfig(lr=3e-3, warmup_steps=20, total_steps=TRAIN_STEPS)
    state = init_state(model, jax.random.PRNGKey(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    stream = SyntheticStream(BENCH_CFG.vocab_size, seq_len=SEQ,
                             global_batch=32)
    for i in range(TRAIN_STEPS):
        state, m = step(state, {"tokens": stream.next()})
    os.makedirs(ART, exist_ok=True)
    flat = jax.tree.leaves(state.params)
    np.savez(path, **{f"p{i}": np.asarray(x) for i, x in enumerate(flat)})
    return model, state.params


def calib_tokens(n=16, seq=SEQ, seed=9):
    return sample_batch(jax.random.PRNGKey(seed), BENCH_CFG.vocab_size, seq, n)


def heldout_tokens(n=32, seq=128):
    return sample_batch(jax.random.PRNGKey(1234), BENCH_CFG.vocab_size, seq, n)


def eval_ppl(model, params) -> float:
    return perplexity(model, params, heldout_tokens())


def bench_problem(r=128, c=512, seed=0):
    """A weight matrix + Hessian from the trained model's first MLP layer,
    padded/sliced to (r, c); falls back to synthetic when shapes differ."""
    model, params = get_model_and_params()
    W = np.asarray(params["layers"]["ffn"]["w_in"][0]).T  # (out,in)=(384,128)
    key = jax.random.PRNGKey(seed)
    if W.shape[0] < r or W.shape[1] < c:
        reps = (int(np.ceil(r / W.shape[0])), int(np.ceil(c / W.shape[1])))
        W = np.tile(W, reps)
    W = jnp.asarray(W[:r, :c])
    # layer-input Hessian from calibration activations through the embed
    toks = calib_tokens(8)
    emb = params["embed"][toks]  # (B,S,D)
    X = emb.reshape(-1, emb.shape[-1])
    if X.shape[-1] != c:
        X = jax.random.normal(key, (2048, c)) @ (
            jnp.eye(c) + 0.3 * jax.random.normal(jax.random.PRNGKey(1),
                                                 (c, c)) / np.sqrt(c))
    st = hes.accumulate(hes.init_hessian(c), X)
    H = hes.finalize(st)
    return W, H


def timed(fn, *args, reps=1, **kw):
    """(result, us_per_call) with a warmup call."""
    r = fn(*args, **kw)
    jax.block_until_ready(jax.tree.leaves(r)[0]) if jax.tree.leaves(r) else None
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*args, **kw)
    leaves = jax.tree.leaves(r)
    if leaves:
        jax.block_until_ready(leaves[0])
    dt = (time.perf_counter() - t0) / reps
    return r, dt * 1e6


def row(name: str, us: float, derived) -> str:
    line = f"{name},{us:.1f},{derived}"
    print(line, flush=True)
    return line
