"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Table mapping in DESIGN.md §7.
Run: PYTHONPATH=src:. python -m benchmarks.run [--only tab2,fig2]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    sys.path.insert(0, ".")
    from benchmarks import (fig2_sqnr, tab1_kmeans_vs_gptvq, tab2_main,
                            tab3_transfer, tab6_em_init, tab7_em_iters,
                            tab8_overhead, tab9_codebook_update,
                            tab10_scale_bs, tab11_scaling)

    suites = {
        "fig2": fig2_sqnr.run,
        "tab1": tab1_kmeans_vs_gptvq.run,
        "tab2": tab2_main.run,
        "tab3": tab3_transfer.run,
        "tab6": tab6_em_init.run,
        "tab7": tab7_em_iters.run,
        "tab8": tab8_overhead.run,
        "tab9": tab9_codebook_update.run,
        "tab10": tab10_scale_bs.run,
        "tab11": tab11_scaling.run,
    }
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args, _ = ap.parse_known_args()
    selected = [s for s in args.only.split(",") if s] or list(suites)

    print("name,us_per_call,derived")
    failures = []
    for name in selected:
        t0 = time.time()
        try:
            suites[name]()
            print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(name)
            print(f"# {name} FAILED:", flush=True)
            traceback.print_exc()
    if failures:
        print(f"# FAILURES: {failures}")
        sys.exit(1)
    print("# all benchmarks complete")


if __name__ == "__main__":
    main()
