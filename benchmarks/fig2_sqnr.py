"""Figure 2: quantization SQNR vs dimensionality at equal total overhead.

Paper claim: uniform < non-uniform (1D VQ) < 2D VQ < 4D VQ in SQNR when the
codebook overhead is held at 0.25 b/weight.
"""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_problem, row, timed
from repro.core import hessian as hes
from repro.core.bpv import VQConfig, group_size_for_overhead
from repro.core.gptvq import gptvq_quantize_matrix
from repro.core.quant import rtn_quantize


def sqnr_db(W, Q):
    err = jnp.sum((W - Q) ** 2)
    sig = jnp.sum(W**2)
    return float(10 * jnp.log10(sig / jnp.maximum(err, 1e-20)))


def run(bits: float = 2.0):
    # bits=2 keeps k << vectors-per-codebook at bench-matrix scale for all
    # d in {1,2,4}; at d=4,b=3 the codebook would exceed the vector count
    # and SQNR degenerates to exact reconstruction (not a real data point)
    W, H = bench_problem(r=128, c=512)
    U = hes.inv_hessian_cholesky(H)
    eye = jnp.eye(W.shape[1])
    Ueye = hes.inv_hessian_cholesky(jnp.eye(W.shape[1]))
    out = []

    Q, us = timed(rtn_quantize, W, int(bits), 64)  # 16b scale/64 = 0.25 bpv
    out.append(row(f"fig2/uniform_{bits:g}b", us, f"sqnr_db={sqnr_db(W, Q):.2f}"))

    for d in (1, 2, 4):
        gs = group_size_for_overhead(d, bits, 0.25, 8)
        cfg = VQConfig(d=d, bits_per_dim=bits, group_size=gs, em_iters=30,
                       codebook_update_iters=0)
        # data-free variant isolates pure representational power (Fig 2
        # measures SQNR of the representation, not the algorithm)
        res, us = timed(gptvq_quantize_matrix, W, Ueye, cfg)
        out.append(row(f"fig2/vq{d}d_{bits:g}b", us,
                       f"sqnr_db={sqnr_db(W, res.arrays.Q):.2f}"))
    return out


if __name__ == "__main__":
    run()
