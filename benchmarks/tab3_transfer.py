"""Table 3: footprint + decode latency of VQ vs integer formats.

The paper measures an ARM TBL kernel on a Snapdragon CPU. Here (DESIGN §6.4)
we report (a) the exact relative HBM footprint per format — the quantity
that bounds weight-movement latency on TPU where decode is bandwidth-bound —
and (b) host wall-clock of the fused dequant-matmul (XLA path) vs a dense
matmul as a directional latency proxy.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_problem, row, timed
from repro.core import vq_linear as vql_mod
from repro.core.bpv import VQConfig
from repro.kernels import ops


def run():
    W, H = bench_problem(r=256, c=512)
    x = jax.random.normal(jax.random.PRNGKey(0), (64, 512))
    n = W.size
    out = []

    dense16 = jnp.asarray(W, jnp.bfloat16)
    f_dense = jax.jit(lambda a, b: a.astype(jnp.bfloat16) @ b.T)
    _, us16 = timed(f_dense, x, dense16, reps=20)
    out.append(row("tab3/int16_dense", us16, "rel_footprint=1.00(vs int4=4.0)"))

    base_bytes = n * 0.5  # int4 baseline footprint
    for name, cfg in (
        ("2d_2.5b@512", VQConfig(d=2, bits_per_dim=2.5, group_size=512)),
        ("2d_2b@1024", VQConfig(d=2, bits_per_dim=2, group_size=1024)),
        ("1d_3b@128", VQConfig(d=1, bits_per_dim=3, group_size=128)),
    ):
        vql = vql_mod.quantize_array(W, H, type(cfg)(
            **{**cfg.__dict__, "em_iters": 10, "codebook_update_iters": 0}))
        f_vq = jax.jit(lambda a, v=vql: ops.vql_matmul(
            a, v, use_pallas=False))
        _, us = timed(f_vq, x, reps=20)
        rel_fp = vql.payload_bytes() / base_bytes
        rel_bpv = cfg.bits_per_value / 4.0
        out.append(row(f"tab3/vq_{name}", us,
                       f"rel_footprint={rel_bpv:.2f};measured={rel_fp:.2f};"
                       f"rel_latency_host={us / us16:.2f}"))
    return out


if __name__ == "__main__":
    run()
