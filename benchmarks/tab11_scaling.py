"""Table 11: blockwise normalization on/off at EQUAL total overhead (scaled
variants double the group size to pay for the 4-bit scales)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import bench_problem, row, timed
from repro.core import hessian as hes
from repro.core.bpv import VQConfig
from repro.core.gptvq import gptvq_quantize_matrix, layer_error


def run():
    W, H = bench_problem(r=128, c=512)
    scale = jnp.exp2(jax.random.randint(jax.random.PRNGKey(3),
                                        (W.shape[0], 1), -3, 4).astype(jnp.float32))
    W = W * scale
    U = hes.inv_hessian_cholesky(H)
    out = []
    pairs = [
        ("1d_2b", VQConfig(d=1, bits_per_dim=2, group_size=256),
         VQConfig(d=1, bits_per_dim=2, group_size=512, scale_block=32)),
        ("1d_3b", VQConfig(d=1, bits_per_dim=3, group_size=512),
         VQConfig(d=1, bits_per_dim=3, group_size=1024, scale_block=32)),
        ("2d_2b", VQConfig(d=2, bits_per_dim=2, group_size=2048),
         VQConfig(d=2, bits_per_dim=2, group_size=4096, scale_block=32)),
        ("2d_3b", VQConfig(d=2, bits_per_dim=3, group_size=8192),
         VQConfig(d=2, bits_per_dim=3, group_size=16384, scale_block=32)),
    ]
    for tag, cfg_off, cfg_on in pairs:
        for label, cfg in (("noscale", cfg_off), ("scale", cfg_on)):
            cfg = type(cfg)(**{**cfg.__dict__, "em_iters": 30,
                               "codebook_update_iters": 0})
            res, us = timed(gptvq_quantize_matrix, W, U, cfg)
            e = float(layer_error(W, res.arrays.Q, H))
            out.append(row(f"tab11/{tag}_{label}", us,
                           f"layer_err={e:.5f};bpv={cfg.bits_per_value:.3f}"))
    return out


if __name__ == "__main__":
    run()
