"""Table 2 (+4): main result — RTN / GPTQ / GPTVQ-{1,2,4}D at matched bpv.

Paper claim ordering at every bpv: RTN > GPTQ > VQ-1D > VQ-2D (> VQ-4D),
lower perplexity better, with the gap largest at 2-bit settings.
Zero-shot task suites are not reproducible offline; perplexity carries the
comparison (DESIGN.md §6.3).
"""
from __future__ import annotations

from benchmarks.common import (calib_tokens, eval_ppl, get_model_and_params,
                               row, timed)
from repro.core.bpv import PAPER_SETTINGS
from repro.core.pipeline import quantize_model


SETTINGS = {
    "2.25bpv": {
        "rtn": {"bits": 2, "group_size": 64},
        "gptq": {"bits": 2, "group_size": 64},
        "vq1d": PAPER_SETTINGS["2.25bpv_1d"],
        "vq2d": PAPER_SETTINGS["2.25bpv_2d"],
        "vq4d": PAPER_SETTINGS["2.25bpv_4d"],
    },
    "3.125bpv": {
        "rtn": {"bits": 3, "group_size": 128},
        "gptq": {"bits": 3, "group_size": 128},
        "vq1d": PAPER_SETTINGS["3.125bpv_1d"],
        "vq2d": PAPER_SETTINGS["3.125bpv_2d"],
    },
    "4.125bpv": {
        "rtn": {"bits": 4, "group_size": 128},
        "gptq": {"bits": 4, "group_size": 128},
        "vq1d": PAPER_SETTINGS["4.125bpv_1d"],
        "vq2d": PAPER_SETTINGS["4.125bpv_2d"],
    },
}


def run(budgets=("2.25bpv", "3.125bpv", "4.125bpv")):
    model, params = get_model_and_params()
    calib = calib_tokens()
    out = [row("tab2/fp16", 0.0, f"ppl={eval_ppl(model, params):.3f}")]
    for budget in budgets:
        for name, cfg in SETTINGS[budget].items():
            method = ("rtn" if name == "rtn" else
                      "gptq" if name == "gptq" else "gptvq")
            vcfg = cfg
            if method == "gptvq":
                vcfg = type(cfg)(**{**cfg.__dict__, "em_iters": 25,
                                    "codebook_update_iters": 10})
            (qp, rep), us = timed(
                quantize_model, model, params, calib, method, vcfg, chunk=16)
            out.append(row(f"tab2/{budget}_{name}", us,
                           f"ppl={eval_ppl(model, qp):.3f};bpv={rep.bits_per_value:.3f}"))
    return out


if __name__ == "__main__":
    run()
