"""Table 8: equal-bpv overhead routes — bigger groups w/ fp16 codebooks vs
int8 codebook quantization w/ half group size vs SVD rank-reduction.
Paper finding: int8 codebooks generally win slightly."""
from __future__ import annotations

from benchmarks.common import bench_problem, row, timed
from repro.core import hessian as hes
from repro.core.bpv import VQConfig
from repro.core.codebook_compress import quantize_codebooks, svd_compress
from repro.core.gptvq import gptvq_quantize_matrix, layer_error


def _run_one(W, H, U, cfg, use_svd=False):
    res = gptvq_quantize_matrix(W, U, cfg)
    if use_svd:
        res, _ = svd_compress(res, W, H)
    elif cfg.codebook_bits < 16:
        res = quantize_codebooks(res)
    return res


def run():
    W, H = bench_problem(r=128, c=512)
    U = hes.inv_hessian_cholesky(H)
    out = []
    cases = [
        # (tag, d, b, gs, codebook_bits, svd)  — matched total bpv pairs
        ("1d_2b_gs512_fp16", 1, 2, 512, 16, False),
        ("1d_2b_gs256_int8", 1, 2, 256, 8, False),
        ("1d_2b_gs256_svd", 1, 2, 256, 16, True),
        ("2d_3b_gs16384_fp16", 2, 3, 16384, 16, False),
        ("2d_3b_gs8192_int8", 2, 3, 8192, 8, False),
    ]
    for tag, d, b, gs, cb, svd in cases:
        cfg = VQConfig(d=d, bits_per_dim=b, group_size=gs, codebook_bits=cb,
                       em_iters=30, codebook_update_iters=0,
                       svd_rank_frac=0.5 if svd else 0.0)
        res, us = timed(_run_one, W, H, U, cfg, use_svd=svd)
        e = float(layer_error(W, res.arrays.Q, H))
        out.append(row(f"tab8/{tag}", us,
                       f"layer_err={e:.5f};bpv={cfg.bits_per_value:.3f}"))
    return out


if __name__ == "__main__":
    run()
