"""Table 6: EM seeding — Mahalanobis sort vs k-means++ (quality ~equal,
Mahalanobis much faster)."""
from __future__ import annotations

from benchmarks.common import bench_problem, row, timed
from repro.core import hessian as hes
from repro.core.bpv import VQConfig
from repro.core.gptvq import gptvq_quantize_matrix, layer_error


def run():
    W, H = bench_problem(r=128, c=512)
    U = hes.inv_hessian_cholesky(H)
    out = []
    for setting, d, b, gs in (("1d_3b", 1, 3, 1024), ("2d_3b", 2, 3, 16384)):
        for seed_method in ("mahalanobis", "kmeans++"):
            cfg = VQConfig(d=d, bits_per_dim=b, group_size=gs, em_iters=50,
                           em_seed=seed_method, codebook_update_iters=0)
            res, us = timed(gptvq_quantize_matrix, W, U, cfg)
            e = float(layer_error(W, res.arrays.Q, H))
            out.append(row(f"tab6/{setting}_{seed_method}", us,
                           f"layer_err={e:.5f}"))
    return out


if __name__ == "__main__":
    run()
