"""Quantization-throughput benchmark: the budget pre-pass + allocator and
the pluggable inner solvers, on the trained bench-lm model.

GPTVQ's headline claim is speed (3-11 h for a 70B on one H100), so the
production path must not be dominated by its own bookkeeping. This bench
measures the two changes that made the budgeted pipeline scale:

  * O(c) diagonal-Hessian pre-pass (adapters diag_capture) vs the old
    full (c, c) capture that was read only for its diagonal;
  * closed-form rate-distortion budget scoring
    (recipe.closed_form_proxy_error) vs the refit-per-candidate oracle
    (``scorer="refit"``) that ran a trimmed GPTVQ sweep for every
    (target x candidate) pair.

The headline number is ``prepass_allocator_speedup_closed_form_over_
refit`` — pre-pass + allocator wall, new path over old path (acceptance
bar: >= 5x) — plus the scorer agreement fraction (same setting chosen
per target at the same budget). A full budgeted ``quantize_model`` run
records the honest stage breakdown (``em_init`` split from
``column_sweep`` since the solver refactor), and the three inner
solvers (gptq / babai / cd) are compared on reconstruction error and
wall time at a uniform setting.

Emits ``BENCH_quant.json``.

Run: PYTHONPATH=src:. python benchmarks/quantize_throughput.py --smoke
     [--out BENCH_quant.json]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import jax.numpy as jnp

from benchmarks.common import calib_tokens, get_model_and_params
from repro.core import adapters
from repro.core import hessian as hes
from repro.core.pipeline import (
    _block_prefix,
    _budget_prepass,
    _collect_targets,
    quantize_model,
)
from repro.core.recipe import (
    BUDGET_CANDIDATES,
    PAPER_SETTINGS,
    BudgetEntry,
    QuantRecipe,
    Quantize,
    _proxy_error,
    allocate_budget,
    closed_form_proxy_error,
)


def _full_hessian_prepass(adapter, chunks, plan):
    """The pre-PR baseline: accumulate full (c, c) Hessians per tap and
    read only their diagonals. Kept here (not in the pipeline) purely as
    the measurement baseline for the O(c) diag_capture pre-pass."""
    states = [adapter.calib_state(c, ci) for ci, c in enumerate(chunks)]
    blocks = adapter.blocks()
    diag = {}
    for blk in blocks:
        prefix = _block_prefix(blk)
        eligible = [
            spec for spec in blk.targets()
            if isinstance(plan[f"{prefix}.{spec.name}"].action, Quantize)
            and spec.tap is not None]
        groups = frozenset(spec.group for spec in eligible)
        taps: dict = {}
        if groups:
            for st in states:
                taps = blk.capture(st, taps, groups)
        for spec in eligible:
            tap = taps.get(spec.tap)
            if tap is None:
                continue
            name = f"{prefix}.{spec.name}"
            if spec.per_expert:
                Hs, n = tap
                He = Hs / jnp.maximum(n, 1.0)[:, None, None]
                diag[name] = jnp.mean(jax.vmap(jnp.diagonal)(He), axis=0)
            else:
                diag[name] = jnp.diagonal(hes.finalize(tap))
        blk.install(blk.params())
        states = [blk.advance(st) for st in states]
    return diag


def _entries(adapter, plan, diag):
    """BudgetEntry rows for every Quantize-resolved target (the same
    construction pipeline._allocate performs before allocating)."""
    rows = []
    for blk in adapter.blocks():
        prefix = _block_prefix(blk)
        block_params = blk.params()
        for spec in blk.targets():
            name = f"{prefix}.{spec.name}"
            res = plan[name]
            if not isinstance(res.action, Quantize):
                continue
            W = adapters.tree_get(block_params, spec.path)
            if spec.per_expert:
                replicas, Wq = W.shape[0], W[0].T.astype(jnp.float32)
            else:
                replicas, Wq = 1, W.T.astype(jnp.float32)
            rows.append(BudgetEntry(
                name=name, W=Wq, diag_h=diag.get(name),
                base_cfg=res.action.cfg, numel=W.size, replicas=replicas))
    return rows


def _timed(fn, reps=2):
    """best-of-reps wall time; first rep pays any compilation."""
    best, out = float("inf"), None
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run (fewer sequences, short EM)")
    ap.add_argument("--out", default="BENCH_quant.json")
    ap.add_argument("--budget-bpv", type=float, default=2.5)
    args = ap.parse_args()

    model, params = get_model_and_params()
    n_seq = 4 if args.smoke else 16
    tokens = calib_tokens(n=n_seq)
    chunks = [tokens[i:i + 8] for i in range(0, n_seq, 8)]
    em = 5 if args.smoke else 25
    up = 0 if args.smoke else 10
    recipe = QuantRecipe.uniform(
        PAPER_SETTINGS["2.25bpv_2d"], name="2.25bpv_2d"
    ).with_quantize_overrides(em_iters=em, codebook_update_iters=up)

    adapter = adapters.get_adapter(model, params)
    plan = recipe.resolve(_collect_targets(adapter.blocks()))

    print("== budget pre-pass: O(c) diag_capture vs full (c,c) ==",
          flush=True)
    def _run_diag():
        out = _budget_prepass(adapter, chunks, plan, None)
        jax.block_until_ready(out[0])
        return out

    t_diag, (diag, _missed) = _timed(_run_diag)
    t_full, _diag_full = _timed(
        lambda: jax.block_until_ready(
            _full_hessian_prepass(adapter, chunks, plan)))
    entries = _entries(adapter, plan, diag)
    print(f"  diag={t_diag:.2f}s full={t_full:.2f}s "
          f"({len(entries)} targets)", flush=True)

    print("== allocator: closed-form vs refit-per-candidate ==", flush=True)
    t_cf, alloc_cf = _timed(
        lambda: allocate_budget(entries, args.budget_bpv,
                                scorer="closed_form"))
    t_refit, alloc_refit = _timed(
        lambda: allocate_budget(entries, args.budget_bpv, scorer="refit"))
    new_path = t_diag + t_cf
    old_path = t_full + t_refit
    speedup = old_path / max(new_path, 1e-9)
    # per-target best-candidate agreement: do the two scorers name the
    # same argmin-error setting? (Allocation-level agreement is diluted
    # by greedy tie-flips among candidates both scorers price at ~0.)
    same = 0
    for e in entries:
        rows = []
        for s in BUDGET_CANDIDATES:
            b = PAPER_SETTINGS[s]
            if e.W.shape[1] % b.d:
                continue
            cfg = dataclasses.replace(
                e.base_cfg, d=b.d, bits_per_dim=b.bits_per_dim,
                group_size=b.group_size, codebook_bits=b.codebook_bits)
            rows.append((s, closed_form_proxy_error(e.W, e.diag_h, cfg),
                         _proxy_error(e.W, e.diag_h, cfg)))
        same += (min(rows, key=lambda t: t[1])[0]
                 == min(rows, key=lambda t: t[2])[0])
    agree_frac = same / max(len(entries), 1)
    alloc_agree = (sum(alloc_cf[n][0] == alloc_refit[n][0]
                       for n in alloc_cf) / max(len(alloc_cf), 1))
    print(f"  closed_form={t_cf:.2f}s refit={t_refit:.2f}s | "
          f"pre-pass+allocator speedup={speedup:.1f}x "
          f"argmin agreement={agree_frac:.2f} "
          f"(allocation {alloc_agree:.2f})", flush=True)

    print("== budgeted quantize_model stage breakdown ==", flush=True)
    _, rep = quantize_model(model, params, tokens, recipe=recipe,
                            budget_bpv=args.budget_bpv, pack=True)
    stages = {k: round(v, 3) for k, v in rep.stage_seconds.items()}
    print(f"  stages: {stages}", flush=True)

    print("== inner solvers at uniform 2.25bpv_2d ==", flush=True)
    # shared-stage warmup (em_init compiles are solver-independent) so
    # the first solver timed doesn't foot the whole compile bill
    quantize_model(model, params, tokens, recipe=recipe)
    solver_err, solver_s = {}, {}
    for solver in ("gptq", "babai", "cd"):
        t0 = time.perf_counter()
        _, srep = quantize_model(model, params, tokens,
                                 recipe=recipe.with_solver(solver))
        solver_s[solver] = round(time.perf_counter() - t0, 2)
        solver_err[solver] = round(srep.total_error(), 5)
        print(f"  {solver}: err={solver_err[solver]} "
              f"wall={solver_s[solver]}s", flush=True)

    report = {
        "model": "bench-lm",
        "smoke": bool(args.smoke),
        "budget_bpv": args.budget_bpv,
        "n_quantize_targets": len(entries),
        "prepass_seconds_diag_o_c": round(t_diag, 3),
        "prepass_seconds_full_c2": round(t_full, 3),
        "allocator_seconds_closed_form": round(t_cf, 3),
        "allocator_seconds_refit": round(t_refit, 3),
        "prepass_allocator_speedup_closed_form_over_refit":
            round(speedup, 2),
        "scorer_argmin_agreement_fraction": round(agree_frac, 3),
        "scorer_allocation_agreement_fraction": round(alloc_agree, 3),
        "budgeted_achieved_bpv": round(rep.achieved_bpv, 4),
        "stage_seconds": stages,
        "solver_error": solver_err,
        "solver_seconds": solver_s,
        "solver_error_babai_over_gptq": round(
            solver_err["babai"] / max(solver_err["gptq"], 1e-12), 4),
        "solver_error_cd_over_gptq": round(
            solver_err["cd"] / max(solver_err["gptq"], 1e-12), 4),
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {os.path.abspath(args.out)}; "
          f"pre-pass+allocator speedup = {speedup:.1f}x, "
          f"scorer argmin agreement = {agree_frac:.2f}, "
          f"solver err ratios babai/gptq = "
          f"{report['solver_error_babai_over_gptq']}, cd/gptq = "
          f"{report['solver_error_cd_over_gptq']}")


if __name__ == "__main__":
    main()
