"""Table 9: codebook update (GD on ||WX-QX||^2) ablation — always helps,
at moderate extra runtime."""
from __future__ import annotations

from benchmarks.common import bench_problem, row, timed
from repro.core import hessian as hes
from repro.core.bpv import VQConfig
from repro.core.codebook_compress import codebook_update
from repro.core.gptvq import gptvq_quantize_matrix, layer_error


def run():
    W, H = bench_problem(r=128, c=512)
    U = hes.inv_hessian_cholesky(H)
    out = []
    for d, b, gs in ((1, 2, 512), (1, 3, 1024), (2, 2, 2048), (2, 3, 8192)):
        cfg = VQConfig(d=d, bits_per_dim=b, group_size=gs, em_iters=30,
                       codebook_update_iters=25)

        def no_update():
            return gptvq_quantize_matrix(W, U, cfg)

        def with_update():
            return codebook_update(no_update(), W, H)

        res0, us0 = timed(no_update)
        res1, us1 = timed(with_update)
        e0 = float(layer_error(W, res0.arrays.Q, H))
        e1 = float(layer_error(W, res1.arrays.Q, H))
        out.append(row(f"tab9/{d}d_{b}b_noupdate", us0, f"layer_err={e0:.5f}"))
        out.append(row(f"tab9/{d}d_{b}b_update", us1, f"layer_err={e1:.5f}"))
    return out


if __name__ == "__main__":
    run()
