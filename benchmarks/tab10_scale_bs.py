"""Table 10: blockwise-normalization scaling block size sweep."""
from __future__ import annotations

import jax.numpy as jnp

from benchmarks.common import bench_problem, row, timed
from repro.core import hessian as hes
from repro.core.bpv import VQConfig
from repro.core.gptvq import gptvq_quantize_matrix, layer_error


def run():
    W, H = bench_problem(r=128, c=512)
    # inject realistic per-row magnitude spread (outlier rows)
    import jax
    scale = jnp.exp2(jax.random.randint(jax.random.PRNGKey(3),
                                        (W.shape[0], 1), -3, 4).astype(jnp.float32))
    W = W * scale
    U = hes.inv_hessian_cholesky(H)
    out = []
    for ns in (0, 128, 64, 32, 16, 8):
        cfg = VQConfig(d=2, bits_per_dim=3, group_size=8192, em_iters=30,
                       scale_block=ns, codebook_update_iters=0)
        res, us = timed(gptvq_quantize_matrix, W, U, cfg)
        e = float(layer_error(W, res.arrays.Q, H))
        tag = "none" if ns == 0 else str(ns)
        out.append(row(f"tab10/scale_bs_{tag}", us, f"layer_err={e:.5f}"))
    return out


if __name__ == "__main__":
    run()
