"""Serve-throughput benchmark: paged continuous-batching engine (gather vs
fused paged-attention decode) vs the pre-PR-2 dense-slot engine, fp32 vs
GPTVQ-packed weights.

Workload: a burst of requests with many *distinct* prompt lengths (the
realistic serving shape) on the qwen3-1.7b config family. Reports decode
tokens/s and time-to-first-token (TTFT) at max_batch in {1, 8}, and emits
``BENCH_serve.json``. Quantized-cache cells (``kv_bits`` 8/4/"vq2") rerun
the fused engine with int8/packed-int4/vector-quantized KV pages at a
FIXED per-layer pool byte budget (the fp32 default pool's footprint),
reporting the allocatable-page headroom the same bytes buy alongside the
decode throughput cost of dequantizing on the fly. The ``kv_vq2`` cells
additionally report ``kv_vq2_max_logit_drift_vs_fp32``: decode logits
teacher-forced onto the fp32-cache anchor's greedy token path, drift
taken as the per-step RMS logit difference across the vocab, max over
steps (the scale-stable statistic — a single-logit max is an order
statistic of |V| near-iid errors and grows with vocab size, not cache
quality). The legacy engine is kept here (not in serve/) as the
measurement baseline: it prefility-tiles a full max_batch-wide batch per
admission and retraces per distinct prompt length — exactly the costs the
paged engine removes.

The ``paged-fused`` cells run the engine with ``paged_attn_impl="fused"``:
on TPU that is the Pallas in-kernel page-gather decode kernel
(kernels/paged_attention.py); off-TPU it resolves to the kernel's XLA
oracle through the same fused dispatch boundary (interpret-mode Pallas is
a correctness emulator, not a perf path — the differential suite, not this
bench, is what validates the kernel off-TPU). Each result row records
which backend actually ran in ``fused_backend``.

The ``vq_fused`` cells rerun the VQ-packed engine with
``vq_matmul_impl="fused"`` (kernels/vq_dequant_matmul.py on TPU, the
prep-folded XLA oracle elsewhere — ``vq_backend`` records which) against
the ``vq`` dequant baseline (per-layer dense materialization inside the
forward, the pre-fused path). Their headline ratio is the median of
PAIRED per-pass wall ratios, same methodology as the kv8 cells, and the
report carries the HBM payload accounting: bytes the packed weights
stream per decode tick vs the dense fp32 weights they replace.

The ``prefix_warm`` cell measures the prefix-sharing subsystem
(serve/prefix_cache.py): one engine with the radix cache on serves a
cold then a warm request sharing a 512-token prefix, back to back with a
fresh prefix each pass; the paired per-pass warm/cold TTFT ratio is the
headline (warm admission skips every fully-shared page's prefill, so the
acceptance bar is < 0.5x).

Measurement comes from the engine's own telemetry (obs/): per-pass wall
and token counts are ``Engine.stats`` deltas, TTFT comes from drained
request records, and each paged cell reports the host/device split of
its decode ticks from the ``span.decode_tick/*`` histograms. The
``fp32-noobs`` cell reruns the fused fp32 engine with
``Telemetry(enabled=False)`` back-to-back against the telemetry-on cell;
the paired ``obs_overhead`` ratio pins the cost of the instrumentation
itself (the 2% budget the obs/ subsystem is held to).

Run: PYTHONPATH=src python benchmarks/serve_throughput.py --smoke
     [--out BENCH_serve.json]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import SMOKE
from repro.core.bpv import VQConfig
from repro.core.pipeline import quantize_model
from repro.data.synthetic import sample_batch
from repro.models import model_zoo
from repro.obs import Telemetry
from repro.serve import sampling
from repro.serve.engine import Engine, Request
from repro.serve.serve_step import make_decode, make_prefill


# ---------------------------------------------------------------------------
# legacy dense-slot engine (pre-paged baseline, measurement only)
# ---------------------------------------------------------------------------

class LegacySlotEngine:
    """The PR-1 engine: dense (max_batch, max_len) cache, full prefill at
    admit over a max_batch-wide tiled batch, one shared max-position write
    index per decode tick."""

    def __init__(self, model, params, *, max_batch=8, max_len=512):
        self.model, self.params = model, params
        self.max_batch, self.max_len = max_batch, max_len
        self.cache = model.init_cache(max_batch, max_len, dtype=jnp.float32)
        self.prefill = jax.jit(make_prefill(model))
        self.decode = jax.jit(make_decode(model))
        self.slots = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int64)
        self.last_tok = np.zeros(max_batch, np.int32)
        self.ticks = 0

    def _free_slot(self):
        return next((i for i, s in enumerate(self.slots) if s is None), None)

    def admit(self, req):
        slot = self._free_slot()
        if slot is None:
            return False
        S = len(req.prompt)
        assert S + req.max_new_tokens <= self.max_len
        tok_b = jnp.zeros((self.max_batch, S), jnp.int32).at[slot].set(
            jnp.asarray(req.prompt, jnp.int32))
        logits, new_cache = self.prefill(
            self.params, {"tokens": tok_b}, self.cache)
        self.cache = _merge_slot(self.cache, new_cache, slot, self.max_batch)
        self.slots[slot] = req
        self.pos[slot] = S
        nxt = int(jnp.argmax(logits[slot, S - 1]))
        req.out_tokens.append(nxt)
        self.last_tok[slot] = nxt
        return True

    def step(self):
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        pos = int(self.pos.max())  # shared write position (the known bug)
        toks = jnp.asarray(self.last_tok[:, None], jnp.int32)
        logits, self.cache = self.decode(self.params, toks, self.cache, pos)
        nxt = np.asarray(sampling.sample(jax.random.PRNGKey(0),
                                         logits[:, -1], temperature=0.0))
        for i in active:
            req = self.slots[i]
            t = int(nxt[i])
            req.out_tokens.append(t)
            self.last_tok[i] = t
            self.pos[i] = pos + 1
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done = True
                self.slots[i] = None
        self.ticks += 1


def _merge_slot(old_cache, new_cache, slot, batch):
    def merge_leaf(o, n):
        ax = next((i for i, s in enumerate(o.shape) if s == batch), None)
        if ax is None:
            return n
        idx = [slice(None)] * o.ndim
        idx[ax] = slice(slot, slot + 1)
        return o.at[tuple(idx)].set(n[tuple(idx)])

    return jax.tree.map(merge_leaf, old_cache, new_cache)


# ---------------------------------------------------------------------------
# drivers (shared TTFT instrumentation)
# ---------------------------------------------------------------------------

def run_paged(eng, reqs):
    """Drive one burst through the paged engine, measured by the engine's
    own telemetry: wall/token counts are ``Engine.stats`` deltas (the
    stats accumulate continuously, so deltas isolate this pass on a warm
    persistent engine) and TTFT comes from the drained request records
    (enqueue -> first sampled token, per request, not polled at tick
    granularity like the old perf_counter stitching)."""
    tokens0, wall0 = eng.stats["tokens"], eng.stats["wall_s"]
    for r in reqs:
        eng.submit(r)
    while eng.scheduler.has_work() and eng.ticks < 100_000:
        eng.step()
    ttft = {rec.rid: rec.ttft_s for rec in eng.drain_request_records()
            if rec.ttft_s is not None}
    return (eng.stats["wall_s"] - wall0, eng.stats["tokens"] - tokens0,
            ttft)


def run_legacy(eng, reqs):
    pending = list(reqs)
    ttft = {}
    t0 = time.perf_counter()
    while pending or any(eng.slots):
        while pending and eng._free_slot() is not None:
            if not eng.admit(pending[0]):
                break
            pending.pop(0)
        eng.step()
        now = time.perf_counter() - t0
        for r in reqs:
            if r.out_tokens and r.rid not in ttft:
                ttft[r.rid] = now
    wall = time.perf_counter() - t0
    return wall, sum(len(r.out_tokens) for r in reqs), ttft


class BenchCase:
    """One (engine kind, weights, kv_bits, max_batch) cell: a persistent
    warm engine plus per-pass measurements. Passes of different cases are
    interleaved and summarized by the median, so ambient machine noise
    hits every case evenly instead of whichever ran last.

    ``kv_bits`` < 16 stores the paged KV pool as int8/packed-int4 code
    pages (per-row per-kv-head scales, dequantized on the fly by the
    fused read path); ``pool_bytes`` sizes the pool by a fixed per-layer
    byte budget, so the quantized cells report how many extra allocatable
    pages the same bytes buy."""

    def __init__(self, kind, wtag, model, params, max_batch, max_len,
                 kv_bits=16, pool_bytes=None, page_size=16,
                 vq_impl="gather", telemetry_enabled=True):
        self.kind, self.wtag, self.max_batch = kind, wtag, max_batch
        self.kv_bits = kv_bits
        self.telemetry_enabled = telemetry_enabled
        self.backend = None
        self.vq_backend = None
        self.allocatable_pages = None
        if kind.startswith("paged"):
            impl = "fused" if kind == "paged-fused" else "gather"
            self.eng = Engine(model, params, max_batch=max_batch,
                              max_len=max_len, paged_attn_impl=impl,
                              kv_cache_bits=kv_bits, pool_bytes=pool_bytes,
                              page_size=page_size, vq_matmul_impl=vq_impl,
                              telemetry=Telemetry(
                                  enabled=telemetry_enabled))
            self.backend = self.eng.paged_attn_impl
            self.vq_backend = self.eng.vq_matmul_impl
            self.allocatable_pages = self.eng.scheduler.allocator.capacity
            self.runner = run_paged
        else:
            assert kv_bits == 16  # the legacy dense cache has no pages
            self.eng = LegacySlotEngine(model, params, max_batch=max_batch,
                                        max_len=max_len)
            self.runner = run_legacy
            self.telemetry_enabled = False  # no telemetry in the baseline
        self.cold_wall_s = None
        self.walls, self.ttfts = [], []
        self.tokens = 0
        self.host_prep_s = 0.0
        self.device_s = 0.0

    def _span_sums(self):
        """(host_prep, device) cumulative seconds from the decode-tick
        span histograms; (0, 0) for the legacy engine / disabled obs."""
        tel = getattr(self.eng, "telemetry", None)
        if tel is None or not tel.enabled:
            return 0.0, 0.0
        snap = tel.registry.snapshot()

        def ssum(name):
            h = snap.get(name)
            return h["sum"] if isinstance(h, dict) else 0.0

        return (ssum("span.decode_tick/host_prep"),
                ssum("span.decode_tick/device"))

    def one_pass(self, prompts, max_new, rid0):
        reqs = [Request(rid=rid0 + i, prompt=p, max_new_tokens=max_new)
                for i, p in enumerate(prompts)]
        h0, d0 = self._span_sums()
        wall, tokens, ttft = self.runner(self.eng, reqs)
        h1, d1 = self._span_sums()
        if self.cold_wall_s is None:
            self.cold_wall_s = wall  # first pass includes jit compiles
        else:
            self.walls.append(wall)
            if ttft:
                self.ttfts.append(float(np.mean(sorted(ttft.values()))))
            self.tokens = tokens
            self.host_prep_s += h1 - h0
            self.device_s += d1 - d0

    def summary(self):
        walls = sorted(self.walls)
        med = walls[len(walls) // 2]
        split = self.host_prep_s + self.device_s
        return {
            "engine": self.kind, "weights": self.wtag,
            "fused_backend": self.backend,
            "vq_backend": self.vq_backend,
            "kv_bits": self.kv_bits,
            "telemetry": self.telemetry_enabled,
            "allocatable_pages": self.allocatable_pages,
            "max_batch": self.max_batch, "tokens": self.tokens,
            "cold_wall_s": round(self.cold_wall_s, 4),
            "wall_s_median": round(med, 4),
            "tokens_per_s": round(self.tokens / med, 2),
            "tokens_per_s_best": round(self.tokens / walls[0], 2),
            "ttft_mean_s": (round(sorted(self.ttfts)[len(self.ttfts) // 2],
                                  4) if self.ttfts else None),
            # decode-tick host/device split over all measured passes (the
            # device span closes after the sampled-token download — the
            # tick's sync point — so it accounts device time under jax
            # async dispatch)
            "decode_host_prep_s": round(self.host_prep_s, 4),
            "decode_device_s": round(self.device_s, 4),
            "decode_device_frac": (round(self.device_s / split, 3)
                                   if split > 0 else None),
        }


def bench_vq2_drift(model, params, *, max_len, page_size, prompt_len=16,
                    decode_steps=8):
    """Max-over-steps RMS logit drift of a calibrated vq2 cache vs the
    fp32-cache anchor, teacher-forced onto the anchor's greedy token path
    (free-running traces diverge in token space and would compare logits
    of different sequences). RMS across the vocab is the per-step
    statistic; the acceptance bar is < 0.5."""
    from repro.models.attention import KVQuantSpec, PagedLayout
    from repro.serve import paged_cache as pc
    from repro.serve.engine import calibrate_vq_codebooks

    n_pages = max_len // page_size
    rng = np.random.RandomState(15)
    prompt = rng.randint(0, model.cfg.vocab_size - 1, size=prompt_len)
    table = np.arange(1, n_pages + 1, dtype=np.int32)[None]

    def trace(bits, forced=None):
        layout = PagedLayout(n_pages + 1, page_size, KVQuantSpec.of(bits))
        cache = model.init_cache(1, max_len, dtype=jnp.float32,
                                 paged=layout)
        if bits == "vq2":
            cache = calibrate_vq_codebooks(model, params, cache,
                                           page_size=page_size,
                                           calib_len=min(64, max_len))
        cache = pc.push_page_table(cache, table)
        logits, cache, _ = model.forward(
            params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]},
            cache=cache, pos=jnp.zeros((1,), jnp.int32))
        out, toks, pos = [logits[0, -1]], [], len(prompt)
        tok = int(jnp.argmax(logits[0, -1]))
        for i in range(decode_steps):
            if forced is not None:
                tok = forced[i]
            toks.append(tok)
            logits, cache, _ = model.forward(
                params, {"tokens": jnp.asarray([[tok]], jnp.int32)},
                cache=cache, pos=jnp.full((1,), pos, jnp.int32))
            out.append(logits[0, -1])
            tok = int(jnp.argmax(logits[0, -1]))
            pos += 1
        return out, toks

    anchor, anchor_toks = trace(16)
    vq, _ = trace("vq2", forced=anchor_toks)
    return max(float(jnp.sqrt(jnp.mean((a - b) ** 2)))
               for a, b in zip(anchor, vq))


def bench_prefix_warm(model, params, passes, vocab):
    """Warm-vs-cold TTFT for a 512-token shared prompt prefix.

    One persistent engine with the radix prefix cache on. Each pass draws
    a FRESH random 512-token prefix, serves a cold request (populates the
    cache — and, from pass 1 on, LRU-evicts the previous pass's now-cold
    branch under pool pressure), then a warm request with the same prefix
    and a divergent 8-token tail. The headline is the median of PAIRED
    per-pass warm/cold TTFT ratios (the two requests run back to back, so
    ambient host noise cancels — same methodology as the kv8/obs cells).
    Pass 0 is discarded (jit compiles); from then on both sides are
    jit-warm, so the ratio isolates the prefill actually skipped: the
    warm request enters at pos=512 and prefills only its 8-token tail."""
    eng = Engine(model, params, max_batch=1, max_len=576, page_size=16,
                 prefix_cache=True)
    rng = np.random.RandomState(17)
    colds, warms, ratios = [], [], []
    for i in range(passes + 1):
        prefix = rng.randint(0, vocab - 1, size=512)

        def req(rid):
            tail = rng.randint(0, vocab - 1, size=8)
            return Request(rid=rid, prompt=np.concatenate([prefix, tail]),
                           max_new_tokens=4)

        _, _, t_cold = run_paged(eng, [req(9000 + 2 * i)])
        _, _, t_warm = run_paged(eng, [req(9001 + 2 * i)])
        if i == 0:
            continue
        c, w = next(iter(t_cold.values())), next(iter(t_warm.values()))
        colds.append(c)
        warms.append(w)
        ratios.append(w / c)
    # every warm request must actually have hit (32 pages = the full
    # 512-token prefix; the 8-token tail page stays private)
    assert eng.stats["prefix_hits"] >= passes, eng.stats
    assert eng.stats["prefix_evictions"] > 0, \
        "fresh per-pass prefixes must have forced LRU eviction"
    ratios.sort()
    return {
        "engine": "paged", "weights": "fp32", "kind": "prefix_warm",
        "prefix_tokens": 512, "passes": passes,
        "prefix_hits": eng.stats["prefix_hits"],
        "prefix_hit_tokens": eng.stats["prefix_hit_tokens"],
        "prefix_evictions": eng.stats["prefix_evictions"],
        "ttft_cold_median_s": round(sorted(colds)[len(colds) // 2], 4),
        "ttft_warm_median_s": round(sorted(warms)[len(warms) // 2], 4),
        "ttft_warm_over_cold_median": round(ratios[len(ratios) // 2], 3),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run on the qwen3-1.7b SMOKE config")
    ap.add_argument("--out", default="BENCH_serve.json")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--max-new", type=int, default=0)
    args = ap.parse_args()

    # qwen3-1.7b architecture shape, scaled to a CI-runnable cell (the
    # SMOKE d_model=64 cell is per-op-overhead-bound and measures nothing)
    cfg = SMOKE["qwen3-1.7b"].scaled(
        dtype="float32", d_model=256, n_heads=8, n_kv_heads=4, head_dim=32,
        d_ff=512, vocab_size=4096, max_seq_len=256)
    n_req = args.requests or (8 if args.smoke else 16)
    max_new = args.max_new or (16 if args.smoke else 32)
    max_len = 128 if args.smoke else 256
    # enough passes for a stable median of the paired per-pass ratios —
    # single-pass walls are ~0.3-1s and this host's ambient load swings
    # unpaired medians by 40% between runs (a 12-rep A/B of the fp32 vs
    # kv8 fused cells spread paired ratios over 0.91-1.17 around a
    # best-wall ratio of 1.00)
    passes = 9 if args.smoke else 11
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    print(f"== quantizing {cfg.name} smoke weights (GPTVQ 2D packed) ==",
          flush=True)
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 32, 4)
    vq_cfg = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=5,
                      codebook_update_iters=0)
    qparams, _ = quantize_model(model, params, calib, "gptvq", vq_cfg,
                                pack=True)

    rng = np.random.RandomState(0)
    # many DISTINCT lengths: the realistic shape, and the one the legacy
    # engine retraces on
    lens = [6 + 5 * i for i in range(n_req)]
    prompts = [rng.randint(0, cfg.vocab_size - 1, size=s) for s in lens]

    # fixed per-layer pool byte budget for the quantized-cache cells: the
    # byte footprint of the fp32 default pool at each max_batch, so the
    # fp32 fused cell doubles as the fixed-bytes baseline and the kv8/kv4
    # cells show the page headroom the same bytes buy
    from repro.kernels import kv_quant

    page_size = 16  # passed explicitly to every BenchCase engine below,
    # so the budget arithmetic and the engines can never disagree
    n_pages = -(-max_len // page_size)
    blk_bytes = kv_quant.page_bytes(page_size, cfg.n_kv_heads, cfg.hd, 16,
                                    dtype_bytes=4)

    results = []
    all_cases = {}
    for mb in (1, 8):
        budget = (mb * n_pages + 1) * blk_bytes
        # the kv8/kv4 cells run IMMEDIATELY after their fp32 fused
        # baseline within each pass: their headline ratio is paired
        # per-pass, and back-to-back execution keeps minute-scale host
        # noise out of the pair
        cases = [
            BenchCase("paged", "fp32", model, params, mb, max_len,
                      page_size=page_size),
            BenchCase("paged-fused", "fp32", model, params, mb, max_len,
                      page_size=page_size),
            # same engine with telemetry disabled, run IMMEDIATELY after
            # the telemetry-on cell: the paired ratio is the cost of the
            # obs/ instrumentation itself
            BenchCase("paged-fused", "fp32-noobs", model, params, mb,
                      max_len, page_size=page_size,
                      telemetry_enabled=False),
            BenchCase("paged-fused", "fp32", model, params, mb, max_len,
                      kv_bits=8, pool_bytes=budget, page_size=page_size),
            BenchCase("paged-fused", "fp32", model, params, mb, max_len,
                      kv_bits=4, pool_bytes=budget, page_size=page_size),
            # the vq2 cell shares the same fixed byte budget: its page
            # headroom pays for packed 4-bit codebook indices over d=2
            # head-dim vectors (2 bits/value) plus the frozen per-head
            # codebooks, which blocks_for_bytes charges off the top
            BenchCase("paged-fused", "fp32", model, params, mb, max_len,
                      kv_bits="vq2", pool_bytes=budget,
                      page_size=page_size),
            # the vq_fused cell runs IMMEDIATELY after its vq dequant
            # baseline: the fused-over-dequant ratio is paired per-pass
            BenchCase("paged-fused", "vq", model, qparams, mb, max_len,
                      page_size=page_size),
            BenchCase("paged-fused", "vq_fused", model, qparams, mb,
                      max_len, page_size=page_size, vq_impl="fused"),
            BenchCase("legacy", "fp32", model, params, mb, max_len),
        ]
        for i in range(passes + 1):  # pass 0 is the cold/compile pass
            for c in cases:
                c.one_pass(prompts, max_new, rid0=1000 * i)
        for c in cases:
            all_cases[(mb, c.kind, c.wtag, c.kv_bits)] = c
            r = c.summary()
            results.append(r)
            pages = (f" pages={r['allocatable_pages']}"
                     if r["allocatable_pages"] is not None else "")
            ttft = (f"{r['ttft_mean_s']:.3f}s"
                    if r["ttft_mean_s"] is not None else "n/a")
            dev = (f" dev={r['decode_device_frac']:.0%}"
                   if r["decode_device_frac"] is not None else "")
            print(f"  {r['engine']:11s} {r['weights']:10s} "
                  f"kv{r['kv_bits']!s:<3} max_batch={mb}: "
                  f"{r['tokens_per_s']:8.1f} tok/s (median)  "
                  f"ttft_mean={ttft}  "
                  f"cold={r['cold_wall_s']:.1f}s{pages}{dev}", flush=True)

    print("== prefix_warm: 512-token shared prefix, warm vs cold TTFT ==",
          flush=True)
    prefix_cell = bench_prefix_warm(model, params, passes, cfg.vocab_size)
    print(f"  prefix_warm: cold ttft "
          f"{prefix_cell['ttft_cold_median_s']:.3f}s -> warm "
          f"{prefix_cell['ttft_warm_median_s']:.3f}s "
          f"(paired median ratio "
          f"{prefix_cell['ttft_warm_over_cold_median']}, "
          f"{prefix_cell['prefix_evictions']} LRU evictions)", flush=True)

    def pick(engine, mb, wtag="fp32", kv=16):
        return next(r for r in results if r["engine"] == engine
                    and r["max_batch"] == mb and r["weights"] == wtag
                    and r["kv_bits"] == kv)

    def case_by(mb, kv):
        return all_cases[(mb, "paged-fused", "fp32", kv)]

    fused_b1 = round(pick("paged-fused", 1)["tokens_per_s"]
                     / pick("legacy", 1)["tokens_per_s"], 3)
    fused_b8 = round(pick("paged-fused", 8)["tokens_per_s"]
                     / pick("legacy", 8)["tokens_per_s"], 3)
    # quantized-cache cells: page headroom at FIXED pool bytes, and the
    # decode-throughput cost of paying for on-the-fly dequant. The tok/s
    # ratio is the median of PAIRED per-pass wall ratios (pass i of both
    # cells runs back to back), so minute-scale ambient slowdowns on a
    # shared bench host cancel instead of landing on whichever cell they
    # overlapped — unpaired medians swung this ratio by 40% run to run.
    kv8_pages_b8 = round(pick("paged-fused", 8, kv=8)["allocatable_pages"]
                         / pick("paged-fused", 8)["allocatable_pages"], 3)
    kv4_pages_b8 = round(pick("paged-fused", 8, kv=4)["allocatable_pages"]
                         / pick("paged-fused", 8)["allocatable_pages"], 3)
    kv_vq2_pages = {
        mb: round(pick("paged-fused", mb, kv="vq2")["allocatable_pages"]
                  / pick("paged-fused", mb)["allocatable_pages"], 3)
        for mb in (1, 8)}

    def paired_walls_ratio(case_base, case_new):
        """Median of paired per-pass wall ratios: > 1 means ``case_new``
        decodes faster than ``case_base`` (pass i of both ran back to
        back, so ambient host noise cancels within each pair)."""
        ratios = sorted(b / q for b, q in zip(case_base.walls,
                                              case_new.walls))
        return round(ratios[len(ratios) // 2], 3)

    def paired_tps_ratio(mb, kv):
        return paired_walls_ratio(case_by(mb, 16), case_by(mb, kv))

    kv8_tps_b1 = paired_tps_ratio(1, 8)
    kv8_tps_b8 = paired_tps_ratio(8, 8)
    kv_vq2_tps = {mb: paired_tps_ratio(mb, "vq2") for mb in (1, 8)}

    # vq2 fidelity: one anchored logit trace (the cells above only pin
    # throughput/pages; this pins that the extra pages aren't bought
    # with a broken read path)
    kv_vq2_drift = round(bench_vq2_drift(model, params, max_len=max_len,
                                         page_size=page_size), 4)
    print(f"  kv_vq2 max RMS logit drift vs fp32 cache = {kv_vq2_drift} "
          f"(teacher-forced anchor path; bar < 0.5)", flush=True)

    # observability overhead: telemetry-on over telemetry-off, paired
    # per-pass (the cells run back to back). ~1.0 means the obs/
    # instrumentation is free at decode granularity; < 0.98 would blow
    # the 2% budget the subsystem is held to.
    obs_overhead = {
        mb: paired_walls_ratio(
            all_cases[(mb, "paged-fused", "fp32-noobs", 16)],
            all_cases[(mb, "paged-fused", "fp32", 16)])
        for mb in (1, 8)}

    # fused VQ serving path: paired ratios vs the dequant baseline (the
    # 0.65x decode gap this path exists to close) and vs fp32 weights,
    # plus the HBM payload the packed weights stream per decode tick vs
    # the dense fp32 weights they replace (every weight is read once per
    # token at decode, so bytes-per-tick is the roofline quantity)
    from repro.core import vq_linear as vql_mod

    vq_fused_over_dequant = {
        mb: paired_walls_ratio(all_cases[(mb, "paged-fused", "vq", 16)],
                               all_cases[(mb, "paged-fused", "vq_fused",
                                          16)])
        for mb in (1, 8)}
    vq_fused_over_fp32 = {
        mb: paired_walls_ratio(all_cases[(mb, "paged-fused", "fp32", 16)],
                               all_cases[(mb, "paged-fused", "vq_fused",
                                          16)])
        for mb in (1, 8)}
    prepped = vql_mod.prepare_fused_tree(qparams)
    vq_leaves = [l for l in jax.tree.leaves(prepped,
                                            is_leaf=vql_mod._is_vq_leaf)
                 if vql_mod._is_vq_leaf(l)]
    vq_payload = sum(l.payload_bytes() for l in vq_leaves)
    dense_bytes = sum(  # leading stack dims (experts/layers) multiply
        int(np.prod(l.words.shape[:-2])) * l.r * l.c * 4
        for l in vq_leaves)
    report = {
        "bench": "serve_throughput",
        "config": cfg.name + ("-smoke" if args.smoke else ""),
        "workload": {"n_requests": n_req, "max_new_tokens": max_new,
                     "max_len": max_len, "prompt_lens": lens},
        "results": results,
        "prefix_warm": prefix_cell,
        "prefix_warm_ttft_over_cold":
            prefix_cell["ttft_warm_over_cold_median"],
        "paged_over_legacy_tokens_per_s_b8":
            round(pick("paged", 8)["tokens_per_s"]
                  / pick("legacy", 8)["tokens_per_s"], 3),
        "paged_fused_over_legacy_tokens_per_s_b1": fused_b1,
        "paged_fused_over_legacy_tokens_per_s_b8": fused_b8,
        "kv8_pages_over_fp32_fixed_pool_bytes_b8": kv8_pages_b8,
        "kv4_pages_over_fp32_fixed_pool_bytes_b8": kv4_pages_b8,
        "kv_vq2_pages_over_fp32_fixed_pool_bytes_b1": kv_vq2_pages[1],
        "kv_vq2_pages_over_fp32_fixed_pool_bytes_b8": kv_vq2_pages[8],
        "kv8_fused_tokens_per_s_over_fp32_b1": kv8_tps_b1,
        "kv8_fused_tokens_per_s_over_fp32_b8": kv8_tps_b8,
        "kv_vq2_fused_tokens_per_s_over_fp32_b1": kv_vq2_tps[1],
        "kv_vq2_fused_tokens_per_s_over_fp32_b8": kv_vq2_tps[8],
        # per-step RMS logit drift across the vocab, max over decode
        # steps, teacher-forced on the fp32 anchor's greedy path
        "kv_vq2_max_logit_drift_vs_fp32": kv_vq2_drift,
        "obs_overhead_tokens_per_s_on_over_off_b1": obs_overhead[1],
        "obs_overhead_tokens_per_s_on_over_off_b8": obs_overhead[8],
        "vq_fused_over_vq_dequant_tokens_per_s_b1": vq_fused_over_dequant[1],
        "vq_fused_over_vq_dequant_tokens_per_s_b8": vq_fused_over_dequant[8],
        "vq_fused_tokens_per_s_over_fp32_b1": vq_fused_over_fp32[1],
        "vq_fused_tokens_per_s_over_fp32_b8": vq_fused_over_fp32[8],
        "vq_payload_bytes": vq_payload,
        "dense_weight_bytes": dense_bytes,
        "hbm_bytes_saved_per_decode_tick": dense_bytes - vq_payload,
    }
    with open(args.out, "w") as f:
        json.dump(report, f, indent=2)
    print(f"wrote {os.path.abspath(args.out)}; fused/legacy tok/s "
          f"@B1 = {fused_b1}, @B8 = {fused_b8}; kv8 pages/fp32 @B8 = "
          f"{kv8_pages_b8} at {kv8_tps_b1}/{kv8_tps_b8} rel tok/s @B1/B8; "
          f"kv_vq2 pages/fp32 @B1/B8 = {kv_vq2_pages[1]}/{kv_vq2_pages[8]} "
          f"at drift {kv_vq2_drift}; "
          f"vq fused/dequant tok/s @B1 = {vq_fused_over_dequant[1]}, "
          f"@B8 = {vq_fused_over_dequant[8]}; obs on/off tok/s "
          f"@B1 = {obs_overhead[1]}, @B8 = {obs_overhead[8]}; "
          f"prefix warm/cold ttft = "
          f"{prefix_cell['ttft_warm_over_cold_median']}")


if __name__ == "__main__":
    main()
