"""Fault tolerance: checkpoint/restart supervision for long training runs.

``supervise`` wraps a step loop: on any step failure it restores the latest
checkpoint, optionally re-plans the mesh (elastic), and resumes. Heartbeats
are written per step so an external watchdog (k8s liveness / SLURM prolog)
can detect a hung job and recycle the pod — on thousands of nodes, crash
loops are routine and the recovery path must be the *default* path.
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Callable

from repro.checkpoint.checkpointer import Checkpointer
from repro.runtime.straggler import StragglerMonitor


@dataclasses.dataclass
class SuperviseResult:
    final_state: Any
    steps_done: int
    restarts: int
    straggler_flags: int


def write_heartbeat(path: str, step: int, extra: dict | None = None):
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump({"step": step, "time": time.time(), **(extra or {})}, f)
    os.replace(tmp, path)


def supervise(
    *,
    state: Any,
    step_fn: Callable[[Any, int], Any],       # (state, step) -> state
    ckpt: Checkpointer,
    total_steps: int,
    checkpoint_every: int = 50,
    max_restarts: int = 3,
    heartbeat_path: str | None = None,
    on_restore: Callable[[Any], Any] | None = None,
) -> SuperviseResult:
    """Run step_fn to total_steps with checkpoint/restart on failure."""
    monitor = StragglerMonitor()
    restarts = 0
    start = ckpt.latest_step() or 0
    if start > 0:
        state, _ = ckpt.restore(state)
        if on_restore:
            state = on_restore(state)
    step = start
    while step < total_steps:
        try:
            t0 = time.perf_counter()
            state = step_fn(state, step)
            monitor.record(step, time.perf_counter() - t0)
            step += 1
            if heartbeat_path:
                write_heartbeat(heartbeat_path, step)
            if step % checkpoint_every == 0 or step == total_steps:
                ckpt.save(step, state)
        except Exception:
            restarts += 1
            if restarts > max_restarts:
                raise
            restore_step = ckpt.latest_step()
            if restore_step is None:
                raise
            state, _ = ckpt.restore(state, step=restore_step)
            if on_restore:
                state = on_restore(state)
            step = restore_step
    ckpt.wait()
    return SuperviseResult(state, step, restarts, len(monitor.flagged))
