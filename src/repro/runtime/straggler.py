"""Straggler detection for the training loop.

At >256 hosts, slow hosts (thermal throttling, failing HBM, noisy
neighbours) stretch every synchronous step. The monitor keeps a rolling
window of per-step (and per-host, when the launcher reports them) timings
and flags outliers; the launcher quarantines flagged hosts at the next
checkpoint boundary and triggers an elastic re-mesh (runtime/elastic.py).
"""
from __future__ import annotations

import collections
import dataclasses
import statistics
from typing import Deque


@dataclasses.dataclass
class StragglerReport:
    step: int
    duration: float
    median: float
    threshold: float
    is_straggler: bool
    host: int | None = None


class StragglerMonitor:
    def __init__(self, window: int = 64, k_mad: float = 5.0,
                 min_samples: int = 8):
        self.window: Deque[float] = collections.deque(maxlen=window)
        self.k_mad = k_mad
        self.min_samples = min_samples
        self.flagged: list[StragglerReport] = []
        self.host_counts: dict[int, int] = collections.defaultdict(int)

    def record(self, step: int, duration: float,
               host: int | None = None) -> StragglerReport:
        if len(self.window) >= self.min_samples:
            med = statistics.median(self.window)
            mad = statistics.median(abs(x - med) for x in self.window) or 1e-9
            thr = med + self.k_mad * mad
        else:
            med, thr = duration, float("inf")
        rep = StragglerReport(step, duration, med, thr, duration > thr, host)
        if rep.is_straggler:
            self.flagged.append(rep)
            if host is not None:
                self.host_counts[host] += 1
        else:
            self.window.append(duration)
        return rep

    def quarantine_candidates(self, repeat_threshold: int = 3) -> list[int]:
        """Hosts flagged repeatedly -> candidates for removal at next ckpt."""
        return [h for h, c in self.host_counts.items() if c >= repeat_threshold]
