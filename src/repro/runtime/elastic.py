"""Elastic re-meshing: recompute the device mesh after capacity changes.

Policy: keep the model (TP) axis fixed at the largest power-of-two that the
architecture's head/ffn dims divide (TP changes invalidate too much - layout,
collectives, kernel tuning), absorb capacity changes into the data axis, and
drop remainder devices into a hot-spare pool. Parameters re-enter through
``reshard`` (device_put with the new NamedSharding) after a checkpoint
restore — the checkpoint layout is mesh-agnostic (full logical arrays).
"""
from __future__ import annotations

import dataclasses

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class MeshPlan:
    pod: int
    data: int
    model: int
    spares: int

    @property
    def used(self) -> int:
        return self.pod * self.data * self.model


def plan_mesh(n_devices: int, *, model_parallel: int = 16,
              pods: int = 1) -> MeshPlan:
    """Largest (pod, data, model) grid fitting n_devices with fixed TP."""
    assert n_devices >= model_parallel * pods
    per_pod = n_devices // pods
    data = per_pod // model_parallel
    used = pods * data * model_parallel
    return MeshPlan(pod=pods, data=data, model=model_parallel,
                    spares=n_devices - used)


def degrade_plan(plan: MeshPlan, lost_devices: int) -> MeshPlan:
    """Re-plan after losing devices; spares absorb losses first."""
    remaining = plan.used + plan.spares - lost_devices
    return plan_mesh(remaining, model_parallel=plan.model, pods=plan.pod)


def build_mesh(plan: MeshPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    used = devices[: plan.used]
    import numpy as np
    arr = np.array(used).reshape(plan.pod, plan.data, plan.model)
    if plan.pod == 1:
        return Mesh(arr[0], ("data", "model"))
    return Mesh(arr, ("pod", "data", "model"))


def reshard(tree, specs, mesh: Mesh):
    """Move a (restored) tree onto a new mesh.

    Specs are sanitized against the new mesh first: any dim a degraded mesh
    no longer divides falls back to replication rather than failing the
    restart (the same portability rule as models/common.sanitize_specs).
    """
    from repro.models.common import sanitize_specs

    specs = sanitize_specs(tree, specs, mesh)

    def put(x, spec):
        return jax.device_put(x, NamedSharding(mesh, spec))

    return jax.tree.map(put, tree, specs)
