"""runtime."""
