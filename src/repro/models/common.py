"""Shared neural-net primitives: norms, RoPE, dense init, partition helpers.

Parameter convention: plain nested dicts of jax arrays. For every init
function there is a parallel ``*_specs`` function returning the same tree of
``jax.sharding.PartitionSpec`` leaves; tests assert the treedefs match for
every architecture, and ``sanitize_specs`` downgrades any axis that does not
divide the mesh (e.g. 8 GQA kv-head dims on a 16-way model axis) to
replicated, so every config compiles on every mesh.
"""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

from repro.obs import dispatch as obs_dispatch

Params = dict
DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32}

# Trace-time counts of which weight form each model matmul site dispatched
# on (dense array vs engine-prepped FusedVQLinear) — same contract as the
# flash/paged/vq counters: bumps happen at trace time, so a jitted serving
# tick contributes once per matmul site it baked, and a silent densify of
# a leaf that should have stayed fused shows up as a count regression.
_MATMUL_DISPATCH = obs_dispatch.register_dispatch(
    "matmul", ("dense", "fused_vq", "expert_dense", "expert_fused_vq"))


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, *, scale: float | None = None,
               dtype=jnp.float32) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in) if scale is None else scale
    return (jax.random.normal(key, (d_in, d_out)) * scale).astype(dtype)


def embed_init(key, vocab: int, d: int, dtype=jnp.float32) -> jax.Array:
    return (jax.random.normal(key, (vocab, d)) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# weight application: dense arrays or fused VQ leaves
# ---------------------------------------------------------------------------

def matmul(x: jax.Array, w) -> jax.Array:
    """``x @ w`` where ``w`` is a dense (in, out) kernel OR an engine-prepped
    ``core/vq_linear.FusedVQLinear`` (fused VQ-dequant matmul; the dense
    weight is never materialized in HBM on the Pallas path). Every model
    matmul site routes through here so a single prep pass at engine load
    switches the whole zoo onto the fused serving path."""
    from repro.core import vq_linear as vql_mod

    if isinstance(w, vql_mod.FusedVQLinear):
        _MATMUL_DISPATCH["fused_vq"] += 1
        return vql_mod.fused_matmul(x, w).astype(x.dtype)
    _MATMUL_DISPATCH["dense"] += 1
    return x @ w


def expert_matmul(x: jax.Array, w) -> jax.Array:
    """Per-expert matmul: einsum('...ecd,edf->...ecf') for dense (E, d, f)
    stacks, or a stacked FusedVQLinear (leading E on every leaf) mapped
    expert-by-expert through the fused path — routed experts skip the
    per-expert dequant round-trip."""
    from repro.core import vq_linear as vql_mod

    if not isinstance(w, vql_mod.FusedVQLinear):
        _MATMUL_DISPATCH["expert_dense"] += 1
        if x.ndim == 3:
            return jnp.einsum("ecd,edf->ecf", x, w.astype(x.dtype))
        return jnp.einsum("becd,edf->becf", x, w.astype(x.dtype))

    _MATMUL_DISPATCH["expert_fused_vq"] += 1

    def one(args):
        xe, we = args
        return vql_mod.fused_matmul(xe, we)

    if x.ndim == 3:  # (E, C, D)
        y = jax.lax.map(one, (x.astype(jnp.float32), w))
        return y.astype(x.dtype)
    B, E, C, D = x.shape  # (B, E, C, D)
    xt = x.transpose(1, 0, 2, 3).reshape(E, B * C, D)
    y = jax.lax.map(one, (xt.astype(jnp.float32), w))
    return y.reshape(E, B, C, -1).transpose(1, 0, 2, 3).astype(x.dtype)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return ((x * jax.lax.rsqrt(var + eps)) * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jax.Array, scale: jax.Array, bias: jax.Array,
              eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------

def rope_freqs(hd: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=jnp.float32) / hd))


def position_ids(pos, batch: int, seq: int) -> jax.Array:
    """(B, S) absolute positions from a scalar or per-row (B,) offset.

    The serving engine decodes with a *per-slot* position vector (each
    continuous-batching slot is at its own depth in its sequence); training
    and prefill paths pass the usual scalar offset.
    """
    p = jnp.asarray(pos)
    if p.ndim == 0:
        p = jnp.broadcast_to(p[None], (batch,))
    return p[:, None] + jnp.arange(seq)[None, :]


def apply_rope(x: jax.Array, pos: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, hd); pos: broadcastable to (..., S)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = pos[..., :, None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    cos = jnp.cos(ang)[..., :, None, :]
    sin = jnp.sin(ang)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations
# ---------------------------------------------------------------------------

def act_fn(name: str):
    if name in ("swiglu", "geglu"):
        # gate nonlinearity used by gated MLPs
        return jax.nn.silu if name == "swiglu" else jax.nn.gelu
    if name == "relu2":
        return lambda x: jnp.square(jax.nn.relu(x))
    if name == "gelu":
        return jax.nn.gelu
    raise ValueError(name)


def is_gated(name: str) -> bool:
    return name in ("swiglu", "geglu")


# ---------------------------------------------------------------------------
# sharding helpers
# ---------------------------------------------------------------------------

def sanitize_specs(shapes: Any, specs: Any, mesh: Mesh) -> Any:
    """Drop mesh axes from any spec dim that does not divide the dim size.

    Production note: this is how the framework stays mesh-portable — GQA
    kv-projections, odd vocab sizes, small expert counts etc. silently fall
    back to replication on meshes they do not divide.
    """
    axis_size = dict(zip(mesh.axis_names, mesh.devices.shape))

    def fix(shape, spec):
        dims = shape.shape if hasattr(shape, "shape") else shape
        if spec is None:
            return P()
        out = []
        for i, names in enumerate(spec):
            if names is None:
                out.append(None)
                continue
            tup = names if isinstance(names, tuple) else (names,)
            # drop axes absent from this mesh (e.g. 'pod' on single-pod)
            tup = tuple(n for n in tup if n in axis_size)
            if not tup:
                out.append(None)
                continue
            total = 1
            for n in tup:
                total *= axis_size[n]
            if i < len(dims) and dims[i] % total == 0:
                out.append(tup if len(tup) > 1 else tup[0])
            else:
                out.append(None)
        return P(*out)

    return jax.tree.map(fix, shapes, specs)


def tree_size_bytes(tree: Any) -> int:
    return sum(
        x.size * x.dtype.itemsize for x in jax.tree.leaves(tree)
        if hasattr(x, "size")
    )
