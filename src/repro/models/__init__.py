"""Model assemblies: families, attention/paged KV cache, layer stacks."""
