"""Phi-3-vision style VLM: transformer LM backbone + stubbed CLIP frontend.

Per the assignment, the vision tower is a STUB: ``input_specs`` provides
precomputed patch embeddings (B, n_image_tokens, d_model) which are simply
prepended to the text embedding sequence (the projector is folded into the
stub). Decode steps operate on the text tail with the usual KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer


def init_params(cfg: ModelConfig, key):
    return transformer.init_params(cfg, key)


def param_specs(cfg: ModelConfig):
    return transformer.param_specs(cfg)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               paged=None):
    return transformer.init_cache(cfg, batch, max_len, dtype, paged)


def cache_specs(cfg: ModelConfig):
    return transformer.cache_specs(cfg)


def forward(params, cfg: ModelConfig, tokens, *, patches=None, pos=0,
            cache=None, remat: bool = True, **kw):
    """tokens: (B, S_text); patches: (B, n_image_tokens, D) or None (decode).

    Returns logits over the full (image + text) sequence at prefill; callers
    slice off the image positions for loss/sampling.
    """
    return transformer.forward(
        params, cfg, tokens, pos=pos, cache=cache, extra_embeds=patches,
        remat=remat, **kw)
