"""Whisper-style encoder-decoder backbone.

The conv/mel frontend is a STUB per the assignment: ``input_specs`` provides
precomputed frame embeddings (B, encoder_seq, d_model). Learned absolute
positional embeddings (whisper uses sinusoidal-enc/learned-dec; we use
learned for both — backbone-equivalent), GELU MLPs, biased QKV, pre-norm.

Cache layout: per-decoder-layer self-attention KV (stacked) + cross K/V
computed once from the encoder memory at prefill.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, common as cm, mlp


class EncDecCache(NamedTuple):
    self_kv: attention.KVCache      # stacked (L, B, S, KV, hd)
    cross_k: jax.Array              # (L, B, S_enc, KV, hd)
    cross_v: jax.Array


def _enc_block_init(key, cfg, dtype):
    k1, k2 = jax.random.split(key)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "attn": attention.init(k1, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "ffn": mlp.init(k2, cfg, dtype),
    }


def _dec_block_init(key, cfg, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "norm1": jnp.ones((cfg.d_model,), dtype),
        "self_attn": attention.init(k1, cfg, dtype),
        "norm_x": jnp.ones((cfg.d_model,), dtype),
        "cross_attn": attention.init(k2, cfg, dtype),
        "norm2": jnp.ones((cfg.d_model,), dtype),
        "ffn": mlp.init(k3, cfg, dtype),
    }


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cm.DTYPES[cfg.dtype]
    ks = jax.random.split(key, 6)
    L_enc, L_dec = cfg.n_encoder_layers, cfg.n_layers
    return {
        "embed": cm.embed_init(ks[0], cfg.padded_vocab, cfg.d_model, dtype),
        "pos_enc": cm.embed_init(ks[1], cfg.encoder_seq, cfg.d_model, dtype),
        "pos_dec": cm.embed_init(ks[2], cfg.max_seq_len, cfg.d_model, dtype),
        "enc_layers": jax.vmap(lambda k: _enc_block_init(k, cfg, dtype))(
            jax.random.split(ks[3], L_enc)),
        "dec_layers": jax.vmap(lambda k: _dec_block_init(k, cfg, dtype))(
            jax.random.split(ks[4], L_dec)),
        "enc_norm": jnp.ones((cfg.d_model,), dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }  # lm head tied with embed (whisper)


def param_specs(cfg: ModelConfig) -> dict:
    enc_one = {"norm1": P(None), "attn": attention.specs(cfg),
               "norm2": P(None), "ffn": mlp.specs(cfg)}
    dec_one = {"norm1": P(None), "self_attn": attention.specs(cfg),
               "norm_x": P(None), "cross_attn": attention.specs(cfg),
               "norm2": P(None), "ffn": mlp.specs(cfg)}
    stack = lambda t: jax.tree.map(lambda s: P(None, *s), t,
                                   is_leaf=lambda x: isinstance(x, P))
    return {
        "embed": P("model", "data"),
        "pos_enc": P(None, "data"),
        "pos_dec": P(None, "data"),
        "enc_layers": stack(enc_one),
        "dec_layers": stack(dec_one),
        "enc_norm": P(None),
        "final_norm": P(None),
    }


def embed_frames(params, cfg: ModelConfig, frames: jax.Array):
    """Add learned positional embeddings to the (stub) frame embeddings."""
    return frames + params["pos_enc"][None, : frames.shape[1]].astype(
        frames.dtype)


def enc_block_apply(layer_p, cfg: ModelConfig, h):
    """One encoder block (bidirectional attention + MLP) on (B, S_enc, D)."""
    a, _ = attention.apply(
        layer_p["attn"], cfg, cm.rmsnorm(h, layer_p["norm1"], cfg.norm_eps),
        causal=False, use_rope=False)
    h = h + a
    f = mlp.apply(layer_p["ffn"], cfg,
                  cm.rmsnorm(h, layer_p["norm2"], cfg.norm_eps))
    return h + f


def encode(params, cfg: ModelConfig, frames: jax.Array, remat=True):
    """frames: (B, S_enc, D) precomputed embeddings (conv frontend stub)."""
    x = embed_frames(params, cfg, frames)

    def body(h, layer_p):
        from repro.core import vq_linear as vql_mod
        layer_p = vql_mod.dequant_tree(layer_p, cm.DTYPES[cfg.dtype])
        return enc_block_apply(layer_p, cfg, h), None

    if isinstance(params["enc_layers"], list):
        # heterogeneous encoder stack (mixed quantization recipe)
        for layer_p in params["enc_layers"]:
            x, _ = (jax.checkpoint(body) if remat else body)(x, layer_p)
    else:
        body_fn = jax.checkpoint(body) if remat else body
        x, _ = jax.lax.scan(body_fn, x, params["enc_layers"])
    return cm.rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               paged=None):
    L = cfg.n_layers
    # self-attention KV pages when serving; the cross K/V are fixed-size
    # per-slot encoder projections (like recurrent state) and stay resident
    kv1 = (attention.init_paged_cache(cfg, batch, max_len, paged, dtype)
           if paged is not None
           else attention.init_cache(cfg, batch, max_len, dtype))
    stack = lambda x: jnp.broadcast_to(x[None], (L, *x.shape))
    cross_shape = (L, batch, cfg.encoder_seq, cfg.n_kv_heads, cfg.hd)
    return EncDecCache(
        self_kv=jax.tree.map(stack, kv1),
        cross_k=jnp.zeros(cross_shape, dtype),
        cross_v=jnp.zeros(cross_shape, dtype),
    )


def cache_specs(cfg: ModelConfig):
    kv = attention.KVCache(
        k=P(None, ("pod", "data"), None, "model", None),
        v=P(None, ("pod", "data"), None, "model", None))
    cross = P(None, ("pod", "data"), None, "model", None)
    return EncDecCache(self_kv=kv, cross_k=cross, cross_v=cross)


def _cross_kv(layer_p, cfg, memory):
    B, S, _ = memory.shape
    KV, hd = cfg.n_kv_heads, cfg.hd
    k = cm.matmul(memory, layer_p["wk"]).reshape(B, S, KV, hd)
    v = cm.matmul(memory, layer_p["wv"]).reshape(B, S, KV, hd)
    if cfg.qkv_bias:
        k = k + layer_p["bk"].reshape(KV, hd)
        v = v + layer_p["bv"].reshape(KV, hd)
    return k, v


def _cross_attend(layer_p, cfg, x, ck, cv):
    B, S, D = x.shape
    H, hd = cfg.n_heads, cfg.hd
    q = cm.matmul(x, layer_p["wq"]).reshape(B, S, H, hd)
    if cfg.qkv_bias:
        q = q + layer_p["bq"].reshape(H, hd)
    msk = jnp.ones((1, 1, 1, S, ck.shape[1]), bool)
    o = attention._plain_attention(q, ck, cv, msk)
    return cm.matmul(o.reshape(B, S, H * hd), layer_p["wo"]).astype(x.dtype)


def dec_block_apply(layer_p, cfg: ModelConfig, h, memory):
    """One decoder block (causal self-attn, cross-attn over ``memory``,
    MLP) on (B, S, D) — the cache-free prefill/train path, used by the
    audio-family quantization adapter (core/adapters/encdec.py)."""
    a, _ = attention.apply(
        layer_p["self_attn"], cfg,
        cm.rmsnorm(h, layer_p["norm1"], cfg.norm_eps), use_rope=False)
    h = h + a
    ck, cv = _cross_kv(layer_p["cross_attn"], cfg, memory)
    c = _cross_attend(layer_p["cross_attn"], cfg,
                      cm.rmsnorm(h, layer_p["norm_x"], cfg.norm_eps),
                      ck.astype(h.dtype), cv.astype(h.dtype))
    h = h + c
    f = mlp.apply(layer_p["ffn"], cfg,
                  cm.rmsnorm(h, layer_p["norm2"], cfg.norm_eps))
    return h + f


def forward(params, cfg: ModelConfig, tokens, *, frames=None, memory=None,
            pos=0, cache=None, remat: bool = True, last_only: bool = False,
            paged_impl: str | None = None,
            vq_matmul_impl: str | None = None):
    """Decoder forward. Provide ``frames`` (prefill/train; encoder runs) or a
    cache whose cross K/V were filled by a previous prefill."""
    from repro.core import vq_linear as vql_mod
    if vq_matmul_impl is not None:
        params = vql_mod.retag_fused(params, vq_matmul_impl)
    assert frames is not None or cache is not None
    top = {k: v for k, v in params.items()
           if k not in ("enc_layers", "dec_layers")}
    params = {**params, **vql_mod.dequant_tree(top, cm.DTYPES[cfg.dtype])}
    if frames is not None:
        memory = encode(params, cfg, frames, remat)

    B, S = tokens.shape
    x = params["embed"][tokens]
    pos_ids = cm.position_ids(pos, B, S)  # (B, S): pos may be per-slot
    x = x + params["pos_dec"][pos_ids].astype(x.dtype)
    from repro.models.transformer import _axes_size, _dp_axes
    dp = _dp_axes()
    if dp and B % _axes_size(dp) == 0:  # see hybrid.py — avoid replication
        x = jax.lax.with_sharding_constraint(x, P(dp, None, None))
        if memory is not None:
            memory = jax.lax.with_sharding_constraint(
                memory, P(dp, None, None))

    fill_cross = cache is not None and memory is not None

    def body(h, xs):
        from repro.core import vq_linear as vql_mod
        layer_p, self_c, ck_in, cv_in = xs
        layer_p = vql_mod.dequant_tree(layer_p, cm.DTYPES[cfg.dtype])
        a, new_kv = attention.apply(
            layer_p["self_attn"], cfg,
            cm.rmsnorm(h, layer_p["norm1"], cfg.norm_eps),
            pos=pos, cache=self_c, use_rope=False, paged_impl=paged_impl)
        h = h + a
        if memory is not None:
            ck, cv = _cross_kv(layer_p["cross_attn"], cfg, memory)
        else:
            ck, cv = ck_in, cv_in
        c = _cross_attend(layer_p["cross_attn"], cfg,
                          cm.rmsnorm(h, layer_p["norm_x"], cfg.norm_eps),
                          ck.astype(h.dtype), cv.astype(h.dtype))
        h = h + c
        f = mlp.apply(layer_p["ffn"], cfg,
                      cm.rmsnorm(h, layer_p["norm2"], cfg.norm_eps))
        new_ck = ck if fill_cross else ck_in
        new_cv = cv if fill_cross else cv_in
        return h + f, (new_kv, new_ck, new_cv)

    body_fn = jax.checkpoint(body) if remat else body
    if cache is not None:
        xs = (params["dec_layers"], cache.self_kv, cache.cross_k, cache.cross_v)
    else:
        L = cfg.n_layers
        dummy = jnp.zeros((L, B, 1, cfg.n_kv_heads, cfg.hd), x.dtype)
        xs = (params["dec_layers"], None, dummy, dummy)
    if isinstance(params["dec_layers"], list):
        # heterogeneous decoder stack (mixed quantization recipe): loop
        # layers, slicing the stacked caches and restacking the outputs so
        # the cache layout matches the scan path bit-for-bit
        layers, self_kv, ck, cv = xs
        outs = []
        for i, layer_p in enumerate(layers):
            xs_i = (layer_p,
                    None if self_kv is None
                    else jax.tree.map(lambda a: a[i], self_kv),
                    ck[i], cv[i])
            x, out_i = body_fn(x, xs_i)
            outs.append(out_i)
        new_kv, new_ck, new_cv = jax.tree.map(
            lambda *a: jnp.stack(a), *outs)
    else:
        x, (new_kv, new_ck, new_cv) = jax.lax.scan(body_fn, x, xs)

    if last_only:
        x = x[:, -1:]
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = (x @ params["embed"].T).astype(jnp.float32)
    new_cache = (EncDecCache(new_kv, new_ck, new_cv)
                 if cache is not None else None)
    return logits, new_cache, jnp.zeros((), jnp.float32)
