"""GQA attention: init/specs/apply, flash-style chunked softmax, KV cache.

Layout convention: activations (B, S, D); q/k/v (B, S, H, hd).
The chunked path (two-level scan with online softmax) keeps the score tile
at (B, KV, G, Tq, Ts) so 32k-token prefill fits VMEM-scale working sets —
the pure-JAX analogue of flash attention; a Pallas version is a §Perf item.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.obs import dispatch as obs_dispatch

NEG_INF = -1e30

# Flash-attention backend: "xla" (portable two-level scan, the default and
# the dry-run path) or "pallas" (kernels/flash_attention.py — the TPU fast
# path; runs in interpret mode off-TPU). Set via set_flash_impl().
# ``counts`` records how often each impl was *dispatched* (trace-time for
# jitted callers) — the regression tests pin dispatch decisions against it
# through the obs.dispatch API (snapshot_dispatch_counters /
# reset_dispatch_counters); the registered dict here IS the live counter,
# so the bump sites stay one plain increment on the trace path.
_FLASH_IMPL = {"impl": "xla",
               "counts": obs_dispatch.register_dispatch(
                   "flash", ("xla", "pallas"))}


def set_flash_impl(impl: str):
    assert impl in ("xla", "pallas")
    _FLASH_IMPL["impl"] = impl


# Paged decode-attention backend for _paged_apply's S == 1 path:
#   "gather" — scatter then attend over the page-table-gathered logical
#              view (portable XLA; the pre-fused path and the baseline)
#   "xla"    — kernels/ref.paged_attention_ref via kernels/ops (the oracle;
#              same math routed through the fused dispatch boundary)
#   "pallas" — kernels/paged_attention.py fused TPU kernel (in-kernel page
#              gather; interpret mode off-TPU — tests only, not a perf path)
# The serving engine threads its choice explicitly (apply(paged_impl=...),
# captured per-engine by serve_step's jitted closures; prefill is pinned to
# "gather" there even for width-1 chunks). This module global is only the
# default for callers that don't pass one — it is read at trace time.
_PAGED_IMPL = {"impl": "gather",
               "counts": obs_dispatch.register_dispatch(
                   "paged", ("gather", "xla", "pallas"))}


def set_paged_impl(impl: str):
    assert impl in ("gather", "xla", "pallas")
    _PAGED_IMPL["impl"] = impl


def init(key, cfg: ModelConfig, dtype=jnp.float32, d_in: int | None = None):
    D = d_in or cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    p = {
        "wq": cm.dense_init(ks[0], D, H * hd, dtype=dtype),
        "wk": cm.dense_init(ks[1], D, KV * hd, dtype=dtype),
        "wv": cm.dense_init(ks[2], D, KV * hd, dtype=dtype),
        "wo": cm.dense_init(ks[3], H * hd, cfg.d_model, dtype=dtype),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((H * hd,), dtype)
        p["bk"] = jnp.zeros((KV * hd,), dtype)
        p["bv"] = jnp.zeros((KV * hd,), dtype)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def specs(cfg: ModelConfig):
    s = {
        "wq": P("data", "model"),
        "wk": P("data", "model"),
        "wv": P("data", "model"),
        "wo": P("model", "data"),
    }
    if cfg.qkv_bias:
        s.update({"bq": P("model"), "bk": P("model"), "bv": P("model")})
    if cfg.qk_norm:
        s.update({"q_norm": P(None), "k_norm": P(None)})
    return s


class KVCache(NamedTuple):
    k: jax.Array  # (B, S_max, KV, hd)
    v: jax.Array


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16) -> KVCache:
    shape = (batch, max_len, cfg.n_kv_heads, cfg.hd)
    return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype))


# ---------------------------------------------------------------------------
# paged KV cache (serving)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class KVQuantSpec:
    """Storage format of the paged KV pool.

    ``bits=16`` is passthrough: pages hold the cache dtype verbatim (the
    default; bf16/fp32 depending on the engine). ``bits`` in {8, 4} stores
    int8 code pages (int4 packed two-per-byte along the head dim) plus f32
    per-row per-kv-head scales that page alongside them — page writes
    quantize in-graph and every read path (gather / XLA oracle / fused
    Pallas kernel) dequantizes on the fly through kernels/kv_quant.py, so
    a logical fp view of the pool is never materialized.

    ``mode="vq"`` (``KVQuantSpec.of("vq2")``; bits=2) stores vector-
    quantized pages instead: 4-bit codebook indices over d=2 vectors
    along the head dim (2 bits per value), against per-(pool, kv-head)
    codebooks carried as cache leaves (``PagedKVCache.k_codebook`` /
    ``v_codebook``). Per-row amax scales are kept, so the zero-row and
    stale-row invariants are identical to the scalar formats.
    """
    bits: int = 16
    mode: str = "scalar"

    def __post_init__(self):
        assert self.mode in ("scalar", "vq"), self.mode
        if self.mode == "vq":
            assert self.bits == 2, self.bits
        else:
            assert self.bits in (16, 8, 4), self.bits

    @classmethod
    def of(cls, bits) -> "KVQuantSpec":
        """Parse an engine/CLI ``kv_cache_bits`` value: 16/8/4 or the
        string "vq2"."""
        if isinstance(bits, KVQuantSpec):
            return bits
        from repro.kernels import kv_quant
        if bits == kv_quant.VQ_BITS:
            return cls(bits=2, mode="vq")
        return cls(bits=int(bits))

    @property
    def quantized(self) -> bool:
        return self.bits < 16

    @property
    def vq(self) -> bool:
        return self.mode == "vq"

    @property
    def fmt(self):
        """The kernels/kv_quant.py format token: int bits or "vq2"
        (what the byte-accounting helpers take as ``bits``)."""
        from repro.kernels import kv_quant
        return kv_quant.VQ_BITS if self.vq else self.bits

    def storage_cols(self, hd: int) -> int:
        from repro.kernels import kv_quant
        return kv_quant.storage_cols(hd, self.fmt) if self.quantized else hd


@dataclasses.dataclass(frozen=True)
class PagedLayout:
    """Shape of a paged KV pool: ``num_blocks`` fixed-size blocks of
    ``page_size`` tokens each, shared by every serving slot.

    Block 0 is reserved as a scratch block: page-table entries of inactive
    slots point there, so their (discarded) decode writes never touch live
    data. The serve-side allocator (serve/paged_cache.py) hands out block
    ids 1..num_blocks-1.

    ``kv`` is the page storage format (KVQuantSpec). Carrying it on the
    layout means every family's ``init_cache`` builds quantized pools with
    no signature change, and the cache leaves self-describe their format
    to the read/write paths (the spec can never disagree with the storage).
    """
    num_blocks: int
    page_size: int
    kv: KVQuantSpec = KVQuantSpec()

    def n_pages(self, max_len: int) -> int:
        return -(-max_len // self.page_size)


class PagedKVCache(NamedTuple):
    """KV pool + per-slot page table.

    ``k``/``v`` carry NO batch axis — blocks are a shared pool; which slot
    owns which block is entirely encoded in ``page_table`` (logical page p
    of slot b lives in physical block ``page_table[b, p]``). Keeping the
    page table a cache *leaf* means the family assemblies' layer scans
    thread it exactly like any dense cache leaf — no forward-signature
    change beyond ``pos`` accepting per-slot vectors.

    Quantized pools (KVQuantSpec bits < 16) store int8 code pages in
    ``k``/``v`` (int4 packed two codes per byte, so the last axis is
    hd//2) and per-row per-kv-head f32 scales in ``k_scale``/``v_scale``;
    passthrough pools leave the scale leaves None (jax treats None as an
    empty subtree, so the pytree contract of every existing caller is
    unchanged).

    Vector-quantized pools (KVQuantSpec mode "vq") additionally carry
    the frozen per-(pool, kv-head) codebooks as cache leaves
    (``k_codebook``/``v_codebook``, (KV, 16, 2) f32); ``k``/``v`` then
    hold packed 4-bit codebook indices (last axis hd//4). Codebook
    presence — not the spec — is what the read/write paths key on, the
    same self-description rule the scalar formats use for scales.
    """
    k: jax.Array           # (num_blocks, page_size, KV, storage_cols)
    v: jax.Array           # (num_blocks, page_size, KV, storage_cols)
    page_table: jax.Array  # (B, n_pages) int32; 0 = scratch block
    k_scale: jax.Array | None = None  # (num_blocks, page_size, KV) f32
    v_scale: jax.Array | None = None  # (num_blocks, page_size, KV) f32
    k_codebook: jax.Array | None = None  # (KV, VQ_K, VQ_D) f32
    v_codebook: jax.Array | None = None  # (KV, VQ_K, VQ_D) f32


def init_paged_cache(cfg: ModelConfig, batch: int, max_len: int,
                     layout: PagedLayout, dtype=jnp.bfloat16) -> PagedKVCache:
    table = jnp.zeros((batch, layout.n_pages(max_len)), jnp.int32)
    if layout.kv.quantized:
        from repro.kernels import kv_quant
        shape = (layout.num_blocks, layout.page_size, cfg.n_kv_heads,
                 layout.kv.storage_cols(cfg.hd))
        sshape = shape[:-1]
        cb = (kv_quant.default_codebook(cfg.n_kv_heads)
              if layout.kv.vq else None)
        return PagedKVCache(
            jnp.zeros(shape, jnp.int8), jnp.zeros(shape, jnp.int8), table,
            jnp.zeros(sshape, jnp.float32), jnp.zeros(sshape, jnp.float32),
            cb, cb)
    shape = (layout.num_blocks, layout.page_size, cfg.n_kv_heads, cfg.hd)
    return PagedKVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                        table)


# ---------------------------------------------------------------------------
# softmax attention cores
# ---------------------------------------------------------------------------

def _plain_attention(q, k, v, mask):
    """q (B,Sq,H,hd), k/v (B,Sk,KV,hd), mask broadcastable (B,1,1,Sq,Sk).

    Inputs stay in their storage dtype (bf16 caches are NOT up-cast — a
    32k-seq cache slice in f32 would double decode HBM); accumulation is
    f32 via preferred_element_type.
    """
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.reshape(B, Sq, KV, G, hd)
    s = jnp.einsum("bqkgh,bskh->bkgqs", qh, k,
                   preferred_element_type=jnp.float32)
    s = s / jnp.sqrt(hd).astype(jnp.float32)
    s = jnp.where(mask, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqs,bskh->bqkgh", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.reshape(B, Sq, H, hd).astype(q.dtype)


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    q_chunk: int = 512, kv_chunk: int = 1024):
    """Two-level chunked attention with online softmax (memory O(tile))."""
    if _FLASH_IMPL["impl"] == "pallas" and isinstance(q_offset, int):
        # the kernel handles causal masking at any static row offset, so a
        # nonzero q_offset (e.g. a chunk with an empty cache prefix, where
        # Sk == Sq and positions are absolute) no longer silently falls
        # back to the XLA scan. Traced offsets keep the XLA path (the
        # kernel's mask is built at trace time).
        from repro.kernels.flash_attention import flash_attention_tpu
        on_tpu = jax.default_backend() == "tpu"
        _FLASH_IMPL["counts"]["pallas"] += 1
        return flash_attention_tpu(q, k, v, causal=causal,
                                   q_offset=q_offset, interpret=not on_tpu)
    _FLASH_IMPL["counts"]["xla"] += 1
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    Tq = min(q_chunk, Sq)
    Ts = min(kv_chunk, Sk)
    assert Sq % Tq == 0 and Sk % Ts == 0
    nq, nk = Sq // Tq, Sk // Ts
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    qh = q.reshape(B, nq, Tq, KV, G, hd).transpose(1, 0, 3, 4, 2, 5)
    # (nq, B, KV, G, Tq, hd)
    kh = k.reshape(B, nk, Ts, KV, hd).transpose(1, 0, 3, 2, 4)  # (nk,B,KV,Ts,hd)
    vh = v.reshape(B, nk, Ts, KV, hd).transpose(1, 0, 3, 2, 4)

    k_pos = jnp.arange(Sk).reshape(nk, Ts)

    def q_block(args):
        qi, qb = args  # qb: (B, KV, G, Tq, hd)
        q_pos = q_offset + qi * Tq + jnp.arange(Tq)

        def kv_step(carry, xs):
            m, l, acc = carry
            kb, vb, kp = xs

            def compute(carry):
                m, l, acc = carry
                s = jnp.einsum("bkgqh,bksh->bkgqs", qb.astype(jnp.float32),
                               kb.astype(jnp.float32)) * scale
                if causal:
                    msk = kp[None, :] <= q_pos[:, None]  # (Tq, Ts)
                    s = jnp.where(msk[None, None, None], s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                alpha = jnp.exp(m - m_new)
                p = jnp.exp(s - m_new[..., None])
                l2 = l * alpha + jnp.sum(p, axis=-1)
                acc2 = acc * alpha[..., None] + jnp.einsum(
                    "bkgqs,bksh->bkgqh", p, vb.astype(jnp.float32))
                return m_new, l2, acc2

            if causal and nk >= 8:
                # causal chunk skip: kv chunks strictly after this q block
                # are fully masked — lax.cond skips their compute at run
                # time, halving long-context attention FLOPs (§Perf it.7).
                # Gated to nk >= 8: at short seq the cond's extra backward
                # residuals cost ~1 GiB while attention is <0.1% of step
                # FLOPs (dbrx train_4k measurement).
                needed = kp[0] <= q_pos[-1]
                carry = jax.lax.cond(needed, compute, lambda c: c, carry)
            else:
                carry = compute(carry)
            return carry, None

        m0 = jnp.full((B, KV, G, Tq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Tq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Tq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (kh, vh, k_pos))
        return acc / jnp.maximum(l, 1e-30)[..., None]

    out = jax.lax.map(q_block, (jnp.arange(nq), qh))  # (nq,B,KV,G,Tq,hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# full layer apply
# ---------------------------------------------------------------------------

def pre_out(p, cfg: ModelConfig, x, *, pos: jax.Array | int = 0,
            causal: bool = True, use_rope: bool = True,
            flash_threshold: int = 2048):
    """Self-attention up to (but not including) ``wo``; returns (B,S,H*hd).

    The Hessian tap for the output projection: GPTVQ quantizes ``wo``
    against the distribution of its *inputs*, which is exactly this
    pre-projection attention output (core/adapters/*).
    """
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x)
    if use_rope:
        pos_arr = cm.position_ids(pos, B, S)
        q = cm.apply_rope(q, pos_arr, cfg.rope_theta)
        k = cm.apply_rope(k, pos_arr, cfg.rope_theta)
    if S > flash_threshold:
        o = flash_attention(q, k, v, causal=causal)
    else:
        if causal:
            msk = (jnp.arange(S)[None, :] <= jnp.arange(S)[:, None])
            msk = msk[None, None, None]
        else:
            msk = jnp.ones((1, 1, 1, S, S), bool)
        o = _plain_attention(q, k, v, msk)
    return o.reshape(B, S, -1)


def cross_pre_out(p, cfg: ModelConfig, x, memory, *, flash_threshold=2048):
    """Cross-attention up to (but not including) ``wo``; returns (B,S,H*hd)."""
    B, S, _ = x.shape
    q, k, v = _project_qkv(p, cfg, x, kv_x=memory)
    Sk = memory.shape[1]
    if max(S, Sk) > flash_threshold:
        o = flash_attention(q, k, v, causal=False)
    else:
        msk = jnp.ones((1, 1, 1, S, Sk), bool)
        o = _plain_attention(q, k, v, msk)
    return o.reshape(B, S, -1)


def _project_qkv(p, cfg: ModelConfig, x, kv_x=None):
    B, S, _ = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    kv_x = x if kv_x is None else kv_x
    Skv = kv_x.shape[1]
    q = cm.matmul(x, p["wq"])
    k = cm.matmul(kv_x, p["wk"])
    v = cm.matmul(kv_x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, Skv, KV, hd)
    v = v.reshape(B, Skv, KV, hd)
    if cfg.qk_norm:
        q = cm.rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = cm.rmsnorm(k, p["k_norm"], cfg.norm_eps)
    return q, k, v


def apply(
    p,
    cfg: ModelConfig,
    x: jax.Array,
    *,
    pos: jax.Array | int = 0,
    cache: KVCache | None = None,
    causal: bool = True,
    use_rope: bool = True,
    flash_threshold: int = 2048,
    paged_impl: str | None = None,
):
    """Self-attention. Returns (y, new_cache).

    * prefill/train: x is (B, S, D); if a cache is given the fresh K/V are
      written at positions [pos, pos+S).
    * decode: x is (B, 1, D); attends over cache[:pos+1].
    * paged (serving): cache is a PagedKVCache and ``pos`` may be a per-slot
      (B,) vector — K/V are scattered into each slot's blocks through the
      page table and attention reads back through a page-table gather, so
      every slot decodes at its own depth (no shared write position).
    """
    B, S, D = x.shape
    if cache is None:
        # cache-free path shares its math with the quantizer's Hessian tap
        o = pre_out(p, cfg, x, pos=pos, causal=causal, use_rope=use_rope,
                    flash_threshold=flash_threshold)
        return cm.matmul(o, p["wo"]).astype(x.dtype), None
    q, k, v = _project_qkv(p, cfg, x)
    pos_arr = cm.position_ids(pos, B, S)  # (B, S)
    if use_rope:
        q = cm.apply_rope(q, pos_arr, cfg.rope_theta)
        k = cm.apply_rope(k, pos_arr, cfg.rope_theta)

    if isinstance(cache, PagedKVCache):
        return _paged_apply(p, cache, q, k, v, pos_arr, x.dtype,
                            impl=paged_impl)

    ck = jax.lax.dynamic_update_slice(
        cache.k, k.astype(cache.k.dtype), (0, jnp.asarray(pos), 0, 0))
    cv = jax.lax.dynamic_update_slice(
        cache.v, v.astype(cache.v.dtype), (0, jnp.asarray(pos), 0, 0))
    new_cache = KVCache(ck, cv)
    if S == 1:
        # decode: attend over the whole cache with a length mask
        Sk = ck.shape[1]
        valid = (jnp.arange(Sk) <= jnp.asarray(pos))[None, None, None, None, :]
        o = _plain_attention(q, ck, cv, valid)
        return cm.matmul(o.reshape(B, S, -1), p["wo"]).astype(x.dtype), new_cache
    k, v = ck[:, : S + 0], cv[:, : S + 0]  # prefill from position 0

    if S > flash_threshold:
        o = flash_attention(q, k, v, causal=causal)
    else:
        Sk = k.shape[1]
        if causal:
            msk = (jnp.arange(Sk)[None, :] <= jnp.arange(S)[:, None])
            msk = msk[None, None, None]
        else:
            msk = jnp.ones((1, 1, 1, S, Sk), bool)
        o = _plain_attention(q, k, v, msk)
    y = cm.matmul(o.reshape(B, S, -1), p["wo"])
    return y.astype(x.dtype), new_cache


def _paged_apply(p, cache: PagedKVCache, q, k, v, pos_arr, out_dtype,
                 impl: str | None = None):
    """Scatter new K/V through the page table, attend over the gathered
    logical view. ``pos_arr`` is (B, S): the absolute position of every new
    token per slot (S > 1 during chunked prefill, S == 1 at decode).

    Writes from slots whose page-table entries are 0 land in the reserved
    scratch block; reads are masked to ``kpos <= pos`` per slot, so stale
    data in recycled blocks and the scratch block never leak into live
    rows.

    Decode (S == 1) dispatches on ``impl`` (falling back to the
    set_paged_impl() module default): "pallas" runs the fused kernel
    (kernels/paged_attention.py) whose BlockSpec index maps gather K/V
    pages in-kernel through the page table; "xla" runs the same math
    through the oracle (kernels/ref.py). The default "gather" — and
    chunked prefill at any impl (the engine pins prefill closures to
    "gather", including width-1 tail chunks) — materializes the
    (B, n_pages*page_size, KV, hd) logical view per layer, the same
    working set as a dense cache read.

    Quantized pools (the cache's scale leaves are present): fresh K/V rows
    are quantized in-graph right here — per-row per-kv-head amax scales,
    int8 codes (int4 packed two-per-byte) — and every read path dequants
    on the fly. Stale codes AND stale scales in recycled/scratch blocks
    decode to finite garbage that the same ``kpos <= pos`` mask discards.
    The format is inferred from the cache leaves themselves (scales
    present + stored column count), so it can never disagree with the
    storage the engine allocated via PagedLayout.kv.

    VQ pools (the cache's codebook leaves are present): rows store 4-bit
    codebook indices instead of scalar codes. The codebooks are frozen
    (the engine calibrates them once at load, before any serving write),
    so assignment at this scatter site is a pure deterministic function
    of the written row — replayed and interleaved writes stay
    bit-identical, the same property the scalar round gives.
    """
    from repro.kernels import kv_quant as kvq

    B, S = pos_arr.shape
    page_size = cache.k.shape[1]
    n_pages = cache.page_table.shape[-1]
    quantized = cache.k_scale is not None
    vq = cache.k_codebook is not None
    if vq:
        kv_bits = kvq.VQ_BITS
    elif quantized:
        kv_bits = kvq.infer_bits(cache.k.shape[-1], q.shape[-1])
    else:
        kv_bits = kvq.PASSTHROUGH_BITS
    page = pos_arr // page_size
    blk = jnp.take_along_axis(
        cache.page_table, jnp.minimum(page, n_pages - 1), axis=1)  # (B, S)
    # positions past the table extent (a padded prefill chunk can overhang
    # max_len) go to scratch — clipping them into the last page would
    # overwrite live K/V
    blk = jnp.where(page < n_pages, blk, 0)
    off = pos_arr % page_size
    if quantized:
        if vq:
            kc, ks = kvq.vq_quantize_rows(k, cache.k_codebook)
            vc, vs = kvq.vq_quantize_rows(v, cache.v_codebook)
        else:
            kc, ks = kvq.quantize_kv(k, kv_bits)
            vc, vs = kvq.quantize_kv(v, kv_bits)
        ck = cache.k.at[blk, off].set(kc)
        cv = cache.v.at[blk, off].set(vc)
        cks = cache.k_scale.at[blk, off].set(ks)
        cvs = cache.v_scale.at[blk, off].set(vs)
    else:
        ck = cache.k.at[blk, off].set(k.astype(cache.k.dtype))
        cv = cache.v.at[blk, off].set(v.astype(cache.v.dtype))
        cks = cvs = None
    new_cache = PagedKVCache(ck, cv, cache.page_table, cks, cvs,
                             cache.k_codebook, cache.v_codebook)

    impl = impl or _PAGED_IMPL["impl"]
    if S == 1 and impl in ("xla", "pallas"):
        from repro.kernels import ops
        _PAGED_IMPL["counts"][impl] += 1
        o = ops.paged_attention(
            q[:, 0], ck, cv, cache.page_table, pos_arr[:, 0],
            k_scale=cks, v_scale=cvs,
            k_codebook=cache.k_codebook, v_codebook=cache.v_codebook,
            use_pallas=(impl == "pallas"),
            interpret=jax.default_backend() != "tpu")
        return cm.matmul(o.reshape(B, 1, -1), p["wo"]).astype(out_dtype), new_cache
    _PAGED_IMPL["counts"]["gather"] += 1

    Sk = n_pages * page_size
    kg = ck[cache.page_table].reshape(B, Sk, *ck.shape[2:])
    vg = cv[cache.page_table].reshape(B, Sk, *cv.shape[2:])
    if vq:
        kg = kvq.vq_dequant_rows(
            kg, cks[cache.page_table].reshape(B, Sk, kg.shape[2]),
            cache.k_codebook)
        vg = kvq.vq_dequant_rows(
            vg, cvs[cache.page_table].reshape(B, Sk, vg.shape[2]),
            cache.v_codebook)
    elif quantized:
        kg = kvq.dequant_rows(
            kg, cks[cache.page_table].reshape(B, Sk, kg.shape[2]), kv_bits)
        vg = kvq.dequant_rows(
            vg, cvs[cache.page_table].reshape(B, Sk, vg.shape[2]), kv_bits)
    # per-slot causal + length mask over logical positions
    msk = jnp.arange(Sk)[None, None, :] <= pos_arr[:, :, None]  # (B, S, Sk)
    o = _plain_attention(q, kg, vg, msk[:, None, None])
    return cm.matmul(o.reshape(B, S, -1), p["wo"]).astype(out_dtype), new_cache


def cross_apply(p, cfg: ModelConfig, x, memory, *, flash_threshold=2048):
    """Cross-attention (whisper decoder): keys/values from encoder memory."""
    o = cross_pre_out(p, cfg, x, memory, flash_threshold=flash_threshold)
    return cm.matmul(o, p["wo"]).astype(x.dtype)
