"""Unified model API: build(cfg) -> Model with init/specs/forward/cache.

``forward(params, batch, cache=None, pos=0)`` where batch is a dict:
  tokens  : (B, S) int32            — always present
  frames  : (B, S_enc, D)           — audio family (conv-frontend stub)
  patches : (B, n_image_tokens, D)  — vlm family (CLIP stub)
Returns (logits, new_cache, aux_loss).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import encdec, hybrid, transformer, vlm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    init_params: Callable[[jax.Array], Any]
    param_specs: Callable[[], Any]
    forward: Callable[..., tuple]
    init_cache: Callable[..., Any]
    cache_specs: Callable[..., Any]


def build(cfg: ModelConfig) -> Model:
    if cfg.family == "audio":
        def fwd(params, batch, cache=None, pos=0, remat=True, **kw):
            return encdec.forward(
                params, cfg, batch["tokens"], frames=batch.get("frames"),
                pos=pos, cache=cache, remat=remat, **kw)
        return Model(cfg, lambda k: encdec.init_params(cfg, k),
                     lambda: encdec.param_specs(cfg), fwd,
                     lambda b, s, dtype=jnp.bfloat16, paged=None:
                         encdec.init_cache(cfg, b, s, dtype, paged),
                     lambda **kw: encdec.cache_specs(cfg))
    if cfg.family == "hybrid":
        def fwd(params, batch, cache=None, pos=0, remat=True, **kw):
            return hybrid.forward(params, cfg, batch["tokens"], pos=pos,
                                  cache=cache, remat=remat, **kw)
        return Model(cfg, lambda k: hybrid.init_params(cfg, k),
                     lambda: hybrid.param_specs(cfg), fwd,
                     lambda b, s, dtype=jnp.bfloat16, paged=None:
                         hybrid.init_cache(cfg, b, s, dtype, paged),
                     lambda **kw: hybrid.cache_specs(cfg, **kw))
    if cfg.family == "vlm":
        def fwd(params, batch, cache=None, pos=0, remat=True, **kw):
            return vlm.forward(params, cfg, batch["tokens"],
                               patches=batch.get("patches"), pos=pos,
                               cache=cache, remat=remat, **kw)
        return Model(cfg, lambda k: vlm.init_params(cfg, k),
                     lambda: vlm.param_specs(cfg), fwd,
                     lambda b, s, dtype=jnp.bfloat16, paged=None:
                         vlm.init_cache(cfg, b, s, dtype, paged),
                     lambda **kw: vlm.cache_specs(cfg))

    # dense / moe / ssm(xlstm)
    def fwd(params, batch, cache=None, pos=0, remat=True, **kw):
        return transformer.forward(params, cfg, batch["tokens"], pos=pos,
                                   cache=cache, remat=remat, **kw)
    return Model(cfg, lambda k: transformer.init_params(cfg, k),
                 lambda: transformer.param_specs(cfg), fwd,
                 lambda b, s, dtype=jnp.bfloat16, paged=None:
                     transformer.init_cache(cfg, b, s, dtype, paged),
                 lambda **kw: transformer.cache_specs(cfg))


def abstract_params(model: Model, key=None):
    """ShapeDtypeStruct tree of the parameters (no allocation)."""
    key = jax.random.PRNGKey(0) if key is None else key
    return jax.eval_shape(model.init_params, key)


def count_params(model: Model) -> int:
    import math
    shapes = abstract_params(model)
    return sum(math.prod(x.shape) for x in jax.tree.leaves(shapes))
