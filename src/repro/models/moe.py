"""Mixture-of-Experts layer with capacity-based scatter dispatch.

Expert-parallel design (DESIGN.md §4): expert weights carry a leading
``n_experts`` dim sharded over the 'model' mesh axis. Tokens are scattered
into an (E, C, D) buffer — the scatter across the token->expert resharding
is where GSPMD inserts the all-to-all — experts run as one batched einsum on
the MXU, and results are gathered back with the top-k combine weights.

Capacity C = ceil(tokens_per_shard * top_k / E * capacity_factor); overflow
tokens are dropped (standard Switch/GShard semantics) and the router aux
loss (load-balancing, Shazeer-style) keeps drops rare.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    D, F, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    p = {
        "router": cm.dense_init(ks[0], D, E, scale=0.02, dtype=jnp.float32),
        "w_in": (jax.random.normal(ks[1], (E, D, F)) / jnp.sqrt(D)).astype(dtype),
        "w_out": (jax.random.normal(ks[2], (E, F, D)) / jnp.sqrt(F)).astype(dtype),
    }
    if cm.is_gated(cfg.activation):
        p["w_gate"] = (jax.random.normal(ks[3], (E, D, F)) / jnp.sqrt(D)).astype(dtype)
    return p


def specs(cfg: ModelConfig):
    s = {
        "router": P(None, "model"),
        "w_in": P("model", "data", None),
        "w_out": P("model", None, "data"),
    }
    if cm.is_gated(cfg.activation):
        s["w_gate"] = P("model", "data", None)
    return s


def capacity(tokens_per_row: int, cfg: ModelConfig) -> int:
    c = int(tokens_per_row * cfg.n_experts_active / cfg.n_experts
            * cfg.moe_capacity_factor)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _dispatch_one(xf, p, cfg: ModelConfig, C: int):
    """Route one batch row. xf: (S, D). Returns (y (S,D), aux scalar).

    Dispatch is per-row so the slot cumsum never crosses a data shard —
    batch stays sharded over (pod, data), experts over 'model', and the
    scatter/gather below is where GSPMD places the token all-to-all.
    """
    S, D = xf.shape
    E, K = cfg.n_experts, cfg.n_experts_active

    logits = xf.astype(jnp.float32) @ p["router"]  # (S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # slot of each (token, k) within its expert queue (exclusive cumsum)
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)  # (S, K, E)
    flat_oh = onehot.reshape(S * K, E)
    pos_in_expert = jnp.cumsum(flat_oh, axis=0) - flat_oh
    slot = jnp.sum(pos_in_expert * flat_oh, axis=-1).reshape(S, K)
    keep = slot < C

    eid = expert_ids.reshape(-1)
    sid = jnp.where(keep, slot, C).reshape(-1)  # dropped -> scratch row C

    buf = jnp.zeros((E, C + 1, D), xf.dtype)
    tok_rep = jnp.repeat(xf, K, axis=0)  # (S*K, D)
    buf = buf.at[eid, sid].set(tok_rep, mode="drop")
    hbuf = buf[:, :C]  # (E, C, D)

    act = cm.act_fn(cfg.activation)
    h = cm.expert_matmul(hbuf, p["w_in"])
    if cm.is_gated(cfg.activation):
        g = cm.expert_matmul(hbuf, p["w_gate"])
        h = act(g) * h
    else:
        h = act(h)
    out = cm.expert_matmul(h, p["w_out"])  # (E,C,D)

    out_pad = jnp.concatenate([out, jnp.zeros((E, 1, D), out.dtype)], axis=1)
    y_slots = out_pad[eid, sid].reshape(S, K, D)
    w = (gate_vals * keep.astype(gate_vals.dtype)).astype(xf.dtype)
    y = jnp.sum(y_slots * w[..., None], axis=1)  # (S, D)

    # Shazeer load-balance aux loss
    me = jnp.mean(probs, axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E, dtype=jnp.float32), axis=0)
    aux = cfg.moe_aux_loss_coef * E * jnp.sum(me * ce)
    return y, aux


def expert_hessians(p, cfg: ModelConfig, x, diag_only: bool = False):
    """Per-expert GPTVQ Hessian statistics for one calibration chunk.

    x: (B, S, D) layer inputs. Routes every token with the layer's own
    router (top-k, no capacity drop — calibration wants the true input
    distribution, not the serving-time drop pattern) and accumulates

      * input-side  H_e = sum_{tokens routed to e} x x^T        (E, D, D)
      * output-side H_e = sum_{tokens routed to e} h_e h_e^T    (E, F, F)

    where h_e is the expert's activated hidden state; tokens not routed to
    an expert are masked to zero on the ``w_out`` side so they contribute
    nothing. Returns ((Hin, n), (Hout, n)) with n = per-expert *raw* token
    counts for this chunk — counts sum across chunks, and the consumer
    clamps once at division time (clamping per chunk would inflate n for
    experts unrouted in some chunks and skew the mean-Hessian scale).

    With ``diag_only`` (the budget pre-pass's O(c) capture mode) only the
    Hessian diagonals are accumulated: (E, D) / (E, F) stacks instead of
    (E, D, D) / (E, F, F).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_active
    xf = x.reshape(B * S, D)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, eids = jax.lax.top_k(probs, K)
    onehot = jax.nn.one_hot(eids, E, dtype=jnp.float32).sum(1)  # (N, E)
    # output-side: inputs to w_out are h = act(...) per expert
    act = cm.act_fn(cfg.activation)
    h = jnp.einsum("nd,edf->enf", xf, p["w_in"].astype(jnp.float32))
    if cm.is_gated(cfg.activation):
        g = jnp.einsum("nd,edf->enf", xf, p["w_gate"].astype(jnp.float32))
        h = act(g) * h
    else:
        h = act(h)
    h = h * onehot.T[..., None]  # zero out tokens not routed to e
    n = onehot.sum(0)
    if diag_only:
        Hin_d = jnp.einsum("ne,nd->ed", onehot, xf * xf)
        Hout_d = jnp.einsum("enf->ef", h * h)
        return (Hin_d, n), (Hout_d, n)
    # input-side: H_e = sum over tokens routed to e of x x^T
    Hin = jnp.einsum("ne,nd,nc->edc", onehot, xf, xf)
    Hout = jnp.einsum("enf,eng->efg", h, h)
    return (Hin, n), (Hout, n)


def _maybe_constrain(t, spec):
    """Sharding constraint when tracing under a mesh (no-op otherwise)."""
    try:
        import jax._src.mesh as jmesh
        m = jmesh.thread_resources.env.physical_mesh
        if m.empty:
            return t
        names = set(m.axis_names)
        fixed = []
        for i, ax in enumerate(spec):
            tup = ax if isinstance(ax, tuple) else ((ax,) if ax else ())
            tup = tuple(a for a in tup if a in names)
            size = 1
            for a in tup:
                size *= dict(zip(m.axis_names, m.devices.shape))[a]
            ok = tup and t.shape[i] % size == 0
            fixed.append((tup if len(tup) > 1 else tup[0]) if ok else None)
        return jax.lax.with_sharding_constraint(t, P(*fixed))
    except Exception:
        return t


def _ambient_mesh():
    try:
        import jax._src.mesh as jmesh
        m = jmesh.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def apply(p, cfg: ModelConfig, x: jax.Array):
    """x: (B, S, D) -> (y, aux_loss).

    Under a mesh with a 'model' axis that divides n_experts, dispatch runs
    through the shard_map expert-parallel path (`_apply_ep`): activations
    are replicated over 'model' anyway (TP), so each model shard selects
    and computes tokens for ITS experts entirely locally and one psum
    combines — zero all-to-all, no GSPMD scatter fallbacks (§Perf it.3:
    dbrx-132b prefill_32k temp 217 GB -> fits). Otherwise the pure-pjit
    batched dispatch below runs (CPU tests, degenerate meshes).
    """
    from repro.core import vq_linear as vql_mod

    mesh = _ambient_mesh()
    # the shard_map EP path moves raw weight arrays through in_specs —
    # fused-VQ expert stacks stay on the pjit path (expert_matmul dispatch)
    fused = isinstance(p["w_in"], vql_mod.FusedVQLinear)
    if mesh is not None and "model" in mesh.axis_names and not fused:
        tp = dict(zip(mesh.axis_names, mesh.devices.shape))["model"]
        if cfg.n_experts % tp == 0 and tp > 1:
            return _apply_ep(p, cfg, x, mesh)
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_active
    C = capacity(S, cfg)

    logits = x.astype(jnp.float32) @ p["router"]  # (B, S, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, K)  # (B, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

    # per-row slot assignment (cumsum never crosses a batch row)
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)  # (B, S, K, E)
    flat_oh = onehot.reshape(B, S * K, E)
    pos_in_expert = jnp.cumsum(flat_oh, axis=1) - flat_oh
    slot = jnp.sum(pos_in_expert * flat_oh, axis=-1).reshape(B, S, K)
    keep = slot < C

    eid = expert_ids.reshape(B, S * K)
    sid = jnp.where(keep, slot, C).reshape(B, S * K)
    bid = jnp.broadcast_to(jnp.arange(B)[:, None], (B, S * K))

    # scatter stays LOCAL to each data shard (indices are per-row); the
    # token all-to-all happens at the explicit reshard below, immediately
    # before the expert matmul — scatter across a sharded dim would force
    # GSPMD replication (§Perf iteration 3)
    buf = jnp.zeros((B, E, C + 1, D), x.dtype)
    tok_rep = jnp.repeat(x, K, axis=1)  # (B, S*K, D)
    buf = buf.at[bid, eid, sid].set(tok_rep, mode="drop")
    buf = _maybe_constrain(buf, (("pod", "data"), None, None, None))
    hbuf = _maybe_constrain(buf[:, :, :C],
                            (("pod", "data"), "model", None, None))  # <- a2a

    act = cm.act_fn(cfg.activation)
    h = cm.expert_matmul(hbuf, p["w_in"])
    if cm.is_gated(cfg.activation):
        g = cm.expert_matmul(hbuf, p["w_gate"])
        h = act(g) * h
    else:
        h = act(h)
    h = _maybe_constrain(h, (("pod", "data"), "model", None, None))
    out = cm.expert_matmul(h, p["w_out"])
    # combine all-to-all back to data-sharded so the gather below is local
    out = _maybe_constrain(out, (("pod", "data"), None, None, None))

    out_pad = jnp.concatenate([out, jnp.zeros((B, E, 1, D), out.dtype)], axis=2)
    y_slots = out_pad[bid, eid, sid].reshape(B, S, K, D)
    w = (gate_vals * keep.astype(gate_vals.dtype)).astype(x.dtype)
    y = jnp.sum(y_slots * w[..., None], axis=2)  # (B, S, D)

    me = jnp.mean(probs.reshape(-1, E), axis=0)
    ce = jnp.mean(jax.nn.one_hot(expert_ids[..., 0].reshape(-1), E,
                                 dtype=jnp.float32), axis=0)
    aux = cfg.moe_aux_loss_coef * E * jnp.sum(me * ce)
    return y.astype(x.dtype), aux


def _apply_ep(p, cfg: ModelConfig, x: jax.Array, mesh):
    """shard_map expert parallelism: local dispatch, psum combine."""
    from jax.experimental.shard_map import shard_map

    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.n_experts_active
    C = capacity(S, cfg)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    tp = axes["model"]
    E_local = E // tp
    dp_names = tuple(a for a in ("pod", "data") if a in axes)
    dpn = 1
    for a in dp_names:
        dpn *= axes[a]
    batch_ax = dp_names if B % dpn == 0 else None

    gated = "w_gate" in p

    def local_fn(router, w_in, w_gate, w_out, xl):
        # xl: (B_l, S, D) local rows, replicated over 'model'
        # w_*: (E_local, D, F) this shard's experts; router replicated
        Bl = xl.shape[0]
        xf = xl.reshape(Bl * S, D)
        logits = xf.astype(jnp.float32) @ router          # (N, E) global E
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, expert_ids = jax.lax.top_k(probs, K)   # (N, K)
        gate_vals = gate_vals / jnp.maximum(
            jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9)

        shard = jax.lax.axis_index("model")
        lo = shard * E_local
        local_eid = expert_ids - lo                        # (N, K)
        mine = (local_eid >= 0) & (local_eid < E_local)
        eid = jnp.where(mine, local_eid, E_local)          # E_local = scratch

        # slot within each local expert queue (exclusive cumsum over N*K)
        oh = jax.nn.one_hot(eid, E_local + 1, dtype=jnp.int32).reshape(
            -1, E_local + 1)
        pos = jnp.cumsum(oh, axis=0) - oh
        slot = jnp.sum(pos * oh, axis=-1).reshape(-1)
        keep = (slot < C) & mine.reshape(-1)
        sid = jnp.where(keep, slot, C)

        buf = jnp.zeros((E_local + 1, C + 1, D), xl.dtype)
        tok = jnp.repeat(xf, K, axis=0)                    # (N*K, D) local
        buf = buf.at[eid.reshape(-1), sid].set(tok, mode="drop")
        hbuf = buf[:E_local, :C]                           # (E_l, C, D)

        act = cm.act_fn(cfg.activation)
        h = jnp.einsum("ecd,edf->ecf", hbuf, w_in.astype(xl.dtype))
        if gated:
            g = jnp.einsum("ecd,edf->ecf", hbuf, w_gate.astype(xl.dtype))
            h = act(g) * h
        else:
            h = act(h)
        out = jnp.einsum("ecf,efd->ecd", h, w_out.astype(xl.dtype))

        pad = jnp.zeros((1, C + 1, D), out.dtype)
        out_pad = jnp.concatenate(
            [jnp.pad(out, ((0, 0), (0, 1), (0, 0))), pad], axis=0)
        y_slots = out_pad[eid.reshape(-1), sid].reshape(Bl * S, K, D)
        w = (gate_vals * keep.reshape(Bl * S, K)).astype(xl.dtype)
        y = jnp.sum(y_slots * w[..., None], axis=1)        # (N, D) partial
        y = jax.lax.psum(y, "model")                       # combine shards
        y = y.reshape(Bl, S, D)

        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_ids[:, 0], E,
                                     dtype=jnp.float32), axis=0)
        aux = cfg.moe_aux_loss_coef * E * jnp.sum(me * ce)
        if dp_names and batch_ax is not None:
            aux = jax.lax.pmean(aux, dp_names)
        return y, aux

    in_specs = (
        P(None, None),                    # router replicated
        P("model", None, None),           # experts over 'model'
        P("model", None, None),
        P("model", None, None),
        P(batch_ax, None, None),          # tokens over DP axes
    )
    out_specs = (P(batch_ax, None, None), P())
    fn = shard_map(
        local_fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
        check_rep=False)
    gate_arg = p["w_gate"] if gated else p["w_in"]  # ignored when not gated
    y, aux = fn(p["router"].astype(jnp.float32), p["w_in"],
                gate_arg, p["w_out"], x)
    return y.astype(x.dtype), aux
