"""xLSTM blocks (Beck et al. 2024): mLSTM (matrix memory) and sLSTM (scalar
memory), with stabilized exponential gating.

Faithful-but-minimal reading of the paper's block diagrams (DESIGN.md §5):
  * mLSTM block: x + down( mLSTM_core(up(x)) * silu(up_gate(x)) ), where the
    core keeps a per-head matrix state C (hd x hd), normalizer n and
    stabilizer m, updated sequentially (lax.scan over time).
  * sLSTM block: x + core(norm(x)) followed by x + gated_ffn(norm(x)); the
    core has block-diagonal (per-head) recurrent connections.

Sequential scans are the correctness reference; a chunk-parallel mLSTM is a
§Perf item (the mLSTM update is the same algebra as linear attention).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

class MLSTMCache(NamedTuple):
    C: jax.Array  # (B, H, hd, hd)
    n: jax.Array  # (B, H, hd)
    m: jax.Array  # (B, H)


def _mlstm_dims(cfg: ModelConfig):
    d_inner = 2 * cfg.d_model
    H = cfg.n_heads
    hd = d_inner // H
    return d_inner, H, hd


def mlstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    D = cfg.d_model
    d_inner, H, hd = _mlstm_dims(cfg)
    ks = jax.random.split(key, 9)
    return {
        "up": cm.dense_init(ks[0], D, d_inner, dtype=dtype),
        "up_gate": cm.dense_init(ks[1], D, d_inner, dtype=dtype),
        "wq": cm.dense_init(ks[2], d_inner, d_inner, dtype=dtype),
        "wk": cm.dense_init(ks[3], d_inner, d_inner, dtype=dtype),
        "wv": cm.dense_init(ks[4], d_inner, d_inner, dtype=dtype),
        "w_i": cm.dense_init(ks[5], d_inner, H, scale=0.02, dtype=jnp.float32),
        "w_f": cm.dense_init(ks[6], d_inner, H, scale=0.02, dtype=jnp.float32),
        "b_i": jnp.zeros((H,), jnp.float32),
        "b_f": jnp.full((H,), 3.0, jnp.float32),  # open forget gates at init
        "w_o": cm.dense_init(ks[7], d_inner, d_inner, dtype=dtype),
        "down": cm.dense_init(ks[8], d_inner, D, dtype=dtype),
    }


def mlstm_specs(cfg: ModelConfig):
    return {
        "up": P("data", "model"), "up_gate": P("data", "model"),
        "wq": P("data", "model"), "wk": P("data", "model"),
        "wv": P("data", "model"),
        "w_i": P("data", None), "w_f": P("data", None),
        "b_i": P(None), "b_f": P(None),
        "w_o": P("data", "model"),
        "down": P("model", "data"),
    }


def mlstm_cache(cfg: ModelConfig, batch: int) -> MLSTMCache:
    _, H, hd = _mlstm_dims(cfg)
    return MLSTMCache(
        C=jnp.zeros((batch, H, hd, hd), jnp.float32),
        n=jnp.zeros((batch, H, hd), jnp.float32),
        m=jnp.full((batch, H), -1e30, jnp.float32),
    )


def _mlstm_core(q, k, v, i_raw, f_raw, state: MLSTMCache):
    """Sequential stabilized mLSTM. q/k/v: (B,S,H,hd); gates: (B,S,H)."""
    B, S, H, hd = q.shape
    k = k / jnp.sqrt(hd)
    f_log = jax.nn.log_sigmoid(f_raw.astype(jnp.float32))

    def step(carry, xs):
        C, n, m = carry
        qt, kt, vt, it, ft = xs  # (B,H,hd) x3, (B,H) x2
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])  # (B,H,hd_v,hd_k)
        n = f_p[..., None] * n + i_p[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (
        q.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        i_raw.transpose(1, 0, 2).astype(jnp.float32),
        f_log.transpose(1, 0, 2),
    )
    # two-level chunked scan: BPTT over a flat scan stores the (hd x hd)
    # matrix state for EVERY step (~78 GB/device at train_4k); checkpointing
    # chunk boundaries bounds residuals to S/chunk states (§Perf iteration 4)
    Q = 64
    if S % Q == 0 and S > Q:
        def chunk_body(carry, chunk_xs):
            c2, hs2 = jax.lax.scan(step, carry, chunk_xs)
            return c2, hs2

        chunk_fn = jax.checkpoint(chunk_body)
        xs_c = jax.tree.map(
            lambda t: t.reshape(S // Q, Q, *t.shape[1:]), xs)
        (C, n, m), hs = jax.lax.scan(chunk_fn, (state.C, state.n, state.m),
                                     xs_c)
        hs = hs.reshape(S, *hs.shape[2:])
    else:
        (C, n, m), hs = jax.lax.scan(step, (state.C, state.n, state.m), xs)
    return hs.transpose(1, 0, 2, 3), MLSTMCache(C, n, m)  # (B,S,H,hd)


def mlstm_pre_down(p, cfg: ModelConfig, x, cache: MLSTMCache | None = None):
    """mLSTM block up to (but not including) ``down``.

    Returns (u, h, new_state): u is the up-projected stream feeding the
    q/k/v/o heads, h the gated core output feeding ``down`` — the two
    Hessian taps the xLSTM adapter quantizes against (core/adapters/*).
    """
    B, S, D = x.shape
    d_inner, H, hd = _mlstm_dims(cfg)
    u = cm.matmul(x, p["up"])
    g = cm.matmul(x, p["up_gate"])
    q = cm.matmul(u, p["wq"]).reshape(B, S, H, hd)
    k = cm.matmul(u, p["wk"]).reshape(B, S, H, hd)
    v = cm.matmul(u, p["wv"]).reshape(B, S, H, hd)
    i_raw = u.astype(jnp.float32) @ p["w_i"] + p["b_i"]
    f_raw = u.astype(jnp.float32) @ p["w_f"] + p["b_f"]
    state = cache if cache is not None else mlstm_cache(cfg, B)
    h, new_state = _mlstm_core(q, k, v, i_raw, f_raw, state)
    o = jax.nn.sigmoid(cm.matmul(u, p["w_o"]))
    h = (h.reshape(B, S, d_inner).astype(x.dtype) * o) * jax.nn.silu(g)
    return u, h, new_state


def mlstm_apply(p, cfg: ModelConfig, x, cache: MLSTMCache | None = None):
    _, h, new_state = mlstm_pre_down(p, cfg, x, cache)
    y = cm.matmul(h, p["down"])
    return y.astype(x.dtype), (new_state if cache is not None else None)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

class SLSTMCache(NamedTuple):
    c: jax.Array  # (B, D)
    n: jax.Array  # (B, D)
    h: jax.Array  # (B, D)
    m: jax.Array  # (B, D)


def slstm_init(key, cfg: ModelConfig, dtype=jnp.float32):
    D, H = cfg.d_model, cfg.n_heads
    hd = D // H
    ks = jax.random.split(key, 9)
    ff = cm.dense_init  # alias
    p = {
        "w_z": ff(ks[0], D, D, dtype=dtype), "w_i": ff(ks[1], D, D, dtype=dtype),
        "w_f": ff(ks[2], D, D, dtype=dtype), "w_o": ff(ks[3], D, D, dtype=dtype),
        # block-diagonal recurrent mats, per head
        "r_z": (jax.random.normal(ks[4], (H, hd, hd)) / jnp.sqrt(hd)).astype(dtype),
        "r_i": (jax.random.normal(ks[5], (H, hd, hd)) / jnp.sqrt(hd)).astype(dtype),
        "r_f": (jax.random.normal(ks[6], (H, hd, hd)) / jnp.sqrt(hd)).astype(dtype),
        "r_o": (jax.random.normal(ks[7], (H, hd, hd)) / jnp.sqrt(hd)).astype(dtype),
        "b_z": jnp.zeros((D,), dtype), "b_i": jnp.zeros((D,), dtype),
        "b_f": jnp.full((D,), 3.0, dtype), "b_o": jnp.zeros((D,), dtype),
    }
    # gated FFN of the sLSTM block (proj factor 4/3, gated)
    ffdim = max(128, int(round(cfg.d_model * 4 / 3 / 128)) * 128)
    p["ffn"] = {
        "w_in": ff(ks[8], D, ffdim, dtype=dtype),
        "w_gate": ff(jax.random.fold_in(ks[8], 1), D, ffdim, dtype=dtype),
        "w_out": ff(jax.random.fold_in(ks[8], 2), ffdim, D, dtype=dtype),
    }
    p["ffn_norm"] = jnp.ones((D,), dtype)
    return p


def slstm_specs(cfg: ModelConfig):
    return {
        "w_z": P("data", "model"), "w_i": P("data", "model"),
        "w_f": P("data", "model"), "w_o": P("data", "model"),
        "r_z": P("model", None, None), "r_i": P("model", None, None),
        "r_f": P("model", None, None), "r_o": P("model", None, None),
        "b_z": P("model"), "b_i": P("model"), "b_f": P("model"),
        "b_o": P("model"),
        "ffn": {"w_in": P("data", "model"), "w_gate": P("data", "model"),
                "w_out": P("model", "data")},
        "ffn_norm": P(None),
    }


def slstm_cache(cfg: ModelConfig, batch: int) -> SLSTMCache:
    D = cfg.d_model
    z = jnp.zeros((batch, D), jnp.float32)
    return SLSTMCache(c=z, n=z, h=z, m=jnp.full((batch, D), -1e30, jnp.float32))


def _blockdiag(h, R):
    """h: (B, D) -> per-head matmul with R: (H, hd, hd)."""
    B = h.shape[0]
    H, hd, _ = R.shape
    return jnp.einsum("bhi,hij->bhj", h.reshape(B, H, hd),
                      R.astype(h.dtype)).reshape(B, H * hd)


def slstm_apply(p, cfg: ModelConfig, x, cache: SLSTMCache | None = None):
    B, S, D = x.shape
    wz = cm.matmul(x, p["w_z"]) + p["b_z"]
    wi = cm.matmul(x, p["w_i"]) + p["b_i"]
    wf = cm.matmul(x, p["w_f"]) + p["b_f"]
    wo = cm.matmul(x, p["w_o"]) + p["b_o"]
    state = cache if cache is not None else slstm_cache(cfg, B)

    def step(carry, xs):
        c, n, h, m = carry
        z_x, i_x, f_x, o_x = xs  # (B, D) each
        z = jnp.tanh(z_x.astype(jnp.float32) + _blockdiag(h, p["r_z"]))
        it = i_x.astype(jnp.float32) + _blockdiag(h, p["r_i"])
        ft = jax.nn.log_sigmoid(f_x.astype(jnp.float32) + _blockdiag(h, p["r_f"]))
        ot = jax.nn.sigmoid(o_x.astype(jnp.float32) + _blockdiag(h, p["r_o"]))
        m_new = jnp.maximum(ft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(ft + m - m_new)
        c = f_p * c + i_p * z
        n = f_p * n + i_p
        h = ot * c / jnp.maximum(n, 1e-6)
        return (c, n, h, m_new), h

    xs = tuple(t.transpose(1, 0, 2) for t in (wz, wi, wf, wo))
    Q = 64
    if S % Q == 0 and S > Q:  # chunked BPTT, same rationale as mLSTM
        def chunk_body(carry, chunk_xs):
            return jax.lax.scan(step, carry, chunk_xs)

        xs_c = jax.tree.map(lambda t: t.reshape(S // Q, Q, *t.shape[1:]), xs)
        (c, n, h, m), hs = jax.lax.scan(
            jax.checkpoint(chunk_body), tuple(state), xs_c)
        hs = hs.reshape(S, *hs.shape[2:])
    else:
        (c, n, h, m), hs = jax.lax.scan(step, tuple(state), xs)
    y = hs.transpose(1, 0, 2).astype(x.dtype)  # (B,S,D)
    new_cache = SLSTMCache(c, n, h, m) if cache is not None else None
    return y, new_cache


def slstm_ffn_pre_out(p, cfg: ModelConfig, x):
    """Gated-FFN hidden state entering ``ffn.w_out`` (Hessian tap)."""
    return (jax.nn.silu(cm.matmul(x, p["ffn"]["w_gate"]))
            * cm.matmul(x, p["ffn"]["w_in"]))


def slstm_ffn(p, cfg: ModelConfig, x):
    h = slstm_ffn_pre_out(p, cfg, x)
    return cm.matmul(h, p["ffn"]["w_out"]).astype(x.dtype)
