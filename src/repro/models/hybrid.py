"""Zamba2-style hybrid: Mamba2 trunk + one *shared* attention block applied
every ``shared_attn_every`` layers with per-invocation LoRA deltas.

The shared block consumes concat(hidden, initial_embedding) (2*d_model) as in
Zamba, projects back to d_model, and its weights are stored once — each of
the ``n_groups`` invocations adds its own low-rank (LoRA) delta to the QKV
projections. The trunk is scanned two-level: outer scan over groups, inner
scan over the group's mamba layers, so HLO stays O(1) in depth.

n_layers is the mamba-layer count and must be divisible by
``shared_attn_every`` (configs round 81 -> 78; DESIGN.md §5).
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, common as cm, ssm


def _groups(cfg: ModelConfig) -> tuple[int, int]:
    per = cfg.shared_attn_every
    assert per > 0 and cfg.n_layers % per == 0, (cfg.n_layers, per)
    return cfg.n_layers // per, per


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cm.DTYPES[cfg.dtype]
    n_groups, per = _groups(cfg)
    D, H, KV, hd, r = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd,
                       cfg.shared_attn_lora_rank)
    ks = jax.random.split(key, 6)
    layer_keys = jax.random.split(ks[0], cfg.n_layers).reshape(n_groups, per, 2)
    params = {
        "embed": cm.embed_init(ks[1], cfg.padded_vocab, D, dtype),
        "final_norm": jnp.ones((D,), dtype),
        "lm_head": cm.dense_init(ks[2], D, cfg.padded_vocab, dtype=dtype),
        # trunk: (n_groups, per, ...) stacked mamba layers
        "mamba": jax.vmap(jax.vmap(
            lambda k: {"norm": jnp.ones((D,), dtype),
                       "mixer": ssm.init(k, cfg, dtype)}))(layer_keys),
        # shared attention block over concat(h, emb0) = 2D input
        "shared": {
            "norm": jnp.ones((2 * D,), dtype),
            "attn": attention.init(ks[3], cfg, dtype, d_in=2 * D),
        },
        # per-invocation LoRA on q/k/v
        "lora": {
            name: {
                "A": (jax.random.normal(ks[4], (n_groups, 2 * D, r)) * 0.02
                      ).astype(dtype),
                "B": jnp.zeros((n_groups, r, dim), dtype),
            }
            for name, dim in (("q", H * hd), ("k", KV * hd), ("v", KV * hd))
        },
    }
    return params


def param_specs(cfg: ModelConfig) -> dict:
    n_groups, per = _groups(cfg)
    mamba_one = {"norm": P(None), "mixer": ssm.specs(cfg)}
    return {
        "embed": P("model", "data"),
        "final_norm": P(None),
        "lm_head": P("data", "model"),
        "mamba": jax.tree.map(lambda s: P(None, None, *s), mamba_one,
                              is_leaf=lambda x: isinstance(x, P)),
        "shared": {"norm": P(None), "attn": attention.specs(cfg)},
        "lora": {
            name: {"A": P(None, "data", None), "B": P(None, None, "model")}
            for name in ("q", "k", "v")
        },
    }


class HybridCache(NamedTuple):
    mamba: Any        # SSMCache stacked (n_groups, per, ...)
    attn: Any         # KVCache stacked (n_groups, ...)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype=jnp.bfloat16,
               paged=None):
    n_groups, per = _groups(cfg)
    m1 = ssm.init_cache(cfg, batch)
    # mamba state is O(1) per slot and stays slot-resident; only the shared
    # attention block's KV leaves page when serving
    a1 = (attention.init_paged_cache(cfg, batch, max_len, paged, dtype)
          if paged is not None
          else attention.init_cache(cfg, batch, max_len, dtype))
    return HybridCache(
        mamba=jax.tree.map(
            lambda x: jnp.broadcast_to(x[None, None], (n_groups, per, *x.shape)),
            m1),
        attn=jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (n_groups, *x.shape)), a1),
    )


def cache_specs(cfg: ModelConfig, seq_shard: bool = False):
    # mamba state: (n_groups, per, B, P, hd, N); conv: (n_groups, per, B, w-1, ch)
    m = ssm.SSMCache(
        h=P(None, None, ("pod", "data"), "model", None, None),
        conv=P(None, None, ("pod", "data"), None, "model"),
    )
    seq_axis = "data" if seq_shard else None
    batch_axes = ("pod",) if seq_shard else ("pod", "data")
    a = attention.KVCache(
        k=P(None, batch_axes, seq_axis, "model", None),
        v=P(None, batch_axes, seq_axis, "model", None),
    )
    return HybridCache(mamba=m, attn=a)


def lora_attn_params(p, lora_g, cfg: ModelConfig):
    """Shared-attention params with one group's LoRA delta folded in.

    ``lora_g`` is the per-group slice of the "lora" tree. The base weights
    stay shared (and may arrive dequantized from VQ); the low-rank A @ B
    delta is added densely per invocation.
    """
    attn_p = dict(p["attn"])
    for name, wname in (("q", "wq"), ("k", "wk"), ("v", "wv")):
        A, B = lora_g[name]["A"], lora_g[name]["B"]
        attn_p[wname] = attn_p[wname] + A @ B
    return attn_p


def shared_attn_input(p, cfg: ModelConfig, h, emb0):
    """The normalized concat(hidden, initial-embedding) stream entering the
    shared block's q/k/v — the Hessian tap for its projections."""
    xin = jnp.concatenate([h, emb0], axis=-1)
    return cm.rmsnorm(xin, p["norm"], cfg.norm_eps)


def _shared_attn(p, lora_g, cfg: ModelConfig, h, emb0, *, pos, kv_cache,
                 paged_impl=None):
    """One invocation of the shared block with this group's LoRA delta."""
    xin = shared_attn_input(p, cfg, h, emb0)
    attn_p = lora_attn_params(p, lora_g, cfg)
    y, new_kv = attention.apply(attn_p, cfg, xin, pos=pos, cache=kv_cache,
                                paged_impl=paged_impl)
    return y, new_kv


def _loop_groups(params, cfg: ModelConfig, x, emb0, cache_in, has_cache,
                 pos, remat, paged_impl=None):
    """Python-loop trunk for a heterogeneous (list-of-lists) mamba tree.

    Cache layout in and out matches the scan path exactly — stacked
    (n_groups, per, ...) mamba state and (n_groups, ...) shared-attn KV —
    so jitted serving carries are structure-stable either way.
    """
    from repro.core import vq_linear as vql_mod
    h = x
    new_m_groups, new_kv_groups = [], []
    for g, group_p in enumerate(params["mamba"]):
        lora_g = jax.tree.map(lambda a: a[g], params["lora"])
        a_cache = (jax.tree.map(lambda a: a[g], cache_in.attn)
                   if has_cache and cache_in.attn is not None else None)

        def one_group(h, group_p=group_p, lora_g=lora_g, a_cache=a_cache,
                      g=g):
            ha, new_kv = _shared_attn(params["shared"], lora_g, cfg, h,
                                      emb0, pos=pos, kv_cache=a_cache,
                                      paged_impl=paged_impl)
            h = h + ha
            new_layers = []
            for j, lp in enumerate(group_p):
                lp = vql_mod.dequant_tree(lp, cm.DTYPES[cfg.dtype])
                lc = jax.tree.map(lambda a: a[g, j], cache_in.mamba)
                y, new_c = ssm.apply(
                    lp["mixer"], cfg,
                    cm.rmsnorm(h, lp["norm"], cfg.norm_eps), lc)
                h = h + y
                new_layers.append(new_c)
            return h, (jax.tree.map(lambda *a: jnp.stack(a), *new_layers),
                       new_kv)

        fn = jax.checkpoint(one_group) if remat else one_group
        h, (new_m_g, new_kv_g) = fn(h)
        new_m_groups.append(new_m_g)
        new_kv_groups.append(new_kv_g)
    new_m = jax.tree.map(lambda *a: jnp.stack(a), *new_m_groups)
    new_kv = (jax.tree.map(lambda *a: jnp.stack(a), *new_kv_groups)
              if has_cache and cache_in.attn is not None else None)
    return h, new_m, new_kv


def forward(params, cfg: ModelConfig, tokens, *, pos=0, cache=None,
            extra_embeds=None, remat: bool = True, last_only: bool = False,
            paged_impl: str | None = None,
            vq_matmul_impl: str | None = None):
    from repro.core import vq_linear as vql_mod
    if vq_matmul_impl is not None:
        params = vql_mod.retag_fused(params, vq_matmul_impl)
    n_groups, per = _groups(cfg)
    top = {k: v for k, v in params.items() if k not in ("mamba",)}
    # the shared attention block must be DENSE at apply time (per-group
    # LoRA deltas are added onto the base q/k/v matrices), so fused leaves
    # in the top tree densify here; the mamba trunk keeps its fused leaves
    params = {**params,
              **vql_mod.dequant_tree(top, cm.DTYPES[cfg.dtype],
                                     densify_fused=True)}
    x = params["embed"][tokens]
    # pin batch sharding after the embedding gather — GSPMD otherwise falls
    # back to replication ("involuntary full rematerialization"), blowing
    # per-device activations up by the DP degree (§Perf iteration 5)
    from repro.models.transformer import _axes_size, _dp_axes
    dp = _dp_axes()
    if dp and tokens.shape[0] % _axes_size(dp) == 0:
        x = jax.lax.with_sharding_constraint(x, P(dp, None, None))
    emb0 = x  # original embedding, re-fed to every shared-block invocation

    cache_in = cache if cache is not None else HybridCache(
        mamba=jax.tree.map(
            lambda s: jnp.zeros((n_groups, per, *s.shape[2:]), s.dtype),
            init_cache(cfg, tokens.shape[0], 8).mamba),
        attn=None,
    )

    def group_body(h, xs):
        from repro.core import vq_linear as vql_mod
        group_p, lora_g, m_cache, a_cache = xs
        ha, new_kv = _shared_attn(
            params["shared"], lora_g, cfg, h, emb0, pos=pos,
            kv_cache=a_cache, paged_impl=paged_impl)
        h = h + ha

        def layer_body(hh, layer_xs):
            lp, lc = layer_xs
            lp = vql_mod.dequant_tree(lp, cm.DTYPES[cfg.dtype])
            y, new_c = ssm.apply(lp["mixer"], cfg,
                                 cm.rmsnorm(hh, lp["norm"], cfg.norm_eps), lc)
            return hh + y, new_c

        h, new_m = jax.lax.scan(layer_body, h, (group_p, m_cache))
        return h, (new_m, new_kv)

    if isinstance(params["mamba"], list):
        # heterogeneous trunk (mixed quantization recipe): the per-layer
        # packed metadata cannot ride a scan, so loop groups/layers in
        # python, slicing the (still homogeneous) stacked cache per layer
        # and stacking the new state back into the carry layout
        x, new_m, new_kv = _loop_groups(params, cfg, x, emb0, cache_in,
                                        cache is not None, pos, remat,
                                        paged_impl=paged_impl)
    else:
        body = jax.checkpoint(group_body) if remat else group_body
        x, (new_m, new_kv) = jax.lax.scan(
            body, x, (params["mamba"], params["lora"],
                      cache_in.mamba,
                      cache_in.attn if cache is not None else None))
    if last_only:
        x = x[:, -1:]
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = cm.matmul(x, params["lm_head"]).astype(jnp.float32)
    new_cache = HybridCache(mamba=new_m, attn=new_kv) if cache is not None else None
    return logits, new_cache, jnp.zeros((), jnp.float32)
