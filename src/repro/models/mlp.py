"""Dense MLP blocks (gated SwiGLU/GeGLU, relu^2, gelu)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm


def init(key, cfg: ModelConfig, dtype=jnp.float32, d_ff: int | None = None):
    D, F = cfg.d_model, d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {
        "w_in": cm.dense_init(ks[0], D, F, dtype=dtype),
        "w_out": cm.dense_init(ks[1], F, D, dtype=dtype),
    }
    if cm.is_gated(cfg.activation):
        p["w_gate"] = cm.dense_init(ks[2], D, F, dtype=dtype)
    return p


def specs(cfg: ModelConfig):
    s = {"w_in": P("data", "model"), "w_out": P("model", "data")}
    if cm.is_gated(cfg.activation):
        s["w_gate"] = P("data", "model")
    return s


def pre_out(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    """Hidden activations entering ``w_out`` — the Hessian tap for the down
    projection (core/adapters/*)."""
    act = cm.act_fn(cfg.activation)
    h = cm.matmul(x, p["w_in"])
    if cm.is_gated(cfg.activation):
        h = act(cm.matmul(x, p["w_gate"])) * h
    else:
        h = act(h)
    return h


def apply(p, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    return cm.matmul(pre_out(p, cfg, x), p["w_out"]).astype(x.dtype)
