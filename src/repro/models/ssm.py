"""Mamba2 (SSD) block: chunk-parallel training/prefill + recurrent decode.

Canonical single-group Mamba2 head structure:
  d_inner = expand * d_model, heads P = d_inner / ssm_head_dim, state N.
  in_proj -> [z (gate, d_inner) | x (d_inner) | B (N) | C (N) | dt (P)]
  causal depthwise conv(width w) over [x|B|C]; A = -exp(A_log) per head.

Chunked SSD (Dao & Gu 2024), chunk Q:
  a_t = dt_t * A (log decay),  cum = within-chunk cumsum
  intra: Y[i] += sum_{j<=i} (C_i . B_j) exp(cum_i - cum_j) dt_j x_j
  state: S_k = sum_j exp(cum_last - cum_j) dt_j B_j (x) x_j
  inter: H_{k+1} = exp(sum_k) H_k + S_k   (lax.scan over chunks)
         Y[i] += C_i . (exp(cum_i) H_k)

Decode carries (h, conv_state) per layer — O(1) per token, which is what
makes the ``long_500k`` cell runnable for the ssm/hybrid families.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import common as cm


class SSMCache(NamedTuple):
    h: jax.Array     # (B, P, hd, N) recurrent state
    conv: jax.Array  # (B, w-1, conv_ch) rolling conv inputs


def _dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    heads = d_inner // cfg.ssm_head_dim
    conv_ch = d_inner + 2 * cfg.ssm_state
    return d_inner, heads, conv_ch


def init(key, cfg: ModelConfig, dtype=jnp.float32):
    D = cfg.d_model
    d_inner, heads, conv_ch = _dims(cfg)
    N = cfg.ssm_state
    ks = jax.random.split(key, 5)
    proj_out = 2 * d_inner + 2 * N + heads
    p = {
        "in_proj": cm.dense_init(ks[0], D, proj_out, dtype=dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv_width, conv_ch))
                   * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((heads,), jnp.float32),
        "D_skip": jnp.ones((heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "out_proj": cm.dense_init(ks[2], d_inner, D, dtype=dtype),
    }
    return p


def specs(cfg: ModelConfig):
    return {
        "in_proj": P("data", "model"),
        "conv_w": P(None, "model"),
        "conv_b": P("model"),
        "A_log": P(None),
        "dt_bias": P(None),
        "D_skip": P(None),
        "norm_scale": P("model"),
        "out_proj": P("model", "data"),
    }


def init_cache(cfg: ModelConfig, batch: int, dtype=jnp.float32) -> SSMCache:
    d_inner, heads, conv_ch = _dims(cfg)
    return SSMCache(
        h=jnp.zeros((batch, heads, cfg.ssm_head_dim, cfg.ssm_state), dtype),
        conv=jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    )


def _split_proj(cfg: ModelConfig, proj):
    d_inner, heads, _ = _dims(cfg)
    N = cfg.ssm_state
    z, xc, Bc, Cc, dt = jnp.split(
        proj, [d_inner, 2 * d_inner, 2 * d_inner + N, 2 * d_inner + 2 * N],
        axis=-1)
    return z, xc, Bc, Cc, dt


def _causal_conv(u, w, b):
    """u: (B, S, C) already left-padded; depthwise width-k conv."""
    k = w.shape[0]
    S = u.shape[1] - (k - 1)
    out = jnp.zeros((u.shape[0], S, u.shape[2]), jnp.float32)
    for i in range(k):
        out = out + u[:, i : i + S].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out + b.astype(jnp.float32)


def _ssd_chunked(xh, Bm, Cm, dt, A, chunk, h0=None):
    """Chunk-parallel SSD scan.

    xh: (B,S,P,hd)  Bm/Cm: (B,S,N)  dt: (B,S,P)  A: (P,) negative.
    ``h0`` (B,P,hd,N) resumes from a cached state (chunked prefill: the
    serving engine feeds a long prompt in several forward calls).
    Returns y: (B,S,P,hd) and final state (B,P,hd,N).
    """
    Bsz, S, Ph, hd = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q
    a = dt * A[None, None, :]  # (B,S,P) log decay, <= 0
    xd = xh * dt[..., None]    # dt-weighted inputs

    # reshape into chunks
    def c(t):
        return t.reshape(Bsz, nc, Q, *t.shape[2:])

    ac, xc_, Bc, Cc = c(a), c(xd), c(Bm), c(Cm)
    cum = jnp.cumsum(ac, axis=2)  # (B,nc,Q,P)

    # intra-chunk: scores[b,n,p,i,j] = (C_i.B_j) * exp(cum_i - cum_j) , i>=j
    # The (Q,Q) decay tile is materialized per head (lax.map) to keep the
    # working set at B*nc*Q*Q floats instead of *P times that.
    cb = jnp.einsum("bnqs,bnts->bnqt", Cc, Bc)  # (B,nc,Q,Q) shared heads
    causal = jnp.tril(jnp.ones((Q, Q), bool))[None, None]

    def intra_head(args):
        cum_p, xd_p = args  # (B,nc,Q), (B,nc,Q,hd)
        decay = cum_p[:, :, :, None] - cum_p[:, :, None, :]
        # mask the exponent (not the product): exp of the anti-causal half
        # would overflow and poison the backward pass through jnp.where
        decay = jnp.where(causal, decay, -1e30)
        Wp = cb * jnp.exp(decay)
        return jnp.einsum("bnqt,bnth->bnqh", Wp, xd_p)

    y_intra = jax.lax.map(
        intra_head,
        (cum.transpose(3, 0, 1, 2), xc_.transpose(3, 0, 1, 2, 4)),
    ).transpose(1, 2, 3, 0, 4)  # (B,nc,Q,P,hd)

    # chunk-final states: S_k = sum_j exp(cum_last - cum_j) B_j (x) xd_j
    last = cum[:, :, -1:, :]  # (B,nc,1,P)
    w_j = jnp.exp(last - cum)  # (B,nc,Q,P)
    # two-step contraction: a single 3-operand einsum here materializes a
    # (B,nc,Q,P,hd,N) intermediate (~4.8 GB/layer at zamba2 scale)
    xw = xc_ * w_j[..., None]  # (B,nc,Q,P,hd)
    Sk = jnp.einsum("bnqs,bnqph->bnphs", Bc, xw)  # (B,nc,P,hd,N)

    # inter-chunk recurrence over nc
    seg = jnp.exp(jnp.sum(ac, axis=2))  # (B,nc,P) chunk total decay

    def chunk_step(H, xs):
        seg_k, Sk_k = xs  # (B,P), (B,P,hd,N)
        H_out = H  # state entering this chunk
        H = H * seg_k[..., None, None] + Sk_k
        return H, H_out

    H0 = (jnp.zeros((Bsz, Ph, hd, N), jnp.float32) if h0 is None
          else h0.astype(jnp.float32))
    Hfin, Hin = jax.lax.scan(
        chunk_step,
        H0,
        (seg.transpose(1, 0, 2), Sk.transpose(1, 0, 2, 3, 4)),
    )
    Hin = Hin.transpose(1, 0, 2, 3, 4)  # (B,nc,P,hd,N) state entering chunk

    # inter contribution: y[i] += (exp(cum_i) * C_i) . H_in
    # (same reassociation: contract over the state dim FIRST)
    y_inter = jnp.einsum("bnqs,bnphs->bnqph", Cc, Hin) * jnp.exp(cum)[..., None]
    y = (y_intra + y_inter).reshape(Bsz, S, Ph, hd)
    return y, Hfin


def ssd_reference(xh, Bm, Cm, dt, A):
    """Sequential SSD recurrence (oracle for _ssd_chunked tests)."""
    Bsz, S, Ph, hd = xh.shape
    N = Bm.shape[-1]

    def step(h, xs):
        x_t, B_t, C_t, dt_t = xs  # (B,P,hd), (B,N), (B,N), (B,P)
        da = jnp.exp(dt_t * A[None, :])
        h = h * da[..., None, None] + jnp.einsum(
            "bph,bs->bphs", x_t * dt_t[..., None], B_t)
        y = jnp.einsum("bphs,bs->bph", h, C_t)
        return h, y

    h0 = jnp.zeros((Bsz, Ph, hd, N), jnp.float32)
    hf, ys = jax.lax.scan(
        step, h0,
        (xh.transpose(1, 0, 2, 3).astype(jnp.float32),
         Bm.transpose(1, 0, 2).astype(jnp.float32),
         Cm.transpose(1, 0, 2).astype(jnp.float32),
         dt.transpose(1, 0, 2).astype(jnp.float32)),
    )
    return ys.transpose(1, 0, 2, 3), hf


def pre_out(p, cfg: ModelConfig, x: jax.Array, cache: SSMCache | None = None):
    """Mamba2 mixer up to (but not including) ``out_proj``.

    Returns (y, new_cache) with y: (B, S, d_inner) — the gated, normalized
    scan output that feeds the output projection. This is the Hessian tap
    for quantizing ``out_proj`` (core/adapters/*); the conv/scan parameters
    (conv_w, A_log, dt_bias, D_skip, norm_scale) are not matmul weights and
    stay dense.
    """
    Bsz, S, D = x.shape
    d_inner, heads, conv_ch = _dims(cfg)
    N, hd, w = cfg.ssm_state, cfg.ssm_head_dim, cfg.ssm_conv_width
    proj = cm.matmul(x, p["in_proj"])
    z, xc, Bc, Cc, dt = _split_proj(cfg, proj)
    A = -jnp.exp(p["A_log"])  # (P,)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])

    u = jnp.concatenate([xc, Bc, Cc], axis=-1)  # (B,S,conv_ch)
    if cache is not None:
        pad = cache.conv.astype(u.dtype)
    else:
        pad = jnp.zeros((Bsz, w - 1, conv_ch), u.dtype)
    u_pad = jnp.concatenate([pad, u], axis=1)
    new_conv = u_pad[:, -(w - 1):, :]
    conv = jax.nn.silu(_causal_conv(u_pad, p["conv_w"], p["conv_b"]))
    xcv, Bcv, Ccv = jnp.split(conv, [d_inner, d_inner + N], axis=-1)
    xh = xcv.reshape(Bsz, S, heads, hd)

    if S == 1 and cache is not None:
        # recurrent decode step
        h = cache.h.astype(jnp.float32)
        dt1 = dt[:, 0]  # (B,P)
        da = jnp.exp(dt1 * A[None, :])  # (B,P)
        Bx = jnp.einsum("bph,bs->bphs", xh[:, 0] * dt1[..., None], Bcv[:, 0])
        h = h * da[..., None, None] + Bx
        y = jnp.einsum("bphs,bs->bph", h, Ccv[:, 0])[:, None]  # (B,1,P,hd)
        Hfin = h
    else:
        # chunk-parallel prefill; resumes from the cached state so the
        # serving engine can feed a prompt in several chunked calls
        y, Hfin = _ssd_chunked(xh, Bcv, Ccv, dt, A, cfg.ssm_chunk,
                               h0=cache.h if cache is not None else None)
    y = y + xh.astype(jnp.float32) * p["D_skip"][None, None, :, None]
    y = y.reshape(Bsz, S, d_inner)
    y = cm.rmsnorm(y.astype(x.dtype) * jax.nn.silu(z), p["norm_scale"],
                   cfg.norm_eps)
    new_cache = SSMCache(h=Hfin.astype(jnp.float32), conv=new_conv) \
        if cache is not None else None
    return y, new_cache


def apply(p, cfg: ModelConfig, x: jax.Array, cache: SSMCache | None = None):
    """Mamba2 mixer. x: (B,S,D). Returns (y, new_cache)."""
    y, new_cache = pre_out(p, cfg, x, cache)
    out = cm.matmul(y, p["out_proj"])
    return out.astype(x.dtype), new_cache
