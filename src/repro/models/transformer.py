"""Decoder-only LM assembly: dense GQA, MoE, and xLSTM block stacks.

Homogeneous stacks (dense/MoE) are stored with a leading layer axis and
applied with ``lax.scan`` (+ remat) — essential to keep HLO size and compile
time flat in depth (80-layer qwen2-72b on 512 devices). Heterogeneous stacks
(xLSTM's mLSTM/sLSTM mix) are unrolled python-side; those archs are shallow.

Cache pytrees mirror the layer structure: stacked leaves for scanned stacks,
lists for unrolled ones.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.models import attention, common as cm, mlp, moe, xlstm


# ---------------------------------------------------------------------------
# block kinds
# ---------------------------------------------------------------------------

def block_kind(cfg: ModelConfig, i: int) -> str:
    if cfg.family == "ssm" and cfg.name.startswith("xlstm"):
        return "slstm" if i in tuple(cfg.slstm_layers) else "mlstm"
    if cfg.family == "moe":
        return "moe"
    return "dense"


def homogeneous(cfg: ModelConfig) -> bool:
    kinds = {block_kind(cfg, i) for i in range(cfg.n_layers)}
    return len(kinds) == 1 and next(iter(kinds)) in ("dense", "moe")


# ---------------------------------------------------------------------------
# per-block init / specs / apply
# ---------------------------------------------------------------------------

def _block_init(key, cfg: ModelConfig, kind: str, dtype):
    k1, k2 = jax.random.split(key)
    if kind in ("dense", "moe"):
        p = {
            "norm1": jnp.ones((cfg.d_model,), dtype),
            "attn": attention.init(k1, cfg, dtype),
            "norm2": jnp.ones((cfg.d_model,), dtype),
        }
        p["ffn"] = (moe.init(k2, cfg, dtype) if kind == "moe"
                    else mlp.init(k2, cfg, dtype))
        return p
    if kind == "mlstm":
        return {"norm1": jnp.ones((cfg.d_model,), dtype),
                "core": xlstm.mlstm_init(k1, cfg, dtype)}
    if kind == "slstm":
        return {"norm1": jnp.ones((cfg.d_model,), dtype),
                "core": xlstm.slstm_init(k1, cfg, dtype)}
    raise ValueError(kind)


def _block_specs(cfg: ModelConfig, kind: str):
    if kind in ("dense", "moe"):
        return {
            "norm1": P(None),
            "attn": attention.specs(cfg),
            "norm2": P(None),
            "ffn": moe.specs(cfg) if kind == "moe" else mlp.specs(cfg),
        }
    if kind == "mlstm":
        return {"norm1": P(None), "core": xlstm.mlstm_specs(cfg)}
    if kind == "slstm":
        return {"norm1": P(None), "core": xlstm.slstm_specs(cfg)}
    raise ValueError(kind)


def _block_apply(p, cfg: ModelConfig, kind: str, x, *, pos, cache,
                 paged_impl=None):
    """Returns (x, new_cache, aux_loss)."""
    from jax.ad_checkpoint import checkpoint_name

    from repro.core import vq_linear as vql_mod
    p = vql_mod.dequant_tree(p, cm.DTYPES[cfg.dtype])  # no-op if not VQ
    aux = jnp.zeros((), jnp.float32)
    if kind in ("dense", "moe"):
        h, new_kv = attention.apply(
            p["attn"], cfg, cm.rmsnorm(x, p["norm1"], cfg.norm_eps),
            pos=pos, cache=cache, paged_impl=paged_impl)
        # named so the selective remat policy can save it (§Perf it.9):
        # backward then skips re-running the flash-attention scan
        h = checkpoint_name(h, "attn_out")
        x = x + h
        h2 = cm.rmsnorm(x, p["norm2"], cfg.norm_eps)
        if kind == "moe":
            f, aux = moe.apply(p["ffn"], cfg, h2)
        else:
            f = mlp.apply(p["ffn"], cfg, h2)
        return x + f, new_kv, aux
    if kind == "mlstm":
        h, new_c = xlstm.mlstm_apply(
            p["core"], cfg, cm.rmsnorm(x, p["norm1"], cfg.norm_eps), cache)
        return x + h, new_c, aux
    if kind == "slstm":
        xin = cm.rmsnorm(x, p["norm1"], cfg.norm_eps)
        h, new_c = xlstm.slstm_apply(p["core"], cfg, xin, cache)
        x = x + h
        x = x + xlstm.slstm_ffn(
            p["core"], cfg, cm.rmsnorm(x, p["core"]["ffn_norm"], cfg.norm_eps))
        return x, new_c, aux
    raise ValueError(kind)


def _block_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int,
                 dtype=jnp.bfloat16, paged=None):
    if kind in ("dense", "moe"):
        if paged is not None:
            return attention.init_paged_cache(cfg, batch, max_len, paged,
                                              dtype)
        return attention.init_cache(cfg, batch, max_len, dtype)
    # recurrent state is O(1) per slot — stays slot-resident even when the
    # attention leaves are paged
    if kind == "mlstm":
        return xlstm.mlstm_cache(cfg, batch)
    if kind == "slstm":
        return xlstm.slstm_cache(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# whole-model init / specs
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cm.DTYPES[cfg.dtype]
    k_emb, k_layers, k_head = jax.random.split(key, 3)
    params: dict[str, Any] = {
        "embed": cm.embed_init(k_emb, cfg.padded_vocab, cfg.d_model, dtype),
        "final_norm": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(
            k_head, cfg.d_model, cfg.padded_vocab, dtype=dtype)
    keys = jax.random.split(k_layers, cfg.n_layers)
    if homogeneous(cfg):
        kind = block_kind(cfg, 0)
        params["layers"] = jax.vmap(
            lambda k: _block_init(k, cfg, kind, dtype))(keys)
    else:
        params["layers"] = [
            _block_init(keys[i], cfg, block_kind(cfg, i), dtype)
            for i in range(cfg.n_layers)
        ]
    return params


def param_specs(cfg: ModelConfig) -> dict:
    specs: dict[str, Any] = {
        "embed": P("model", "data"),
        "final_norm": P(None),
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P("data", "model")
    if homogeneous(cfg):
        kind = block_kind(cfg, 0)
        one = _block_specs(cfg, kind)
        specs["layers"] = jax.tree.map(
            lambda s: P(None, *s), one,
            is_leaf=lambda x: isinstance(x, P))
    else:
        specs["layers"] = [
            _block_specs(cfg, block_kind(cfg, i)) for i in range(cfg.n_layers)
        ]
    return specs


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               dtype=jnp.bfloat16, paged=None):
    """``paged``: an attention.PagedLayout — attention leaves become shared
    block pools + per-slot page tables (serving); None keeps the dense
    (B, max_len) layout (training/eval)."""
    if homogeneous(cfg):
        kind = block_kind(cfg, 0)
        one = _block_cache(cfg, kind, batch, max_len, dtype, paged)
        return jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (cfg.n_layers, *x.shape)), one)
    return [
        _block_cache(cfg, block_kind(cfg, i), batch, max_len, dtype, paged)
        for i in range(cfg.n_layers)
    ]


def cache_specs(cfg: ModelConfig):
    """Sharding for KV caches: batch over (pod, data), heads over model.

    For long-context single-sequence decode the sequence dim of attention
    caches is sharded over 'data' instead (sequence parallelism) — see
    launch/dryrun.py which picks the spec based on the shape cell.
    """
    def kv_spec(_):
        return P(None, ("pod", "data"), None, "model", None) \
            if homogeneous(cfg) else P(("pod", "data"), None, "model", None)

    if homogeneous(cfg):
        one = _block_cache(cfg, block_kind(cfg, 0), 1, 8)
        return jax.tree.map(lambda x: kv_spec(x), one)
    out = []
    for i in range(cfg.n_layers):
        kind = block_kind(cfg, i)
        one = _block_cache(cfg, kind, 1, 8)
        if kind in ("dense", "moe"):
            out.append(jax.tree.map(lambda x: P(("pod", "data"), None, "model", None), one))
        else:
            out.append(jax.tree.map(lambda x: P(("pod", "data")), one))
    return out


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def embed_tokens(params, cfg: ModelConfig, tokens, extra_embeds=None):
    x = params["embed"][tokens]  # gather
    if extra_embeds is not None:
        x = jnp.concatenate([extra_embeds.astype(x.dtype), x], axis=1)
    return x


def unembed(params, cfg: ModelConfig, x):
    w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return cm.matmul(x, w).astype(jnp.float32)


def forward(
    params,
    cfg: ModelConfig,
    tokens: jax.Array,
    *,
    pos: jax.Array | int = 0,
    cache=None,
    extra_embeds=None,
    remat: bool = True,
    last_only: bool = False,
    paged_impl: str | None = None,
    vq_matmul_impl: str | None = None,
):
    """Returns (logits, new_cache, aux_loss). ``paged_impl`` selects the
    decode attention backend over PagedKVCache leaves (see
    attention._paged_apply); None falls back to the module default.
    ``vq_matmul_impl`` re-stamps FusedVQLinear leaves ("gather" | "xla" |
    "pallas" | "fused") — static metadata only, so each jitted closure
    bakes its own VQ backend (see core/vq_linear)."""
    from repro.core import vq_linear as vql_mod
    if vq_matmul_impl is not None:
        params = vql_mod.retag_fused(params, vq_matmul_impl)
    top = {k: v for k, v in params.items() if k != "layers"}
    params = {**params, **vql_mod.dequant_tree(top, cm.DTYPES[cfg.dtype])}
    x = embed_tokens(params, cfg, tokens, extra_embeds)
    dp = _dp_axes()
    if dp and tokens.shape[0] % _axes_size(dp) == 0:
        x = jax.lax.with_sharding_constraint(x, P(dp, None, None))

    # mixed-precision recipes (core/recipe.py) make per-layer packed
    # metadata heterogeneous, so finalize falls back to a list of layer
    # trees even for a homogeneous stack — the layer loop below handles
    # that (and slices/updates a stacked cache per layer); the scan fast
    # path needs the layers actually stacked.
    layers_stacked = not isinstance(params["layers"], list)
    if homogeneous(cfg) and layers_stacked:
        kind = block_kind(cfg, 0)

        if cache is None:
            # Megatron-style sequence parallelism at layer boundaries: the
            # scan carry (the only tensor live for every layer's backward
            # residuals) shards its seq dim over 'model' instead of being
            # replicated — 16x less stored activation at qwen2-72b scale
            # (§Perf iteration 2). XLA re-gathers inside the block where
            # attention needs the full sequence. (The MoE shard_map path
            # re-gathers the sequence at its boundary — in_specs are
            # authoritative — so SP composes with expert parallelism.)
            sp = (_dp_axes() is not None
                  and x.shape[1] % _axes_size(("model",)) == 0)

            def body(carry, layer_p):
                h = carry
                if sp:
                    h = jax.lax.with_sharding_constraint(
                        h, P(_dp_axes(), "model", None))
                h, new_c, aux = _block_apply(
                    layer_p, cfg, kind, h, pos=pos, cache=None)
                return h, aux

            if remat == "save_attn":
                # selective remat: keep the per-layer attention outputs
                # resident so backward recompute skips the attention fwd
                # (the expensive part of the 1.33x re-forward budget) at
                # the cost of one extra (B,S,D) per layer (§Perf it.9)
                policy = jax.checkpoint_policies.save_only_these_names(
                    "attn_out")
                body_fn = jax.checkpoint(body, policy=policy)
            elif remat:
                body_fn = jax.checkpoint(body)
            else:
                body_fn = body
            x, auxs = jax.lax.scan(body_fn, x, params["layers"])
            new_cache = None
        else:
            # cache travels in the CARRY and is updated layer-slice in
            # place: with donated inputs XLA aliases the whole ring of
            # buffers, halving decode HBM vs a scan-ys cache (EXPERIMENTS
            # §Perf iteration 1).
            def body(carry, layer_p):
                h, cache_all, i = carry
                layer_cache = jax.tree.map(
                    lambda a: jax.lax.dynamic_index_in_dim(
                        a, i, 0, keepdims=False), cache_all)
                h, new_c, aux = _block_apply(
                    layer_p, cfg, kind, h, pos=pos, cache=layer_cache,
                    paged_impl=paged_impl)
                cache_all = jax.tree.map(
                    lambda a, n: jax.lax.dynamic_update_index_in_dim(
                        a, n.astype(a.dtype), i, 0), cache_all, new_c)
                return (h, cache_all, i + 1), aux

            body_fn = jax.checkpoint(body) if remat else body
            (x, new_cache, _), auxs = jax.lax.scan(
                body_fn, (x, cache, jnp.zeros((), jnp.int32)),
                params["layers"])
        aux = jnp.sum(auxs)
    else:
        # cache layout follows init_cache: a per-layer list for
        # heterogeneous configs, a layer-stacked tree for homogeneous
        # configs whose params went heterogeneous (mixed recipe)
        cache_is_list = isinstance(cache, list)
        new_cache = [] if cache_is_list or cache is None else cache
        aux = jnp.zeros((), jnp.float32)
        for i, layer_p in enumerate(params["layers"]):
            kind = block_kind(cfg, i)
            if cache is None:
                c_i = None
            elif cache_is_list:
                c_i = cache[i]
            else:
                c_i = jax.tree.map(lambda a: a[i], cache)
            fn = functools.partial(_block_apply, layer_p, cfg, kind,
                                   pos=pos, cache=c_i,
                                   paged_impl=paged_impl)
            if remat:
                fn = jax.checkpoint(lambda h, _fn=fn: _fn(h))
            x, new_c, a = fn(x)
            if cache_is_list:
                new_cache.append(new_c)
            elif cache is not None:
                new_cache = jax.tree.map(
                    lambda a, n: a.at[i].set(n.astype(a.dtype)),
                    new_cache, new_c)
            aux = aux + a
        if cache is None:
            new_cache = None

    if last_only:
        x = x[:, -1:]  # prefill: only the next-token logits are needed —
        # avoids materializing the (B, S, V) tensor (638 TB for qwen2-72b
        # prefill_32k before this slice; see EXPERIMENTS §Dry-run)
    x = cm.rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params, cfg, x)
    dp = _dp_axes()
    if dp and logits.shape[0] % _axes_size(dp) == 0:
        logits = jax.lax.with_sharding_constraint(logits, P(dp, None, "model"))
    return logits, new_cache, aux


def _ambient_mesh():
    try:
        import jax._src.mesh as jmesh
        m = jmesh.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def _dp_axes():
    """Data-parallel axes present in the ambient mesh ('pod' on multi-pod)."""
    m = _ambient_mesh()
    if m is None:
        return None
    dp = tuple(a for a in ("pod", "data") if a in m.axis_names)
    return dp or None


def _axes_size(axes) -> int:
    m = _ambient_mesh()
    size = dict(zip(m.axis_names, m.devices.shape))
    total = 1
    for a in axes:
        total *= size[a]
    return total
