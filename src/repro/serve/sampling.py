"""Token sampling: greedy / temperature / top-k / top-p.

``temperature`` may be a python scalar, a traced scalar, or a per-row (B,)
vector — the batched serving engine mixes requests with different
temperatures in one decode tick, so each slot samples under its own. Rows
with temperature <= 0 are greedy (argmax). Jit-safe: branching on the
temperature value is pythonic only for python scalars; traced values go
through ``jnp.where`` selects.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _sample_scaled(key, logits: jax.Array, top_k: int, top_p: float):
    """Categorical draw from already temperature-scaled logits."""
    if top_k > 0:
        # rank-based cut, not a threshold against the k-th value: a
        # threshold keeps every logit tied with the k-th (more than k
        # survivors), and top_k >= V used to index out of range. Ranks
        # come from a double argsort of the descending order (stable, so
        # ties break toward the lowest vocab index — deterministic);
        # exactly min(top_k, V) candidates survive.
        k = min(top_k, logits.shape[-1])
        order = jnp.argsort(-logits, axis=-1)
        ranks = jnp.argsort(order, axis=-1)
        logits = jnp.where(ranks < k, logits, -1e30)
    if top_p > 0.0:
        sorted_l = jnp.sort(logits, axis=-1)[:, ::-1]
        probs = jax.nn.softmax(sorted_l, axis=-1)
        cum = jnp.cumsum(probs, axis=-1)
        cutoff_idx = jnp.sum(cum < top_p, axis=-1)
        cutoff = jnp.take_along_axis(sorted_l, cutoff_idx[:, None], axis=-1)
        logits = jnp.where(logits < cutoff, -1e30, logits)
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def sample(key, logits: jax.Array, *, temperature=1.0,
           top_k: int = 0, top_p: float = 0.0) -> jax.Array:
    """logits: (B, V); temperature: scalar or (B,) -> (B,) int32."""
    if isinstance(temperature, (int, float)):
        # python scalar: static branch (no tracer bool conversion)
        if temperature <= 0.0:
            return jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return _sample_scaled(key, logits / temperature, top_k, top_p)
    t = jnp.asarray(temperature, jnp.float32)
    if t.ndim == 0:
        t = t[None]
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    safe_t = jnp.where(t > 0.0, t, 1.0)
    sampled = _sample_scaled(key, logits / safe_t[:, None], top_k, top_p)
    return jnp.where(t > 0.0, sampled, greedy)
