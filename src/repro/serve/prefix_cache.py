"""Radix prefix cache over paged KV blocks (prefix sharing).

Real traffic overwhelmingly shares prompt *prefixes* — system prompts,
few-shot headers, per-tenant preambles. The paged engine's per-slot page
tables already make K/V location a pure indirection, so a warm prefix
does not need to be prefilled again: admission can point the new
sequence's page table at the physical blocks a previous request already
filled and start prefill past the shared boundary. This module owns the
host-side index that makes that lookup possible:

* **Radix tree over full pages.** Each node represents one *full* page
  (``page_size`` tokens) and is keyed by the tuple of token ids written
  into it; a node's path from the root spells a prompt prefix in
  page-size steps. Only full pages are cached — a partially-filled page
  is still written by its owner's decode ticks, so it can never be
  shared (this is the copy-on-write boundary: sharing stops strictly
  before the first page any writer can touch, so no fork ever needs a
  device-side block copy — the tail is simply prefilled privately).
* **Refcounts, not ownership.** The cache holds one reference
  (``BlockAllocator.share``) on every cached block; sequences that hit
  hold their own. A block is only reusable while its content is live,
  and the refcount is exactly that liveness: the pool reclaims it when
  the last sequence *and* the cache have released it.
* **LRU leaf eviction under pool pressure.** When an allocation fails,
  the scheduler asks the cache to give blocks back: evictable nodes are
  tree *leaves* whose block nobody but the cache references
  (``refcount == 1``), dropped oldest-touch first. Interior nodes become
  leaves as their children evict, so sustained pressure drains whole
  cold branches back to the free list while hot prefixes stay resident.

Correctness notes that the tests pin:

* Page content is a pure function of the token ids and absolute
  positions written (RoPE uses absolute positions; KV quantization is
  per-row deterministic), so a cached block is byte-identical to what
  the hitting request's own prefill would have produced — shared-prefix
  serving is token-identical to solo serving, not merely close.
* Lookup caps the shared extent at ``len(tokens) - 1`` so at least one
  real token always prefills (the engine needs last-token logits to
  sample the first output token), and so decode's first write lands
  strictly past every shared page.
* Recurrent-state families (ssm/hybrid/enc-dec) integrate every prompt
  token into slot-resident state that no page table can share; the
  engine keeps the cache inert for them (see Engine ctor) rather than
  serving a stale-state prefix.
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

from repro.serve.paged_cache import BlockAllocator


@dataclasses.dataclass(eq=False)
class PrefixNode:
    """One cached full page: ``key`` is the page's token ids, ``block``
    the physical pool block holding its K/V."""
    key: tuple
    block: int
    parent: "PrefixNode | None"
    children: dict = dataclasses.field(default_factory=dict)
    last_used: int = 0

    @property
    def depth(self) -> int:
        d, n = 0, self.parent
        while n is not None:
            d, n = d + 1, n.parent
        return d


class PrefixCache:
    """Radix tree mapping prompt prefixes (in full-page steps) to the
    physical blocks that already hold their K/V."""

    def __init__(self, allocator: BlockAllocator, page_size: int):
        self.allocator = allocator
        self.page_size = page_size
        self.root = PrefixNode(key=(), block=-1, parent=None)
        self._nodes: set[PrefixNode] = set()  # flat registry (membership)
        self._clock = 0                       # LRU touch counter
        # lazy-deletion min-heap of (last_used, tiebreak, node): every
        # touch pushes a fresh entry and leaves the old one stale in
        # place; evict_one pops in LRU order and discards entries whose
        # stamp no longer matches the node (superseded or evicted). This
        # keeps eviction O(log n) amortized — the old full-registry scan
        # plus list.remove made draining a cold cache under pool
        # pressure O(n^2).
        self._heap: list[tuple[int, int, PrefixNode]] = []
        self._heap_seq = 0
        # host-side stats (the engine mirrors these into obs/ metrics)
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.evictions = 0

    # -- helpers -----------------------------------------------------------

    def _touch(self, node: PrefixNode):
        self._clock += 1
        node.last_used = self._clock
        self._heap_seq += 1
        heapq.heappush(self._heap, (node.last_used, self._heap_seq, node))

    def _page_key(self, tokens, page: int) -> tuple:
        lo = page * self.page_size
        return tuple(int(t) for t in tokens[lo: lo + self.page_size])

    @property
    def cached_blocks(self) -> int:
        return len(self._nodes)

    def blocks(self) -> set[int]:
        """Physical blocks currently referenced by the cache (fuzz/test
        ground truth for the refcount invariants)."""
        return {n.block for n in self._nodes}

    # -- lookup / insert ---------------------------------------------------

    def lookup(self, tokens: np.ndarray) -> list[int]:
        """Longest cached prefix of ``tokens`` in full pages; returns the
        physical blocks in logical page order, with one reference taken
        on each (the caller owns releasing them — normally by putting
        them at the front of a Sequence's page list, whose pages are
        released uniformly at finish/preempt).

        At most ``(len(tokens) - 1) // page_size`` pages match: the final
        token — and any partially-filled page — always prefills privately
        so the engine gets last-token logits and decode never writes a
        shared page.
        """
        max_pages = max(0, (len(tokens) - 1) // self.page_size)
        node, blocks = self.root, []
        for page in range(max_pages):
            child = node.children.get(self._page_key(tokens, page))
            if child is None:
                break
            blocks.append(child.block)
            node = child
        if blocks:
            self.allocator.share(blocks)
            # touch leaf-to-root so LRU order can never evict an ancestor
            # of a fresher descendant first
            n = node
            while n is not self.root:
                self._touch(n)
                n = n.parent
            self.hits += 1
            self.hit_tokens += len(blocks) * self.page_size
        else:
            self.misses += 1
        return blocks

    def insert(self, tokens: np.ndarray, pages: list[int]):
        """Register a fully-prefilled prompt's full pages. ``pages`` is
        the owning sequence's physical block list (logical page order).
        Existing nodes are only LRU-touched (their block stays — content
        is identical by determinism); new nodes take one cache-owned
        reference on the sequence's block, which is what keeps the page
        alive after the sequence itself finishes and releases."""
        full = min(len(tokens) // self.page_size, len(pages))
        node = self.root
        for page in range(full):
            key = self._page_key(tokens, page)
            child = node.children.get(key)
            if child is None:
                child = PrefixNode(key=key, block=pages[page], parent=node)
                self.allocator.share([child.block])
                node.children[key] = child
                self._nodes.add(child)
            self._touch(child)
            node = child

    # -- eviction ----------------------------------------------------------

    def _evictable(self, node: PrefixNode) -> bool:
        # leaves only (evicting an interior node would orphan live
        # descendants whose lookup path runs through it), and only blocks
        # nobody but the cache still references
        return (not node.children
                and self.allocator.refcount(node.block) == 1)

    def evict_one(self) -> bool:
        """Drop the least-recently-used evictable leaf, returning its
        block to the pool. False when nothing can be evicted (every
        cached block is still shared with a live sequence).

        O(log n) amortized: pop the heap in LRU order, skipping stale
        entries (node already evicted, or its stamp superseded by a
        later touch). Entries that are current but not evictable —
        interior nodes, blocks a live sequence still holds — are set
        aside and re-pushed with their unchanged stamp, so they keep
        their LRU position and become poppable once their children
        evict or the co-holder releases."""
        deferred = []
        victim = None
        while self._heap:
            stamp, seq, node = heapq.heappop(self._heap)
            if node not in self._nodes or stamp != node.last_used:
                continue
            if not self._evictable(node):
                deferred.append((stamp, seq, node))
                continue
            victim = node
            break
        for entry in deferred:
            heapq.heappush(self._heap, entry)
        if victim is None:
            return False
        self.allocator.release([victim.block])
        del victim.parent.children[victim.key]
        self._nodes.remove(victim)
        self.evictions += 1
        return True

    def clear(self):
        """Release every cached block and reset the LRU clock and the
        hit/miss/eviction counters (engine teardown — a restarted engine
        must not report stale prefix stats)."""
        for n in self._nodes:
            self.allocator.release([n.block])
        self._nodes.clear()
        self.root.children.clear()
        self._heap.clear()
        self._heap_seq = 0
        self._clock = 0
        self.hits = 0
        self.misses = 0
        self.hit_tokens = 0
        self.evictions = 0
