"""Prefill / decode step builders.

Parameter trees may contain ``VQLinear`` leaves (bit-packed GPTVQ weights);
the model assemblies dequantize them per layer-slice inside their layer scan
(core/vq_linear.dequant_tree), so these steps are agnostic to whether the
model is dense bf16 or VQ-compressed — the paper's technique is a drop-in
serving format.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model


def make_prefill(model: Model, last_only: bool = False):
    """last_only=True returns only next-token logits — required at 32k+
    sequence lengths where full (B, S, V) logits would dominate memory."""
    def prefill(params, batch, cache):
        logits, cache, _ = model.forward(params, batch, cache=cache, pos=0,
                                         last_only=last_only)
        return logits, cache

    return prefill


def make_decode(model: Model):
    def decode(params, tokens, cache, pos):
        """tokens: (B, 1); pos: scalar position of the new token."""
        logits, cache, _ = model.forward(
            params, {"tokens": tokens}, cache=cache, pos=pos)
        return logits, cache

    return decode
