"""Prefill / decode step builders.

Parameter trees may contain ``VQLinear`` leaves (bit-packed GPTVQ
weights), dequantized per layer-slice inside the layer scan
(core/vq_linear.dequant_tree — the "gather" path), or engine-prepped
``FusedVQLinear`` leaves whose matmuls run fused (``vq_impl`` "xla" /
"pallas": the dense weight never materializes; see core/vq_linear). Either
way these steps are agnostic to whether the model is dense bf16 or
VQ-compressed — the paper's technique is a drop-in serving format.

``make_paged_decode`` / ``make_slot_prefill`` are the paged serving
engine's fully-compiled tick functions (per-slot position vectors, page
tables, chunked prefill over B=1 slot views). ``make_prefill`` /
``make_decode`` remain the dense-cache builders used by launch/dryrun and
as the correctness reference for the paged path.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.model_zoo import Model


def make_prefill(model: Model, last_only: bool = False):
    """Whole-prompt prefill from position 0 over a dense cache (dry-run and
    benchmark baselines). last_only=True returns only next-token logits —
    required at 32k+ sequence lengths where full (B, S, V) logits would
    dominate memory."""
    def prefill(params, batch, cache):
        logits, cache, _ = model.forward(params, batch, cache=cache, pos=0,
                                         last_only=last_only)
        return logits, cache

    return prefill


def make_decode(model: Model):
    def decode(params, tokens, cache, pos):
        """tokens: (B, S) int32; pos: scalar start position, or a per-slot
        (B,) vector when the cache is paged (each continuous-batching slot
        writes/attends at its own depth)."""
        logits, cache, _ = model.forward(
            params, {"tokens": tokens}, cache=cache, pos=pos)
        return logits, cache

    return decode


def make_paged_decode(model: Model, axes, paged_impl: str = "gather",
                      vq_impl: str | None = None):
    """One fully-compiled decode tick over a paged cache. ``axes`` is the
    per-leaf batch-axis tree from paged_cache.batch_axes. Folding the
    page-table refresh, the mid-prefill row restore, the PRNG split, AND
    the per-slot sampling into the jitted step keeps the tick at a single
    dispatch with a (B,) int32 device->host transfer — the eager tree-map
    variant cost more host time than the forward itself, and the separate
    sample dispatch + (B, V) logits round-trip dominated the batch=1
    decode gap vs the legacy dense engine (BENCH_serve.json).

    ``paged_impl`` is captured by the closure and threaded through the
    forward to attention._paged_apply — each engine's jitted decode bakes
    its own backend, no module-global mutation involved. ``vq_impl`` does
    the same for VQ-packed weight leaves (core/vq_linear.fused_matmul
    dispatch): the impl re-stamp is static metadata, so the backend is
    part of the traced graph."""
    from repro.serve import paged_cache as pc
    from repro.serve import sampling

    def decode(params, tokens, cache, pos, table, keep_mask, key, temps):
        """tokens (B, 1); pos (B,) per-slot write positions; table
        (B, n_pages) page rows for decoding slots (scratch elsewhere);
        keep_mask (B,) marks slots whose recurrent-state rows must keep
        their pre-tick values (slots still mid-prefill); key is the
        engine PRNG key (split in-graph, new key returned); temps (B,)
        per-slot temperatures (<= 0 greedy)."""
        cache = pc.push_page_table(cache, table)
        logits, new_cache, _ = model.forward(
            params, {"tokens": tokens}, cache=cache, pos=pos,
            paged_impl=paged_impl, vq_matmul_impl=vq_impl)
        key, sub = jax.random.split(key)
        nxt = sampling.sample(sub, logits[:, -1], temperature=temps)
        return nxt, key, pc.restore_masked(cache, new_cache, axes,
                                           keep_mask)

    return decode


def make_slot_prefill(model: Model, axes, vq_impl: str | None = None):
    """One fully-compiled chunked-prefill step: push the page table, slice
    a B=1 view of ``slot`` (traced — one trace serves every slot), run the
    chunk from position ``start``, merge the view back. Retraces only per
    power-of-two chunk width."""
    from repro.serve import paged_cache as pc

    def chunk(params, tokens, cache, slot, start, last_idx, table):
        cache = pc.push_page_table(cache, table)
        view = pc.slot_view_dyn(cache, axes, slot)
        # prefill is pinned to the gather read path — including width-1
        # tail chunks, which would otherwise satisfy the fused path's
        # S == 1 shape test
        logits, new_view, _ = model.forward(
            params, {"tokens": tokens}, cache=view,
            pos=jnp.full((1,), start, jnp.int32), paged_impl="gather",
            vq_matmul_impl=vq_impl)
        # only the last *real* token's logits ever get sampled (chunks may
        # be padded up to their power-of-two bucket) — returning (V,)
        # instead of (1, C, V) keeps the host transfer flat
        last = jax.lax.dynamic_index_in_dim(logits[0], last_idx, 0,
                                            keepdims=False)
        return last, pc.slot_merge_dyn(cache, new_view, axes, slot)

    return chunk
