"""Paged continuous-batching serving engine (allocator, scheduler, steps)."""
