"""Paged KV-cache pool management for the serving engine.

The device-side layout lives in the model layer (models/attention.py:
``PagedKVCache`` leaves inside each family's cache pytree — shared block
pools + per-slot page tables). This module owns everything host-side:

* ``BlockAllocator`` — free-list over physical block ids. Block 0 is the
  reserved scratch block (inactive slots' page-table entries point there,
  so their discarded decode writes never touch live data).
* slot views/merges — the engine prefills one request at a time with a
  B=1 view of the cache (page-table row + that slot's recurrent-state
  rows; the pools pass through shared) and merges the result back. Which
  axis of each cache leaf is the batch axis is *derived*, not guessed:
  ``batch_axes`` diffs ``eval_shape`` of ``init_cache`` at two batch sizes,
  so hybrid's (n_groups, per, B, ...) mamba leaves or any future layout
  resolve correctly even when a leading axis coincides with max_batch.
* ``push_page_table`` — broadcasts the host page table into every
  PagedKVCache leaf (the table is replicated per layer so the layer scans
  can slice it like any other cache leaf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import PagedKVCache, PagedLayout

SCRATCH_BLOCK = 0


class BlockAllocator:
    """Refcounted free-list allocator over block ids 1..num_blocks-1
    (0 is scratch).

    Every live block carries a reference count: ``alloc`` hands out fresh
    blocks at refcount 1, ``share`` takes an extra reference (prefix
    sharing: several sequences — and the radix prefix cache itself — point
    their page tables at the same physical block), and ``release`` drops
    one; a block returns to the free list only when its count reaches
    zero. ``free`` is a hardened alias of ``release`` kept for older
    callers. Releasing an unallocated or already-free id raises
    ``ValueError`` instead of silently corrupting the free list.
    """

    def __init__(self, num_blocks: int):
        assert num_blocks >= 2, "need at least scratch + one usable block"
        self.num_blocks = num_blocks
        self._free = list(range(num_blocks - 1, 0, -1))
        self._ref: dict[int, int] = {}   # block id -> refcount (>= 1)

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        """Distinct blocks currently handed out, shared or not (the
        occupancy-gauge ground truth: the engine's per-tick
        ``serve.pool_used_blocks`` must equal this, and the fuzz suite
        cross-checks both against the blocks held by active sequences
        plus the prefix cache)."""
        return self.capacity - len(self._free)

    @property
    def capacity(self) -> int:
        return self.num_blocks - 1

    @property
    def shared_blocks(self) -> int:
        """Blocks with more than one live reference (the prefix-sharing
        win: each of these would otherwise be a duplicated page)."""
        return sum(1 for c in self._ref.values() if c > 1)

    def refcount(self, block: int) -> int:
        """Live reference count of ``block`` (0 if free)."""
        return self._ref.get(block, 0)

    def alloc(self, n: int) -> list[int] | None:
        """n fresh blocks at refcount 1, or None (all-or-nothing)."""
        if n > len(self._free):
            return None
        got = [self._free.pop() for _ in range(n)]
        for b in got:
            self._ref[b] = 1
        return got

    def share(self, ids: list[int]):
        """Take one extra reference on each (already-allocated) block."""
        for b in ids:
            if b not in self._ref:
                raise ValueError(f"share of unallocated block {b}")
        for b in ids:
            self._ref[b] += 1

    def release(self, ids: list[int]):
        """Drop one reference per block; a block whose count hits zero
        returns to the free list. Raises ValueError on ids that are out
        of range, free, or never allocated (double-release protection —
        a corrupted free list hands the same block to two sequences)."""
        for b in ids:
            if not 0 < b < self.num_blocks:
                raise ValueError(f"release of invalid block id {b}")
            if b not in self._ref:
                raise ValueError(
                    f"release of block {b} that is not allocated "
                    f"(double-release or foreign id)")
        for b in ids:
            self._ref[b] -= 1
            if self._ref[b] == 0:
                del self._ref[b]
                self._free.append(b)

    def free(self, ids: list[int]):
        """Alias of ``release`` (pre-refcount name, kept for callers)."""
        self.release(ids)


# ---------------------------------------------------------------------------
# slot views over a family cache pytree
# ---------------------------------------------------------------------------

def batch_axes(model, max_batch: int, max_len: int, dtype,
               paged: PagedLayout):
    """Tree (matching the cache pytree) of per-leaf batch-axis indices;
    -1 marks leaves without a batch axis (the shared block pools)."""
    a = jax.eval_shape(
        lambda: model.init_cache(max_batch, max_len, dtype=dtype, paged=paged))
    b = jax.eval_shape(
        lambda: model.init_cache(max_batch + 1, max_len, dtype=dtype,
                                 paged=paged))

    def ax(sa, sb):
        diff = [i for i, (x, y) in enumerate(zip(sa.shape, sb.shape))
                if x != y]
        assert len(diff) <= 1, (sa.shape, sb.shape)
        return diff[0] if diff else -1

    return jax.tree.map(ax, a, b)


def _slot_idx(ndim: int, axis: int, slot: int):
    idx = [slice(None)] * ndim
    idx[axis] = slice(slot, slot + 1)
    return tuple(idx)


def slot_merge(cache, new, axes, slot: int, *, shared: bool = True):
    """Write a B=1 view back into the full cache at ``slot``.

    ``shared=True`` (after a prefill forward) takes the returned pools
    wholesale — the forward only scattered into this slot's blocks (plus
    scratch). ``shared=False`` keeps the old pools: used to reset a slot's
    recurrent state from a fresh B=1 template on admission without wiping
    other slots' live blocks.
    """
    def put(o, n, a):
        if a < 0:
            return n if shared else o
        idx = _slot_idx(o.ndim, a, slot)
        return o.at[idx].set(n.astype(o.dtype))

    return jax.tree.map(put, cache, new, axes)


def slot_view_dyn(cache, axes, slot):
    """slot_view with a *traced* slot index (jit-safe: one trace serves
    every slot). Batch-axis leaves become size-1 dynamic slices."""
    return jax.tree.map(
        lambda x, a: x if a < 0
        else jax.lax.dynamic_slice_in_dim(x, slot, 1, axis=a),
        cache, axes)


def slot_merge_dyn(cache, new, axes, slot):
    """slot_merge (shared pools taken wholesale) with a traced slot."""
    return jax.tree.map(
        lambda o, n, a: n if a < 0
        else jax.lax.dynamic_update_slice_in_dim(
            o, n.astype(o.dtype), slot, axis=a),
        cache, new, axes)


def restore_masked(old, new, axes, keep_mask):
    """Rows of batch-axis leaves where ``keep_mask`` (B,) is True keep
    their ``old`` value. The decode step uses this inside the compiled
    tick: slots still mid-prefill get decoded on garbage tokens (their
    cache writes go to scratch), so their recurrent-state rows must keep
    their pre-tick values."""
    def f(o, n, a):
        if a < 0:
            return n
        shape = [1] * n.ndim
        shape[a] = keep_mask.shape[0]
        return jnp.where(keep_mask.reshape(shape), o.astype(n.dtype), n)

    return jax.tree.map(f, old, new, axes)


def push_page_table(cache, table: np.ndarray):
    """Broadcast the host (max_batch, n_pages) table into every
    PagedKVCache leaf (replicated over any leading layer/group axes);
    pools and quantized-page scale leaves pass through untouched."""
    t = jnp.asarray(table, jnp.int32)

    def f(leaf):
        if isinstance(leaf, PagedKVCache):
            return leaf._replace(
                page_table=jnp.broadcast_to(t, leaf.page_table.shape))
        return leaf

    return jax.tree.map(f, cache,
                        is_leaf=lambda x: isinstance(x, PagedKVCache))


# ---------------------------------------------------------------------------
# byte-denominated pool sizing
# ---------------------------------------------------------------------------

def pool_blocks_for_bytes(pool_bytes: int, cfg, layout_page_size: int,
                          kv_bits, dtype=jnp.bfloat16) -> int:
    """Blocks a per-layer byte budget buys for this model's K/V pool
    (incl. the reserved scratch block). Quantized pages cost
    ``hd * bits/8 + 4`` bytes per (token, kv-head) per pool (codes + f32
    scale) instead of ``hd * itemsize``, so the same budget exposes
    ~2-4x the allocatable pages — the whole point of low-bit pages.
    ``kv_bits`` "vq2" prices packed 4-bit index pages (hd//4 + 4 bytes
    per row per pool, ~10x) with the frozen codebooks' fixed bytes
    charged against the budget first (kv_quant.vq_overhead_bytes)."""
    from repro.kernels import kv_quant
    dtype_bytes = jnp.zeros((), dtype).dtype.itemsize
    return kv_quant.blocks_for_bytes(
        pool_bytes, layout_page_size, cfg.n_kv_heads, cfg.hd, kv_bits,
        dtype_bytes=dtype_bytes)


def pool_bytes_of(cfg, layout: PagedLayout, dtype=jnp.bfloat16) -> int:
    """Per-layer byte size of a pool with the given layout (both pools +
    scale overhead + the vq codebooks when present; the page table is
    negligible and excluded)."""
    from repro.kernels import kv_quant
    dtype_bytes = jnp.zeros((), dtype).dtype.itemsize
    total = layout.num_blocks * kv_quant.page_bytes(
        layout.page_size, cfg.n_kv_heads, cfg.hd, layout.kv.fmt,
        dtype_bytes=dtype_bytes)
    if layout.kv.vq:
        total += kv_quant.vq_overhead_bytes(cfg.n_kv_heads)
    return total
