"""Continuous-batching scheduler: FCFS admission, chunked prefill,
preempt-on-pool-exhaustion.

Pure policy/bookkeeping — no jax in the hot path. The engine asks the
scheduler *what* to run each tick (admissions, the next prefill chunk,
block allocations, preemption victims) and executes the forwards itself.

Policies:
* **Admission** — FCFS. A request is placed when a slot is free AND its
  prompt pages allocate; otherwise it waits at the queue head (no
  head-of-line bypass). Requests that can never fit (prompt + generation
  budget over ``max_len`` or over the whole pool) raise ``CapacityError``
  at submit time instead of dying on an assert mid-flight.
* **Chunked prefill** — prompts enter the cache at most ``prefill_chunk``
  tokens per tick, so a long prompt never stalls concurrent decode ticks.
  Chunk widths are powers of two, so prefill compiles O(log max_len)
  variants instead of one per distinct prompt length. Attention-only
  families (``pad_prefill=True``) pad the final chunk up to a power-of-two
  bucket — padded positions are causally masked out and their cache writes
  land beyond the prompt's pages (scratch, or slots decode overwrites
  before reading), so one forward usually covers the whole prompt.
  Recurrent-state families (ssm/hybrid) integrate every token fed through
  them, so padding would corrupt their state; they instead feed the exact
  greedy power-of-two decomposition of the remainder (64, ..., 8, 2, 1).
* **Preemption** — when decode needs a fresh block and the pool is dry,
  the *youngest* running request is evicted back to the queue front (it
  is younger than anything still queued under FCFS, so the front keeps
  arrival order). Eviction is recompute-style: its blocks are released
  and its generated tokens discarded; greedy requests regenerate
  identically. A preempted sharer only ever *releases* its references —
  blocks still referenced by the prefix cache or co-sharers survive, and
  the replayed request re-finds them through a fresh lookup.
* **Prefix sharing** — with a ``prefix_cache`` attached, ``try_place``
  looks the prompt up first: matched full pages are shared (page table
  points at existing blocks, ``pos`` starts past them so their prefill
  chunks are skipped) and only the tail allocates fresh blocks. Under
  pool pressure the scheduler evicts cold cached prefixes before it
  resorts to preempting live sequences.
"""
from __future__ import annotations

import dataclasses
from collections import deque

from repro.serve.paged_cache import BlockAllocator


class CapacityError(ValueError):
    """Request can never be served by this engine configuration."""


def next_chunk_len(remaining: int, max_chunk: int) -> int:
    """Largest power of two <= min(remaining, max_chunk)."""
    assert remaining > 0
    return min(1 << (remaining.bit_length() - 1), max_chunk)


@dataclasses.dataclass
class Sequence:
    """Runtime state of one placed request."""
    req: object                 # serve.engine.Request
    slot: int
    pages: list                 # physical block ids, logical page order
    order: int                  # admission sequence number (preemption age)
    pos: int = 0                # tokens written to the cache so far
    phase: str = "prefill"      # "prefill" -> "decode"
    shared_tokens: int = 0      # prefix tokens served from shared blocks
                                # (prefill starts at pos == shared_tokens)

    @property
    def prompt_len(self) -> int:
        return len(self.req.prompt)


class Scheduler:
    def __init__(self, *, max_batch: int, max_len: int, page_size: int,
                 allocator: BlockAllocator, prefill_chunk: int = 64,
                 pad_prefill: bool = False, on_submit=None,
                 prefix_cache=None):
        assert prefill_chunk & (prefill_chunk - 1) == 0, \
            "prefill_chunk must be a power of two (compile-variant bound)"
        self.max_batch = max_batch
        self.max_len = max_len
        self.page_size = page_size
        self.allocator = allocator
        self.prefill_chunk = prefill_chunk
        self.pad_prefill = pad_prefill
        self.prefix_cache = prefix_cache
        self.queue: deque = deque()
        self.running: list[Sequence | None] = [None] * max_batch
        self._order = 0
        # telemetry hook: fires once per accepted submit (after
        # validation), so enqueue records exist no matter whether a
        # request entered through Engine.submit/run or a direct
        # scheduler.submit (bench drivers, fuzz suites)
        self.on_submit = on_submit

    # -- admission ---------------------------------------------------------

    def validate(self, req):
        if len(req.prompt) == 0:
            raise CapacityError(f"request {req.rid}: empty prompt")
        need = len(req.prompt) + req.max_new_tokens
        if need > self.max_len:
            raise CapacityError(
                f"request {req.rid}: prompt {len(req.prompt)} + "
                f"max_new_tokens {req.max_new_tokens} exceeds max_len "
                f"{self.max_len}")
        pages = -(-need // self.page_size)
        if pages > self.allocator.capacity:
            raise CapacityError(
                f"request {req.rid}: needs {pages} blocks, pool has "
                f"{self.allocator.capacity}")

    def submit(self, req):
        self.validate(req)
        self.queue.append(req)
        if self.on_submit is not None:
            self.on_submit(req)

    @property
    def queue_depth(self) -> int:
        return len(self.queue)

    def has_work(self) -> bool:
        return bool(self.queue) or any(s is not None for s in self.running)

    def active(self) -> list[Sequence]:
        return [s for s in self.running if s is not None]

    def _alloc_with_evict(self, n: int) -> list | None:
        """``allocator.alloc`` that sheds cold cached prefixes first:
        each failed attempt evicts one refcount-1 cached leaf and
        retries, so the prefix cache yields to live demand before the
        scheduler resorts to preempting running sequences."""
        while True:
            got = self.allocator.alloc(n)
            if got is not None:
                return got
            if self.prefix_cache is None or not self.prefix_cache.evict_one():
                return None

    def try_place(self, req) -> Sequence | None:
        """Free slot + prompt pages, or None (request stays queued).

        With a prefix cache, matched full pages come shared (one extra
        reference each, already taken by ``lookup``) and only the tail
        allocates; ``pos`` starts at the shared boundary so the engine
        skips those prefill chunks entirely.
        """
        slot = next((i for i, s in enumerate(self.running) if s is None),
                    None)
        if slot is None:
            return None
        shared: list = []
        if self.prefix_cache is not None:
            shared = self.prefix_cache.lookup(req.prompt)
        need = -(-len(req.prompt) // self.page_size) - len(shared)
        pages = self._alloc_with_evict(need)
        if pages is None:
            if shared:
                self.allocator.release(shared)
            return None
        boundary = len(shared) * self.page_size
        seq = Sequence(req=req, slot=slot, pages=shared + pages,
                       order=self._order, pos=boundary,
                       shared_tokens=boundary)
        self._order += 1
        self.running[slot] = seq
        return seq

    def admit_from_queue(self) -> list[Sequence]:
        placed = []
        while self.queue:
            seq = self.try_place(self.queue[0])
            if seq is None:
                break
            self.queue.popleft()
            placed.append(seq)
        return placed

    # -- prefill -----------------------------------------------------------

    def prefill_chunk_len(self, seq: Sequence) -> tuple[int, int]:
        """(chunk_width, real_tokens) for the next prefill forward."""
        remaining = seq.prompt_len - seq.pos
        if remaining >= self.prefill_chunk:
            return self.prefill_chunk, self.prefill_chunk
        if self.pad_prefill:
            return 1 << (remaining - 1).bit_length(), remaining
        size = next_chunk_len(remaining, self.prefill_chunk)
        return size, size

    # -- decode block supply / preemption ----------------------------------

    def ensure_block(self, seq: Sequence) -> list[Sequence]:
        """Make sure ``seq`` has a block mapped for its next write position.

        Returns the sequences preempted to make room (possibly ``seq``
        itself when it is the youngest). The caller must drop preempted
        sequences from its current tick.
        """
        preempted = []
        while seq.pos // self.page_size >= len(seq.pages):
            got = self._alloc_with_evict(1)
            if got is not None:
                seq.pages.extend(got)
                continue
            victim = max(self.active(), key=lambda s: s.order)
            self.preempt(victim)
            preempted.append(victim)
            if victim is seq:
                break
        return preempted

    def preempt(self, seq: Sequence):
        """Evict back to the queue front; recompute-style (state dropped).

        ``release`` — never a raw free — so blocks co-held by the prefix
        cache or other sharers survive the eviction; the replayed request
        re-finds them with a fresh lookup on re-admission.
        """
        self.allocator.release(seq.pages)
        self.running[seq.slot] = None
        seq.pages = []
        seq.pos = 0
        seq.shared_tokens = 0
        seq.phase = "prefill"
        self.queue.appendleft(seq.req)

    def finish(self, seq: Sequence):
        self.allocator.release(seq.pages)
        self.running[seq.slot] = None
