"""Paged-KV continuous-batching serving engine.

Architecture (PR 2): the KV cache is a pool of fixed-size blocks shared by
all ``max_batch`` slots (models/attention.PagedKVCache — block pools plus
per-slot page tables, threaded through the family assemblies' layer scans
as ordinary cache leaves). Host-side policy lives in serve/scheduler.py
(FCFS admission with capacity-aware rejection, chunked prefill, preempt
youngest on pool exhaustion) and serve/paged_cache.py (block allocator,
slot views, page-table pushes). The engine executes:

* **admit** — the request's prompt pages are allocated and the slot's
  recurrent-state rows are reset from a fresh template. Prompts are fed
  through jitted forwards in power-of-two chunks (O(log max_len) compile
  variants instead of one per distinct prompt length), one chunk per tick,
  as a B=1 slot view: page-table row + recurrent rows sliced, block pools
  shared — no more tiling a full max_batch-wide zero batch per prompt.
* **step** — one tick: admissions, at most one prefill chunk, then a
  single batched decode over every decode-phase slot with a *per-slot
  position vector*. Each slot writes at its own depth through its page
  table; there is no shared max-position write index, so staggered
  admissions leave no gaps and batched greedy decode is token-identical
  to serving each request alone (dense and hybrid families; MoE routing
  couples rows by design). Slots mid-prefill are routed to the scratch
  block for the tick and their recurrent rows restored afterwards.
* **run** — drives a request list to completion. Token throughput is
  counted where tokens are sampled (inside ``step``), so a request's
  final-tick token is never dropped from the stats.

Recurrent/ssm state leaves (mamba h/conv, xLSTM C/n/m, enc-dec cross K/V)
are O(1) per slot and stay slot-resident; only attention KV pages.

Pages may be stored low-bit (``kv_cache_bits`` 8/4 — int8 or packed-int4
codes + per-row per-kv-head scales — or "vq2": packed 4-bit codebook
indices over d=2 head-dim vectors against frozen engine-load-calibrated
codebooks; models/attention.KVQuantSpec): writes quantize in-graph at the
existing scatter sites and every read path dequantizes on the fly, so the
same pool bytes hold 2-4x (scalar) to ~10x (vq2) the pages
(``pool_bytes=`` sizes the allocator by budget instead of block count).

Telemetry (PR 7): every engine owns an ``obs.Telemetry`` (pass your own
to share a registry, write a JSONL event stream, or disable it). Each
tick feeds the metrics registry — queue depth, pool occupancy, prefill
chunk widths, preemptions, device-upload cache hit rate — and host-side
phases run under trace spans (``span.decode_tick/…``; the device span
closes after the sampled-token download, so it accounts device time).
Per-request lifecycle records (enqueue -> admit -> first token ->
finish) accumulate TTFT / inter-token latency and drain via
``drain_request_records()``; ``stats`` is a live property now —
counters and wall time accumulate per tick, so callers driving
``step()`` directly always read current numbers.

This is the end-to-end driver used by examples/quantize_and_serve.py to
demonstrate the paper's deployment claim: identical engine code serves
bf16 and GPTVQ-compressed weights.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.attention import KVQuantSpec, PagedKVCache, PagedLayout
from repro.models.model_zoo import Model
from repro.obs import COUNT_BUCKETS, Telemetry
from repro.serve import paged_cache as pc
from repro.serve import sampling
from repro.serve.scheduler import CapacityError, Scheduler, Sequence
from repro.serve.serve_step import make_paged_decode, make_slot_prefill


@dataclasses.dataclass
class Request:
    rid: int | str
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    n: int = 1                   # parallel samples: n-1 forked children
                                 # share the prompt's KV blocks
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False
    error: str | None = None     # set when rejected (CapacityError)
    forks: list = dataclasses.field(default_factory=list)
                                 # the n-1 child Requests (rid "rid.i")


def calibrate_vq_codebooks(model: Model, params, cache, *,
                           page_size: int = 16, calib_len: int = 64,
                           vq_impl: str | None = "gather",
                           em_iters: int = 25):
    """Fit frozen vq2 KV-page codebooks from a short calibration capture
    and return ``cache`` with its codebook leaves replaced.

    A one-sequence slice of the deterministic calibration corpus
    (data/calibration.calibration_tokens) runs through a small fp32
    passthrough paged cache with an identity page table; the K/V rows
    each layer wrote are read back out of the capture pool, amax-
    normalized per (row, kv-head) — the same normalization the write
    path applies before assignment — split into d=2 vectors along the
    head dim, and EM-fit per (pool, kv-head) with core/codebook
    (Hessian weights 1, i.e. plain k-means; Mahalanobis seeding).

    Everything here is deterministic (fixed corpus, fixed seeding, fixed
    iteration count), so two engines over the same model produce
    bit-identical codebooks — which is what lets frozen-codebook
    assignment preserve the interleaved-vs-solo and preemption-replay
    token-identity invariants. Exposed at module level so tests and
    benches that build caches directly (no Engine) calibrate the exact
    same way."""
    from repro.core.codebook import init_codebook
    from repro.data.calibration import calibration_tokens
    from repro.kernels import kv_quant as kvq

    npc = -(-calib_len // page_size)
    cap = model.init_cache(1, npc * page_size, dtype=jnp.float32,
                           paged=PagedLayout(npc + 1, page_size))
    cap = pc.push_page_table(cap, np.arange(1, npc + 1,
                                            dtype=np.int32)[None])
    toks = calibration_tokens(model.cfg.vocab_size, n_sequences=1,
                              seq_len=calib_len)
    _, cap, _ = model.forward(
        params, {"tokens": toks}, cache=cap,
        pos=jnp.zeros((1,), jnp.int32), paged_impl="gather",
        vq_matmul_impl=vq_impl)

    def fit(pool):
        # pool (*stack, num_blocks, page_size, KV, hd): blocks 1..npc
        # hold the capture's first calib_len rows in logical order
        stack = pool.shape[:-4]
        nb, ps, KV, hd = pool.shape[-4:]
        rows = pool[..., 1:, :, :, :].reshape(*stack, (nb - 1) * ps, KV, hd)
        x = jnp.moveaxis(rows[..., :calib_len, :, :], -2, -3)
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        xn = x / jnp.where(amax > 0, amax, 1.0)
        X = xn.reshape(*stack, KV, -1, kvq.VQ_D)
        flat = X.reshape((-1,) + X.shape[-2:])
        cbs = jax.vmap(lambda Xi: init_codebook(
            Xi, jnp.ones_like(Xi), k=kvq.VQ_K, iters=em_iters))(flat)
        return cbs.reshape(*stack, KV, kvq.VQ_K, kvq.VQ_D).astype(
            jnp.float32)

    def inject(dst, src):
        if isinstance(dst, PagedKVCache):
            return dst._replace(k_codebook=fit(src.k),
                                v_codebook=fit(src.v))
        return dst

    return jax.tree.map(inject, cache, cap,
                        is_leaf=lambda x: isinstance(x, PagedKVCache))


class Engine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_len: int = 512, eos_id: int | None = None, seed: int = 0,
                 page_size: int = 16, num_blocks: int | None = None,
                 pool_bytes: int | None = None,
                 prefill_chunk: int = 64, paged_attn_impl: str = "gather",
                 kv_cache_bits: int = 16, vq_matmul_impl: str = "gather",
                 prefix_cache: bool = False,
                 telemetry: Telemetry | None = None):
        """``paged_attn_impl`` selects the decode attention read path over
        the paged KV pool, threaded into the jitted decode closure (see
        models/attention._paged_apply): "gather" (XLA logical-view gather,
        the portable default), "pallas" (fused in-kernel page gather —
        kernels/paged_attention.py; interpret mode off-TPU, tests only),
        "xla" (the kernel's oracle routed through the same fused
        dispatch), or "fused" (resolves to "pallas" on TPU and "xla"
        elsewhere — what production serving should pass). Prefill always
        uses the gather path.

        ``kv_cache_bits`` selects the page storage format (16 =
        passthrough dtype, 8/4 = int8/packed-int4 code pages with per-row
        per-kv-head f32 scales; the string "vq2" = vector-quantized pages
        holding 4-bit codebook indices over d=2 head-dim vectors, 2 bits
        per value; models/attention.KVQuantSpec). It rides on the
        PagedLayout into every family's ``init_cache``, so all read and
        write paths — including the fused kernel — see quantized pages
        with no forward-signature change. For "vq2" the per-(pool,
        kv-head) codebooks are EM-calibrated once here at construction
        (calibrate_vq_codebooks) and frozen before any serving write.

        ``pool_bytes`` sizes the pool by a per-layer byte budget instead
        of a block count: the allocator then exposes however many pages
        fit, which is where a quantized cache converts its 2-4x byte
        saving into concurrent-slot / context-length headroom. Mutually
        exclusive with ``num_blocks``.

        ``vq_matmul_impl`` selects the execution path for VQ-packed
        (GPTVQ) weight leaves: "gather" (per-layer-slice dense
        materialization via core/vq_linear.dequant_tree — the portable
        default), "xla" (fused-boundary reconstruct-per-matmul over
        engine-prepped FusedVQLinear leaves), "pallas" (the fused
        VMEM-decode kernel, kernels/vq_dequant_matmul.py), or "fused"
        (resolves to "pallas" on TPU, "xla" elsewhere). Any non-"gather"
        choice runs the one-time ``prepare_fused_tree`` prep pass at
        construction — cb_scale folding, code unpack+offset folding, and
        blockwise-scale-plane expansion all happen here ONCE, so per-tick
        work is zero (see core/vq_linear's module docstring for the
        contract).

        ``prefix_cache=True`` attaches a serve/prefix_cache.PrefixCache:
        admission looks the prompt up in a radix tree over full pages and
        serves matched prefixes from existing pool blocks — the new
        sequence's page table points at them (refcounted, copy-on-write
        by construction: sharing stops before the first writable page)
        and prefill starts past the shared boundary. Inert for
        recurrent-state families: any cache leaf outside the PagedKVCache
        pools is slot-resident state that integrates every prompt token,
        which a page-table share cannot replay — the engine detects this
        structurally and keeps the flag off rather than serving from
        stale state.

        ``telemetry`` is the obs.Telemetry sink the engine reports into
        (metrics registry + spans + request records + optional JSONL
        event stream). None constructs a private enabled one; pass
        ``Telemetry(enabled=False)`` to measure the instrumentation cost
        itself (the bench's ``obs_overhead`` cell)."""
        from repro.core import vq_linear as vql_mod

        if paged_attn_impl == "fused":
            paged_attn_impl = ("pallas" if jax.default_backend() == "tpu"
                               else "xla")
        assert paged_attn_impl in ("gather", "xla", "pallas"), paged_attn_impl
        self.paged_attn_impl = paged_attn_impl
        if vq_matmul_impl == "fused":
            vq_matmul_impl = ("pallas" if jax.default_backend() == "tpu"
                              else "xla")
        assert vq_matmul_impl in ("gather", "xla", "pallas"), vq_matmul_impl
        self.vq_matmul_impl = vq_matmul_impl
        if vq_matmul_impl != "gather" and vql_mod.tree_has_vq(params):
            params = vql_mod.prepare_fused_tree(params, impl=vq_matmul_impl)
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)
        kv_spec = KVQuantSpec.of(kv_cache_bits)
        self.kv_cache_bits = kv_cache_bits

        dtype = jnp.float32
        n_pages = -(-max_len // page_size)
        if pool_bytes is not None:
            assert num_blocks is None, \
                "pass num_blocks or pool_bytes, not both"
            num_blocks = pc.pool_blocks_for_bytes(
                pool_bytes, model.cfg, page_size, kv_spec.fmt, dtype)
        elif num_blocks is None:
            # default pool holds every slot at full depth (+ scratch);
            # pass a smaller pool to oversubscribe and exercise preemption
            num_blocks = max_batch * n_pages + 1
        self.layout = PagedLayout(num_blocks=num_blocks,
                                  page_size=page_size, kv=kv_spec)
        self.n_pages = n_pages

        self.cache = model.init_cache(max_batch, max_len, dtype=dtype,
                                      paged=self.layout)
        if kv_spec.vq:
            # calibrate-then-freeze: the codebook leaves are replaced
            # exactly once, before any serving write, so every subsequent
            # page write assigns against the same frozen tables
            self.cache = calibrate_vq_codebooks(
                model, params, self.cache, page_size=page_size,
                calib_len=min(64, max_len), vq_impl=self.vq_matmul_impl)
        self.axes = pc.batch_axes(model, max_batch, max_len, dtype,
                                  self.layout)
        # B=1 template for resetting a slot's recurrent rows on admission
        # (tiny pool: slot_merge(shared=False) never reads template pools)
        self._slot_template = model.init_cache(
            1, max_len, dtype=dtype, paged=PagedLayout(2, page_size,
                                                       kv=kv_spec))

        self.telemetry = telemetry if telemetry is not None else Telemetry()
        if self.telemetry.spans._step_ref is None:
            # StepTraceAnnotation step numbers line up with engine ticks
            self.telemetry.spans._step_ref = lambda: self.ticks
        self._spans = self.telemetry.spans
        reg = self.telemetry.registry
        self._m_queue = reg.gauge("serve.queue_depth")
        self._m_used = reg.gauge("serve.pool_used_blocks")
        self._m_free = reg.gauge("serve.pool_free_blocks")
        self._m_occ = reg.gauge("serve.pool_occupancy")
        self._m_slots = reg.gauge("serve.slots_active")
        self._m_dec_batch = reg.histogram("serve.decode_batch",
                                          COUNT_BUCKETS)
        self._m_chunk = reg.histogram("serve.prefill_chunk_tokens",
                                      COUNT_BUCKETS)
        self._m_dev_hit = reg.counter("serve.dev_cache_hits")
        self._m_dev_miss = reg.counter("serve.dev_cache_misses")
        self._m_shared = reg.gauge("serve.shared_blocks")
        self._m_cached = reg.gauge("serve.prefix_cached_blocks")
        self._m_pfx_miss = reg.counter("serve.prefix_misses")

        allocator = pc.BlockAllocator(num_blocks)
        # structural recurrent-state detection: any cache leaf outside the
        # PagedKVCache pools is per-slot state (mamba h/conv, xLSTM C/n/m,
        # enc-dec cross K/V) that integrates every prompt token — a
        # page-table share can't replay it, so prefix sharing stays inert
        has_slot_state = any(
            not isinstance(l, PagedKVCache)
            for l in jax.tree.leaves(
                self.cache,
                is_leaf=lambda x: isinstance(x, PagedKVCache)))
        self.prefix_cache = None
        if prefix_cache and not has_slot_state:
            from repro.serve.prefix_cache import PrefixCache
            self.prefix_cache = PrefixCache(allocator, page_size)
        self._pending_forks: dict = {}   # parent rid -> child Requests

        self.scheduler = Scheduler(
            max_batch=max_batch, max_len=max_len, page_size=page_size,
            allocator=allocator, prefix_cache=self.prefix_cache,
            prefill_chunk=prefill_chunk,
            # attention-only families pad the final prefill chunk to its
            # power-of-two bucket (masked out exactly); recurrent-state
            # families must feed exact tokens (see scheduler module doc)
            pad_prefill=model.cfg.family not in ("ssm", "hybrid"),
            # direct scheduler.submit callers (bench, fuzz suites) still
            # get enqueue records — the hook is the single entry point
            on_submit=lambda req: self.telemetry.on_enqueue(
                req.rid, len(req.prompt), req.max_new_tokens))
        # fully-compiled tick fns: decode traces once at (max_batch, 1);
        # prefill traces per power-of-two chunk width — O(log) variants.
        # The cache arg is donated: XLA updates the block pools in place
        # instead of copying the whole pool every tick (the engine always
        # replaces self.cache with the returned tree, so the old buffers
        # are never read again).
        self._decode_fn = jax.jit(
            make_paged_decode(model, self.axes,
                              paged_impl=self.paged_attn_impl,
                              vq_impl=self.vq_matmul_impl),
            donate_argnums=(2,))
        self._prefill_fn = jax.jit(
            make_slot_prefill(model, self.axes,
                              vq_impl=self.vq_matmul_impl),
            donate_argnums=(2,))
        self._sample = jax.jit(
            lambda k, logits, t: sampling.sample(k, logits, temperature=t))

        self.last_tok = np.zeros(max_batch, np.int32)
        self.ticks = 0
        self._decode_ticks = 0
        self._tokens = 0
        self._prefill_chunks = 0
        self._preemptions = 0
        self._wall_s = 0.0
        # host->device upload cache for slow-changing tick inputs (page
        # tables, keep masks, temperatures): at steady-state decode these
        # only change when a slot crosses a page boundary or a request
        # enters/leaves, so re-uploading every tick was pure host overhead
        self._dev_cache: dict = {}

    def _dev(self, name: str, arr: np.ndarray):
        """Device copy of ``arr``, re-uploaded only when the host value
        changed since the last tick (cheap array_equal on tiny arrays)."""
        ent = self._dev_cache.get(name)
        if ent is None or not np.array_equal(ent[0], arr):
            self._m_dev_miss.inc()
            ent = (arr.copy(), jnp.asarray(arr))
            self._dev_cache[name] = ent
        else:
            self._m_dev_hit.inc()
        return ent[1]

    @property
    def stats(self) -> dict:
        """Live counters — always current, whether the engine is driven
        by ``run()`` or tick-by-tick via ``step()`` (wall time and every
        counter accumulate continuously inside ``step``)."""
        alloc = self.scheduler.allocator
        pfx = self.prefix_cache
        return {"wall_s": self._wall_s, "decode_ticks": self._decode_ticks,
                "tokens": self._tokens, "ticks": self.ticks,
                "prefill_chunks": self._prefill_chunks,
                "preemptions": self._preemptions,
                "queue_depth": len(self.scheduler.queue),
                "pool_used_blocks": alloc.used_blocks,
                "pool_free_blocks": alloc.free_blocks,
                "shared_blocks": alloc.shared_blocks,
                "prefix_hits": pfx.hits if pfx else 0,
                "prefix_misses": pfx.misses if pfx else 0,
                "prefix_hit_tokens": pfx.hit_tokens if pfx else 0,
                "prefix_evictions": pfx.evictions if pfx else 0,
                "prefix_cached_blocks": pfx.cached_blocks if pfx else 0}

    def drain_request_records(self):
        """Return-and-clear finished per-request lifecycle records
        (obs.RequestRecord: TTFT, mean ITL, tokens, preemptions, finish
        reason)."""
        return self.telemetry.drain_finished()

    # -- admission ---------------------------------------------------------

    def submit(self, req: Request):
        """Queue a request (telemetry records the enqueue). Raises
        CapacityError — after emitting the ``reject`` event and marking
        the request — if it can never fit this engine configuration.

        ``req.n > 1`` creates n-1 forked children (rid "rid.i") sampling
        the same prompt; they are held back until the parent's prefill
        completes — by then every full prompt page is registered in the
        prefix cache, so each child admits by sharing the parent's
        blocks and prefills only the final partial page. Without a
        prefix cache forks still run (and stay greedy-identical); they
        just re-prefill the prompt privately."""
        try:
            self.scheduler.submit(req)
        except CapacityError as e:
            req.error = str(e)
            req.done = True
            self.telemetry.on_reject(req.rid, str(e))
            raise
        if req.n > 1:
            children = []
            for i in range(1, req.n):
                child = Request(rid=f"{req.rid}.{i}", prompt=req.prompt,
                                max_new_tokens=req.max_new_tokens,
                                temperature=req.temperature)
                children.append(child)
                self.telemetry.on_enqueue(child.rid, len(child.prompt),
                                          child.max_new_tokens)
            req.forks = children
            self._pending_forks[req.rid] = children

    def admit(self, req: Request) -> bool:
        """Place a request into a free slot (no prefill compute yet —
        the prompt streams in chunk-per-tick during ``step``). Raises
        CapacityError (after emitting the ``reject`` event) if the
        request can never fit; returns False when no slot/blocks are
        free right now."""
        try:
            self.scheduler.validate(req)
        except CapacityError as e:
            req.error = str(e)
            req.done = True
            self.telemetry.on_reject(req.rid, str(e))
            raise
        seq = self.scheduler.try_place(req)
        if seq is None:
            return False
        self._admit_seq(seq)
        return True

    def _admit_seq(self, seq: Sequence):
        """Post-placement bookkeeping shared by ``admit`` and ``step``:
        telemetry + prefix-hit accounting + slot state reset."""
        self.telemetry.on_admit(seq.req.rid, seq.slot)
        if seq.shared_tokens:
            self.telemetry.on_prefix_hit(
                seq.req.rid, seq.shared_tokens // self.scheduler.page_size,
                seq.shared_tokens)
        elif self.prefix_cache is not None:
            self._m_pfx_miss.inc()
        self._reset_slot(seq)

    def _reset_slot(self, seq: Sequence):
        self.cache = pc.slot_merge(self.cache, self._slot_template,
                                   self.axes, seq.slot, shared=False)

    def _page_table(self, phases: tuple) -> np.ndarray:
        """Host page table with rows populated only for the given phases;
        everything else points at the scratch block."""
        t = np.zeros((self.max_batch, self.n_pages), np.int32)
        for s in self.scheduler.active():
            if s.phase in phases:
                t[s.slot, : len(s.pages)] = s.pages
        return t

    # -- one tick ----------------------------------------------------------

    def step(self):
        t0 = time.perf_counter()
        for seq in self.scheduler.admit_from_queue():
            self._admit_seq(seq)
        # one chunk per prefilling slot per tick: a burst of admissions
        # drains its prompts concurrently, while a single long prompt can
        # never stall the decode cohort by more than one chunk
        prefilling = sorted(
            (s for s in self.scheduler.active() if s.phase == "prefill"),
            key=lambda s: s.order)
        done = []
        if prefilling:
            # one table serves every chunk this tick: nothing allocates or
            # finishes between chunks of the same tick
            table = self._page_table(("prefill", "decode"))
            with self._spans.span("prefill"):
                for seq in prefilling:
                    last_logits = self._prefill_chunk(seq, table)
                    if last_logits is not None:
                        done.append((seq, last_logits))
        if done:
            # sample every prompt that completed this tick in ONE batched
            # draw: per-completion syncs serialized the prefill pipeline
            with self._spans.span("prompt_sample"):
                self.key, sub = jax.random.split(self.key)
                toks = np.asarray(self._sample(
                    sub, jnp.stack([l for _, l in done]),
                    jnp.asarray([s.req.temperature for s, _ in done],
                                jnp.float32)))
            for (seq, _), t in zip(done, toks):
                seq.phase = "decode"
                self._on_prompt_done(seq)
                self._emit(seq, int(t))
        self._decode_tick()
        self.ticks += 1
        # per-tick registry feed: queue/occupancy gauges mirror the
        # scheduler + allocator accounting exactly (fuzz-tested invariant)
        alloc = self.scheduler.allocator
        used = alloc.used_blocks
        self._m_queue.set(len(self.scheduler.queue))
        self._m_used.set(used)
        self._m_free.set(alloc.free_blocks)
        self._m_occ.set(used / alloc.capacity if alloc.capacity else 0.0)
        self._m_slots.set(len(self.scheduler.active()))
        self._m_shared.set(alloc.shared_blocks)
        if self.prefix_cache is not None:
            self._m_cached.set(self.prefix_cache.cached_blocks)
        self._wall_s += time.perf_counter() - t0

    def _on_prompt_done(self, seq: Sequence):
        """Prefill just completed: register the prompt's full pages in
        the prefix cache (they are final — decode writes only ever land
        past prompt_len, in the tail partial page or fresh blocks) and
        release any forked children held for this parent. Insertion
        happens BEFORE the first ``_emit`` so the cache's references are
        taken even if the request finishes on its first sampled token."""
        if self.prefix_cache is not None:
            self.prefix_cache.insert(seq.req.prompt, seq.pages)
        children = self._pending_forks.pop(seq.req.rid, None)
        if children:
            # queue front (reversed keeps child order): they share every
            # full prompt page, so placing them next maximizes the time
            # those blocks stay hot
            for child in reversed(children):
                self.scheduler.queue.appendleft(child)

    def _prefill_chunk(self, seq: Sequence, table: np.ndarray):
        """Feed the next chunk; returns the (V,) next-token logits when the
        prompt is complete, else None."""
        size, real = self.scheduler.prefill_chunk_len(seq)
        self._m_chunk.observe(real)
        start = seq.pos
        chunk = np.zeros(size, np.int32)
        chunk[:real] = np.asarray(seq.req.prompt[start:start + real])
        last_logits, self.cache = self._prefill_fn(
            self.params, jnp.asarray(chunk[None]), self.cache, seq.slot,
            start, real - 1, self._dev("table_pf", table))
        seq.pos += real
        self._prefill_chunks += 1
        return last_logits if seq.pos == seq.prompt_len else None

    def _emit(self, seq: Sequence, tok: int):
        req = seq.req
        req.out_tokens.append(tok)
        self.last_tok[seq.slot] = tok
        self._tokens += 1
        self.telemetry.on_token(req.rid)
        eos = self.eos_id is not None and tok == self.eos_id
        if len(req.out_tokens) >= req.max_new_tokens or eos:
            req.done = True
            self.scheduler.finish(seq)
            self.telemetry.on_finish(req.rid, "eos" if eos else "length")

    def _decode_tick(self):
        decoding = [s for s in self.scheduler.active()
                    if s.phase == "decode"]
        # supply every decoding slot with a block for its write position,
        # preempting youngest-first when the pool runs dry
        for s in sorted(decoding, key=lambda s: s.order):
            if self.scheduler.running[s.slot] is not s:
                continue  # already preempted this tick
            for victim in self.scheduler.ensure_block(s):
                self._on_preempt(victim)
        decoding = [s for s in self.scheduler.active()
                    if s.phase == "decode"]
        if not decoding:
            return
        self._m_dec_batch.observe(len(decoding))
        with self._spans.span("decode_tick"):
            with self._spans.span("host_prep"):
                pos = np.zeros(self.max_batch, np.int32)
                temps = np.zeros(self.max_batch, np.float32)
                # slots mid-prefill decode on garbage this tick (their
                # writes are routed to scratch by the table; their
                # recurrent-state rows are restored inside the compiled
                # step via keep_mask)
                keep = np.zeros(self.max_batch, bool)
                for s in self.scheduler.active():
                    if s.phase == "decode":
                        pos[s.slot] = s.pos
                        temps[s.slot] = s.req.temperature
                    else:
                        keep[s.slot] = True
                toks = jnp.asarray(self.last_tok[:, None], jnp.int32)
                args = (self.params, toks, self.cache, jnp.asarray(pos),
                        self._dev("table_dec",
                                  self._page_table(("decode",))),
                        self._dev("keep", keep), self.key,
                        self._dev("temps", temps))
            with self._spans.span("device"):
                # closes after the (B,) token download — the one sync
                # point of the tick — so this span accounts device time
                nxt, self.key, self.cache = self._decode_fn(*args)
                nxt = np.asarray(nxt)
        for s in decoding:
            s.pos += 1
            self._emit(s, int(nxt[s.slot]))
        self._decode_ticks += 1

    def _on_preempt(self, victim: Sequence):
        self._preemptions += 1
        self._tokens -= len(victim.req.out_tokens)
        self.telemetry.on_preempt(victim.req.rid)
        victim.req.out_tokens.clear()
        victim.req.done = False

    # -- teardown ----------------------------------------------------------

    def close(self):
        """Release engine-held pool state. Clearing the prefix cache
        returns its block references to the allocator AND zeroes its
        LRU clock + hit/miss/eviction counters, so a restarted engine
        (or a launcher serving several engines back to back) never
        reports stale prefix stats. Idempotent."""
        if self.prefix_cache is not None:
            self.prefix_cache.clear()
        self._dev_cache.clear()

    # -- driver ------------------------------------------------------------

    def run(self, requests: list[Request], max_ticks: int = 10_000):
        """Drive all requests to completion; returns them. Requests that
        can never fit are rejected gracefully (``req.error`` set)."""
        for req in requests:
            try:
                self.submit(req)
            except CapacityError:
                pass  # submit marked the request + emitted the reject
        self.telemetry.start_trace()
        try:
            while self.scheduler.has_work() and self.ticks < max_ticks:
                self.step()
        finally:
            self.telemetry.stop_trace()
            self.telemetry.events.flush()
        return requests
