"""Batched serving engine (continuous-batching lite).

Maintains a fixed pool of ``max_batch`` slots over a shared max_len KV cache.
Requests are admitted into free slots; one jitted decode step advances every
active slot per tick; finished sequences free their slot. Per-slot positions
are tracked host-side; the decode step uses per-slot position vectors via a
padded right-aligned layout: each admitted prompt is prefilled individually
into its slot (simple, robust), then all slots decode together.

This is the end-to-end driver used by examples/quantize_and_serve.py to
demonstrate the paper's deployment claim: identical engine code serves bf16
and GPTVQ-compressed weights.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.model_zoo import Model
from repro.serve import sampling
from repro.serve.serve_step import make_decode, make_prefill


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 32
    temperature: float = 0.0
    out_tokens: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model: Model, params, *, max_batch: int = 8,
                 max_len: int = 512, eos_id: int | None = None, seed: int = 0):
        self.model = model
        self.params = params
        self.max_batch = max_batch
        self.max_len = max_len
        self.eos_id = eos_id
        self.key = jax.random.PRNGKey(seed)

        self.cache = model.init_cache(max_batch, max_len, dtype=jnp.float32)
        self.prefill = jax.jit(make_prefill(model))
        self.decode = jax.jit(make_decode(model))
        self.slots: list[Request | None] = [None] * max_batch
        self.pos = np.zeros(max_batch, np.int64)  # next write position
        self.last_tok = np.zeros(max_batch, np.int32)
        self.ticks = 0

    # -- slot admission ----------------------------------------------------
    def _free_slot(self) -> int | None:
        for i, s in enumerate(self.slots):
            if s is None:
                return i
        return None

    def admit(self, req: Request) -> bool:
        slot = self._free_slot()
        if slot is None:
            return False
        S = len(req.prompt)
        assert S + req.max_new_tokens <= self.max_len
        # per-slot prefill: run the prompt through with this slot's cache row
        tokens = jnp.asarray(req.prompt, jnp.int32)[None]
        # batchify: tile prompt into a B=max_batch batch, but only keep slot
        tok_b = jnp.zeros((self.max_batch, S), jnp.int32).at[slot].set(tokens[0])
        logits, new_cache = self.prefill(
            self.params, {"tokens": tok_b}, self.cache)
        # merge only this slot's cache rows (batch axis differs per leaf kind)
        self.cache = _merge_slot(self.cache, new_cache, slot, self.max_batch)
        self.slots[slot] = req
        self.pos[slot] = S
        nxt = int(jnp.argmax(logits[slot, S - 1]))
        req.out_tokens.append(nxt)
        self.last_tok[slot] = nxt
        return True

    # -- decode tick ---------------------------------------------------------
    def step(self):
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        # single position scalar per tick: all slots share the max position
        # write index; inactive slots write into scratch (masked at read).
        pos = int(self.pos.max())
        toks = jnp.asarray(self.last_tok[:, None], jnp.int32)
        logits, self.cache = self.decode(self.params, toks, self.cache, pos)
        self.key, sub = jax.random.split(self.key)
        # per-slot temperatures: every request samples under its own
        # (inactive slots are greedy; their draws are discarded anyway)
        temps = np.zeros(self.max_batch, np.float32)
        for i in active:
            temps[i] = self.slots[i].temperature
        nxt = np.asarray(sampling.sample(sub, logits[:, -1],
                                         temperature=jnp.asarray(temps)))
        for i in active:
            req = self.slots[i]
            t = int(nxt[i])
            req.out_tokens.append(t)
            self.last_tok[i] = t
            self.pos[i] = pos + 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (self.eos_id is not None and t == self.eos_id)):
                req.done = True
                self.slots[i] = None
        self.ticks += 1

    def run(self, requests: list[Request], max_ticks: int = 10_000):
        """Drive all requests to completion; returns them."""
        pending = list(requests)
        t0 = time.perf_counter()
        n_tok = 0
        while (pending or any(self.slots)) and self.ticks < max_ticks:
            while pending and self._free_slot() is not None:
                if not self.admit(pending[0]):
                    break
                pending.pop(0)
            self.step()
            n_tok += sum(1 for s in self.slots if s is not None)
        dt = time.perf_counter() - t0
        self.stats = {"wall_s": dt, "decode_ticks": self.ticks,
                      "tokens": n_tok}
        return requests


def _merge_slot(old_cache, new_cache, slot: int, batch: int):
    """Copy one request's batch row from new_cache into old_cache.

    The batch axis position differs per leaf (layer-stacked attention caches
    put it at axis 1, hybrid mamba stacks at axis 2, ...); every cache layout
    in the zoo keeps exactly one axis of size ``batch`` (the engine's
    ``max_batch``), located here as the first size match. ``batch`` is
    threaded explicitly so two engines with different pool sizes can
    coexist in one process.
    """
    def merge_leaf(o, n):
        ax = next((i for i, s in enumerate(o.shape) if s == batch), None)
        if ax is None:
            return n
        idx = [slice(None)] * o.ndim
        idx[ax] = slice(slot, slot + 1)
        return o.at[tuple(idx)].set(n[tuple(idx)])

    return jax.tree.map(merge_leaf, old_cache, new_cache)
