"""Telemetry subsystem: metrics registry, trace spans, JSONL event
stream, per-request latency records, and kernel-dispatch counters.

This is the measurement layer the serving engine, kernels, and the
quantization pipeline report into — see obs/telemetry.py for the facade
the engine owns, obs/metrics.py for the instrument semantics, and
ROADMAP.md "Serving > Telemetry" for the operator-facing story
(``--events-out`` / ``--metrics-out`` / ``--trace-dir``).
"""
from repro.obs.dispatch import (
    register_dispatch,
    reset_dispatch_counters,
    snapshot_dispatch_counters,
)
from repro.obs.events import (
    EVENT_FIELDS,
    EventLog,
    RequestRecord,
    read_jsonl,
    validate_event,
)
from repro.obs.metrics import (
    COUNT_BUCKETS,
    LATENCY_BUCKETS_S,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    validate_metrics_snapshot,
)
from repro.obs.spans import SpanTimer
from repro.obs.telemetry import Telemetry

__all__ = [
    "COUNT_BUCKETS",
    "Counter",
    "EVENT_FIELDS",
    "EventLog",
    "Gauge",
    "Histogram",
    "LATENCY_BUCKETS_S",
    "MetricsRegistry",
    "RequestRecord",
    "SpanTimer",
    "Telemetry",
    "read_jsonl",
    "register_dispatch",
    "reset_dispatch_counters",
    "snapshot_dispatch_counters",
    "validate_event",
    "validate_metrics_snapshot",
]
