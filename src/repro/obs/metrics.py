"""Zero-dependency metrics registry: counters, gauges, fixed-bucket
histograms, JSON-snapshot export.

Design constraints (this is the hot-path measurement layer for the
serving engine, so they are load-bearing):

* **Cheap instruments.** ``Counter.inc`` / ``Gauge.set`` are one python
  attribute update; ``Histogram.observe`` is a ``bisect`` over a short
  static bucket list. No locks (the engine is single-threaded host code),
  no label cardinality machinery, no background threads.
* **Disabled == free.** A registry built with ``enabled=False`` hands out
  a shared null instrument whose methods are no-ops, so
  ``Engine(telemetry=Telemetry(enabled=False))`` measures the true cost
  of the instrumentation itself (the BENCH_serve.json ``obs_overhead``
  cell pins it within noise of zero).
* **Snapshots are plain JSON.** ``snapshot()`` returns nested dicts of
  numbers only — writable with ``json.dump``, diffable across ticks, and
  schema-checked by tests/obs and the CI metrics smoke step.

Instruments are get-or-create by name: ``registry.counter("tokens")``
returns the same object every call, so callers never need to pre-declare.
"""
from __future__ import annotations

import json
from bisect import bisect_right

# Default latency buckets (seconds): log-spaced 100us .. 30s, the range a
# host-side serving phase (upload, tick, prefill chunk) can plausibly take.
LATENCY_BUCKETS_S = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05,
    0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 30.0)

# Small-integer buckets (queue depths, chunk widths, page counts).
COUNT_BUCKETS = (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024)


class Counter:
    """Monotonic counter."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def inc(self, n: int | float = 1):
        self.value += n

    def to_json(self):
        return self.value


class Gauge:
    """Last-write-wins point-in-time value."""

    __slots__ = ("value",)

    def __init__(self):
        self.value = 0

    def set(self, v):
        self.value = v

    def to_json(self):
        return self.value


class Histogram:
    """Fixed-bucket histogram: counts per upper-edge bucket + overflow,
    plus exact sum/count/min/max so means survive the bucketing."""

    __slots__ = ("buckets", "counts", "sum", "count", "min", "max")

    def __init__(self, buckets=LATENCY_BUCKETS_S):
        self.buckets = tuple(buckets)
        assert list(self.buckets) == sorted(self.buckets), "unsorted buckets"
        self.counts = [0] * (len(self.buckets) + 1)  # +1 overflow
        self.sum = 0.0
        self.count = 0
        self.min = None
        self.max = None

    def observe(self, v):
        v = float(v)
        self.counts[bisect_right(self.buckets, v)] += 1
        self.sum += v
        self.count += 1
        self.min = v if self.min is None else min(self.min, v)
        self.max = v if self.max is None else max(self.max, v)

    @property
    def mean(self) -> float:
        return self.sum / self.count if self.count else 0.0

    def quantile(self, q: float) -> float:
        """Bucket-resolution quantile (upper edge of the bucket holding
        the q-th observation; the overflow bucket reports the exact max)."""
        assert 0.0 <= q <= 1.0
        if not self.count:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self.counts):
            seen += c
            if seen >= rank and c:
                return (self.buckets[i] if i < len(self.buckets)
                        else self.max)
        return self.max

    def to_json(self):
        return {"buckets": list(self.buckets), "counts": list(self.counts),
                "sum": self.sum, "count": self.count,
                "min": self.min, "max": self.max, "mean": self.mean,
                "p50": self.quantile(0.5), "p99": self.quantile(0.99)}


class _Null:
    """Shared no-op instrument handed out by a disabled registry."""

    __slots__ = ()
    value = 0
    sum = 0.0
    count = 0
    mean = 0.0

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v):
        pass

    def quantile(self, q):
        return 0.0

    def to_json(self):
        return None


_NULL = _Null()


class MetricsRegistry:
    """Flat name -> instrument map with get-or-create accessors."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: dict[str, object] = {}

    def _get(self, name: str, factory):
        if not self.enabled:
            return _NULL
        m = self._metrics.get(name)
        if m is None:
            m = self._metrics[name] = factory()
        return m

    def counter(self, name: str) -> Counter:
        return self._get(name, Counter)

    def gauge(self, name: str) -> Gauge:
        return self._get(name, Gauge)

    def histogram(self, name: str, buckets=LATENCY_BUCKETS_S) -> Histogram:
        return self._get(name, lambda: Histogram(buckets))

    def reset(self):
        self._metrics.clear()

    def snapshot(self) -> dict:
        """JSON-able {name: value-or-histogram-dict}, sorted by name."""
        return {k: self._metrics[k].to_json()
                for k in sorted(self._metrics)}

    def write_json(self, path: str):
        with open(path, "w") as f:
            json.dump(self.snapshot(), f, indent=2)


def validate_metrics_snapshot(snap: dict):
    """Schema check for a ``snapshot()`` payload (CI metrics smoke +
    tests/obs). Raises ValueError on the first violation."""
    if not isinstance(snap, dict):
        raise ValueError(f"snapshot must be a dict, got {type(snap)}")
    for name, v in snap.items():
        if not isinstance(name, str):
            raise ValueError(f"metric name {name!r} is not a string")
        if isinstance(v, (int, float)) or v is None:
            continue
        if isinstance(v, dict):
            missing = {"buckets", "counts", "sum", "count"} - set(v)
            if missing:
                raise ValueError(f"histogram {name!r} missing {missing}")
            if len(v["counts"]) != len(v["buckets"]) + 1:
                raise ValueError(
                    f"histogram {name!r}: counts must have one overflow "
                    f"slot past the bucket edges")
            if sum(v["counts"]) != v["count"]:
                raise ValueError(f"histogram {name!r}: bucket counts do "
                                 f"not sum to count")
            continue
        raise ValueError(f"metric {name!r} has unsupported value {v!r}")
