"""Telemetry facade: one object bundling the metrics registry, span
timer, JSONL event log, and per-request lifecycle records.

The serving engine owns exactly one of these (constructing its own when
the caller passes none); the quantization pipeline accepts one for
per-stage spans. Everything hangs off it so "telemetry off" is one
constructor flag away (``Telemetry(enabled=False)`` hands out null
instruments and a disabled event log — the BENCH_serve.json
``obs_overhead`` cell pins the enabled cost within noise).

Request lifecycle (engine-facing API)
-------------------------------------
``on_enqueue`` / ``on_admit`` / ``on_token`` / ``on_preempt`` /
``on_finish`` / ``on_reject`` keep a ``RequestRecord`` per rid, emit the
matching JSONL events, and feed the aggregate TTFT / inter-token-latency
histograms. Finished records move to a drain queue:
``drain_finished()`` returns-and-clears them, so a serving loop can
stream completed-request stats without unbounded growth.

Preemption is recompute-style (discard + replay), so a preempt resets
the victim's token count and first-token time; the invariant
``sum(record.tokens) == engine token counter`` holds at every tick and
is fuzz-tested.
"""
from __future__ import annotations

import json

from repro.obs.dispatch import snapshot_dispatch_counters
from repro.obs.events import EventLog, RequestRecord
from repro.obs.metrics import (
    COUNT_BUCKETS,
    LATENCY_BUCKETS_S,
    MetricsRegistry,
)
from repro.obs.spans import SpanTimer


class Telemetry:
    def __init__(self, *, enabled: bool = True,
                 events_out: str | None = None,
                 trace_dir: str | None = None,
                 step_ref=None):
        self.enabled = enabled
        self.registry = MetricsRegistry(enabled=enabled)
        self.spans = SpanTimer(self.registry, step_ref=step_ref)
        self.events = EventLog(events_out, enabled=enabled)
        self.trace_dir = trace_dir
        self.records: dict[int, RequestRecord] = {}
        self._finished: list[RequestRecord] = []
        # pre-bound aggregate instruments (hot-path: no dict lookups)
        self._ttft = self.registry.histogram("serve.ttft_s",
                                             LATENCY_BUCKETS_S)
        self._itl = self.registry.histogram("serve.itl_s",
                                            LATENCY_BUCKETS_S)
        self._tok = self.registry.counter("serve.tokens")

    # -- device profiler -----------------------------------------------------

    def start_trace(self):
        if self.enabled and self.trace_dir:
            self.spans.start_trace(self.trace_dir)

    def stop_trace(self):
        self.spans.stop_trace()

    # -- request lifecycle ---------------------------------------------------

    def on_enqueue(self, rid: int, prompt_len: int, max_new_tokens: int):
        if not self.enabled:
            return
        rec = self.records.get(rid)
        if rec is None:
            rec = self.records[rid] = RequestRecord(
                rid=rid, prompt_len=prompt_len,
                max_new_tokens=max_new_tokens)
        rec.enqueue_ts = self.events.now()
        self.registry.counter("serve.requests_enqueued").inc()
        self.events.emit("enqueue", rid=rid, prompt_len=prompt_len,
                         max_new_tokens=max_new_tokens)

    def on_reject(self, rid: int, error: str):
        if not self.enabled:
            return
        rec = self.records.pop(rid, RequestRecord(rid=rid))
        rec.finish_ts = self.events.now()
        rec.finish_reason = "rejected"
        self._finished.append(rec)
        self.registry.counter("serve.requests_rejected").inc()
        # short alias kept alongside the legacy name: dashboards/CI key on
        # serve.rejected; serve.requests_rejected predates it
        self.registry.counter("serve.rejected").inc()
        self.events.emit("reject", rid=rid, error=error)

    def on_prefix_hit(self, rid: int, pages: int, tokens: int):
        """An admitted request's prompt prefix was served from shared
        blocks: ``pages`` full pages / ``tokens`` prompt tokens skipped
        prefill entirely."""
        if not self.enabled:
            return
        self.registry.counter("serve.prefix_hits").inc()
        self.registry.counter("serve.prefix_hit_tokens").inc(tokens)
        self.events.emit("prefix_hit", rid=rid, pages=pages, tokens=tokens)

    def on_admit(self, rid: int, slot: int):
        if not self.enabled:
            return
        rec = self.records.get(rid)
        if rec is None:  # direct scheduler.submit callers skip enqueue
            rec = self.records[rid] = RequestRecord(rid=rid)
            rec.enqueue_ts = self.events.now()
        rec.admit_ts = self.events.now()
        self.registry.counter("serve.requests_admitted").inc()
        self.events.emit("admit", rid=rid, slot=slot)

    def on_token(self, rid: int):
        if not self.enabled:
            return
        rec = self.records.get(rid)
        if rec is None:
            return
        now = self.events.now()
        if rec.first_token_ts is None:
            rec.first_token_ts = now
            if rec.enqueue_ts is not None:
                self._ttft.observe(now - rec.enqueue_ts)
                self.events.emit("first_token", rid=rid,
                                 ttft_s=round(now - rec.enqueue_ts, 6))
        elif rec.last_token_ts is not None:
            self._itl.observe(now - rec.last_token_ts)
        rec.last_token_ts = now
        rec.tokens += 1
        self._tok.inc()

    def on_preempt(self, rid: int):
        if not self.enabled:
            return
        rec = self.records.get(rid)
        if rec is None:
            return
        discarded = rec.tokens
        self._tok.inc(-discarded)
        rec.on_preempt()
        self.registry.counter("serve.preemptions").inc()
        self.events.emit("preempt", rid=rid, tokens_discarded=discarded)

    def on_finish(self, rid: int, reason: str):
        if not self.enabled:
            return
        rec = self.records.pop(rid, None)
        if rec is None:
            return
        rec.finish_ts = self.events.now()
        rec.finish_reason = reason
        self._finished.append(rec)
        self.registry.counter("serve.requests_finished").inc()
        self.events.emit(
            "finish", rid=rid, tokens=rec.tokens, reason=reason,
            ttft_s=rec.ttft_s, itl_mean_s=rec.itl_mean_s,
            preemptions=rec.preemptions)

    # -- drain / export ------------------------------------------------------

    def request_token_total(self) -> int:
        """Tokens currently credited across live + finished records (the
        fuzz-tested twin of the engine's token counter)."""
        return (sum(r.tokens for r in self.records.values())
                + sum(r.tokens for r in self._finished))

    def drain_finished(self) -> list[RequestRecord]:
        out, self._finished = self._finished, []
        return out

    def metrics_snapshot(self) -> dict:
        """Registry metrics + kernel dispatch counters, JSON-able."""
        return {"metrics": self.registry.snapshot(),
                "dispatch": snapshot_dispatch_counters()}

    def write_metrics(self, path: str):
        with open(path, "w") as f:
            json.dump(self.metrics_snapshot(), f, indent=2)

    def close(self):
        self.stop_trace()
        self.events.close()
