"""Trace spans: context-manager wall-clock timers with nesting.

``SpanTimer.span("decode_tick")`` times a host-side phase and records it
into the owning registry under ``span.<dotted/path>`` — nested spans
record their full path (``span.decode_tick/upload``), so a snapshot reads
as a flame-graph-shaped breakdown: each name carries a fixed-bucket
latency histogram (count, sum, p50/p99) and the parent/child sums expose
how much of a tick went to upload vs dispatch vs sampling.

Device alignment: when a profiler trace is active (``start_trace`` /
``--trace-dir``), every span additionally enters a
``jax.profiler.StepTraceAnnotation`` so the host spans line up with
device timelines in TensorBoard/xprof. The annotation is only constructed
while a trace is running — with no trace the span costs two
``perf_counter`` calls and one histogram observe.

Spans do NOT force device sync: jax dispatch is async, so a span around a
bare dispatch measures host time only. Phases that should account device
time must contain their own sync point (the engine's decode tick does —
it downloads the sampled tokens before the span closes).
"""
from __future__ import annotations

import time
from contextlib import contextmanager

from repro.obs.metrics import LATENCY_BUCKETS_S, MetricsRegistry


class SpanTimer:
    def __init__(self, registry: MetricsRegistry, step_ref=None):
        self.registry = registry
        self._stack: list[str] = []
        self._tracing = False
        # optional 0-arg callable giving the current step number for
        # StepTraceAnnotation (the engine passes its tick counter)
        self._step_ref = step_ref

    # -- profiler integration ------------------------------------------------

    def start_trace(self, trace_dir: str):
        """Begin a device profiler trace; host spans become step
        annotations inside it. No-op (with a warning flag) when the jax
        profiler is unavailable on this backend."""
        import jax

        jax.profiler.start_trace(trace_dir)
        self._tracing = True

    def stop_trace(self):
        if self._tracing:
            import jax

            jax.profiler.stop_trace()
            self._tracing = False

    # -- spans ---------------------------------------------------------------

    @property
    def current_path(self) -> str:
        return "/".join(self._stack)

    @contextmanager
    def span(self, name: str):
        assert "/" not in name, "span names must be single segments"
        self._stack.append(name)
        path = "/".join(self._stack)
        ann = None
        if self._tracing:
            import jax

            step = self._step_ref() if self._step_ref is not None else None
            ann = jax.profiler.StepTraceAnnotation(path, step_num=step)
            ann.__enter__()
        t0 = time.perf_counter()
        try:
            yield
        finally:
            dt = time.perf_counter() - t0
            if ann is not None:
                ann.__exit__(None, None, None)
            popped = self._stack.pop()
            assert popped == name, (popped, name)
            self.registry.histogram(f"span.{path}",
                                    LATENCY_BUCKETS_S).observe(dt)

    def timed(self, name: str, fn, *args, **kwargs):
        """Run ``fn`` under a span; returns its result."""
        with self.span(name):
            return fn(*args, **kwargs)
