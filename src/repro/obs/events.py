"""JSONL event stream + per-request lifecycle records.

Events are flat JSON objects, one per line, each carrying ``ts`` (host
``perf_counter`` seconds relative to the log's epoch — monotonic,
subtraction-safe) and ``event`` (the type). The engine emits the request
lifecycle (enqueue -> admit -> first_token -> finish, plus preempt /
reject) and the quantization pipeline emits per-stage/per-target rows;
``EVENT_FIELDS`` is the schema the CI metrics smoke step and tests/obs
validate against.

``RequestRecord`` is the accumulated per-request view of those events:
TTFT (enqueue -> first sampled token), mean inter-token latency, token
count, preemption count, and finish reason. Preemption is recompute-style
in this engine (generated tokens are discarded and regenerated), so a
preempt RESETS the record's token count and first-token time — the
record describes the attempt that actually delivered tokens, and the sum
of record token counts stays equal to the engine's token counter (a
fuzz-tested invariant).
"""
from __future__ import annotations

import dataclasses
import json
import time

# event type -> required fields (beyond ts/event). Extra fields are
# allowed; missing ones fail validation.
EVENT_FIELDS: dict[str, tuple] = {
    "enqueue": ("rid", "prompt_len", "max_new_tokens"),
    "admit": ("rid", "slot"),
    "first_token": ("rid", "ttft_s"),
    "token": ("rid",),          # optional per-token stream (off by default)
    "preempt": ("rid", "tokens_discarded"),
    "finish": ("rid", "tokens", "reason", "ttft_s", "itl_mean_s",
               "preemptions"),
    "reject": ("rid", "error"),
    "prefix_hit": ("rid", "pages", "tokens"),
    "quant_stage": ("stage", "block", "seconds"),
    "quant_target": ("name", "action", "seconds"),
}

FINISH_REASONS = ("length", "eos", "rejected", "aborted")


def validate_event(ev: dict):
    """Raise ValueError unless ``ev`` matches the schema."""
    if not isinstance(ev, dict):
        raise ValueError(f"event must be a dict, got {type(ev)}")
    etype = ev.get("event")
    if etype not in EVENT_FIELDS:
        raise ValueError(f"unknown event type {etype!r}")
    if not isinstance(ev.get("ts"), (int, float)):
        raise ValueError(f"event {etype!r} missing numeric ts")
    missing = [f for f in EVENT_FIELDS[etype] if f not in ev]
    if missing:
        raise ValueError(f"event {etype!r} missing fields {missing}")
    if etype == "finish" and ev["reason"] not in FINISH_REASONS:
        raise ValueError(f"finish reason {ev['reason']!r} not in "
                         f"{FINISH_REASONS}")


class EventLog:
    """Append-only event sink: an in-memory ring (tests / drain API) plus
    an optional JSONL file. Disabled logs are free (emit returns at once).
    """

    def __init__(self, path: str | None = None, enabled: bool = True,
                 keep: int = 4096):
        self.enabled = enabled
        self.path = path
        self.keep = keep
        self.events: list[dict] = []
        self._fh = open(path, "w") if (enabled and path) else None
        self._epoch = time.perf_counter()

    def now(self) -> float:
        return time.perf_counter() - self._epoch

    def emit(self, event: str, **fields):
        if not self.enabled:
            return
        ev = {"ts": round(self.now(), 6), "event": event, **fields}
        self.events.append(ev)
        if len(self.events) > self.keep:
            del self.events[: -self.keep]
        if self._fh is not None:
            self._fh.write(json.dumps(ev) + "\n")

    def flush(self):
        if self._fh is not None:
            self._fh.flush()

    def close(self):
        if self._fh is not None:
            self._fh.flush()
            self._fh.close()
            self._fh = None


def read_jsonl(path: str) -> list[dict]:
    """Load and validate a JSONL event file (CI smoke / tests)."""
    out = []
    with open(path) as f:
        for line in f:
            line = line.strip()
            if not line:
                continue
            ev = json.loads(line)
            validate_event(ev)
            out.append(ev)
    return out


@dataclasses.dataclass
class RequestRecord:
    """Per-request lifecycle accumulator (timestamps in EventLog time)."""

    rid: int
    prompt_len: int = 0
    max_new_tokens: int = 0
    enqueue_ts: float | None = None
    admit_ts: float | None = None
    first_token_ts: float | None = None
    last_token_ts: float | None = None
    finish_ts: float | None = None
    tokens: int = 0
    preemptions: int = 0
    finish_reason: str | None = None

    @property
    def ttft_s(self) -> float | None:
        """Enqueue -> first token of the attempt that delivered (resets
        on preempt, matching the recompute-style discard)."""
        if self.first_token_ts is None or self.enqueue_ts is None:
            return None
        return self.first_token_ts - self.enqueue_ts

    @property
    def itl_mean_s(self) -> float | None:
        if self.tokens < 2 or self.first_token_ts is None:
            return None
        return ((self.last_token_ts - self.first_token_ts)
                / (self.tokens - 1))

    def on_preempt(self):
        self.preemptions += 1
        self.tokens = 0
        self.first_token_ts = None
        self.last_token_ts = None

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        d["ttft_s"] = self.ttft_s
        d["itl_mean_s"] = self.itl_mean_s
        return d
