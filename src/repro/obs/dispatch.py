"""Kernel-dispatch counter registry.

The dispatch counters (`models/attention._FLASH_IMPL`/`_PAGED_IMPL`,
`core/vq_linear._VQ_IMPL`, `models/common` matmul sites) bump at *trace*
time: they pin which implementation was actually baked into a jitted
computation, catching silent fallbacks (a requested Pallas path quietly
taking the XLA branch). Before this module each site owned a raw module
global that tests mutated and diffed ad hoc, leaking counts across test
packages; now every site registers its counts dict here once at import
and the supported surface is:

* ``register_dispatch(source, impls)`` — called by the owning module at
  import; returns the (shared, live) counts dict it should bump. The dict
  identity is stable across ``reset_dispatch_counters()`` so the bump
  sites stay one plain ``counts[impl] += 1`` with zero indirection on the
  trace path.
* ``snapshot_dispatch_counters()`` — deep copy of every source's counts
  ({source: {impl: n}}), fed into telemetry metric snapshots.
* ``reset_dispatch_counters()`` — zero all counts in place (the shared
  test fixture; suites no longer leak counts into each other).

This module is dependency-free (no jax) so any layer can import it.
"""
from __future__ import annotations

_COUNTERS: dict[str, dict[str, int]] = {}


def register_dispatch(source: str, impls) -> dict[str, int]:
    """Get-or-create the live counts dict for ``source``. Idempotent:
    re-registration (module reload) returns the existing dict so every
    holder keeps bumping the same object."""
    d = _COUNTERS.get(source)
    if d is None:
        d = _COUNTERS[source] = {impl: 0 for impl in impls}
    else:
        for impl in impls:
            d.setdefault(impl, 0)
    return d


def snapshot_dispatch_counters() -> dict[str, dict[str, int]]:
    """Deep copy of every registered source's counts."""
    return {src: dict(counts) for src, counts in _COUNTERS.items()}


def reset_dispatch_counters() -> None:
    """Zero every registered counter IN PLACE (dict identities survive)."""
    for counts in _COUNTERS.values():
        for impl in counts:
            counts[impl] = 0
