"""Next-token cross-entropy with z-loss, computed in fp32."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def next_token_loss(logits: jax.Array, tokens: jax.Array,
                    z_loss: float = 1e-4):
    """logits: (B, S, V) fp32; tokens: (B, S). Predict token[t+1] from t.

    Returns (loss, metrics). Final position has no target and is masked.
    """
    B, S, V = logits.shape
    targets = tokens[:, 1:]
    lg = logits[:, : S - 1]
    lse = jax.nn.logsumexp(lg, axis=-1)
    # one-hot contraction instead of take_along_axis: a gather over the
    # vocab-sharded axis forces GSPMD to replicate the logits ("involuntary
    # full rematerialization"); the one-hot dot stays sharded (§Perf it.2)
    onehot = jax.nn.one_hot(targets, V, dtype=lg.dtype)
    picked = jnp.einsum("bsv,bsv->bs", lg, onehot)
    nll = lse - picked
    zl = z_loss * jnp.square(lse)
    loss = jnp.mean(nll + zl)
    return loss, {
        "nll": jnp.mean(nll),
        "ppl_proxy": jnp.exp(jnp.clip(jnp.mean(nll), 0, 20.0)),
    }


def perplexity(model, params, tokens: jax.Array, batch_extra=None) -> float:
    """Eval-time token perplexity of a (possibly quantized) model."""
    batch = {"tokens": tokens}
    if batch_extra:
        batch.update(batch_extra)
    logits, _, _ = model.forward(params, batch, remat=False)
    S = tokens.shape[1]
    logits = logits[:, -S:, :]
    _, metrics = next_token_loss(logits.astype(jnp.float32), tokens, z_loss=0.0)
    return float(jnp.exp(metrics["nll"]))
