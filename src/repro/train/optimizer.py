"""AdamW with fp32 master weights, global-norm clipping, cosine schedule.

Pure JAX (optax is unavailable offline). State layout is FSDP-friendly:
``m``/``v``/``master`` mirror the parameter tree, so the parameter partition
specs apply leaf-for-leaf (launch/dryrun.py relies on this).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any
    master: Any  # fp32 master copy of (possibly bf16) params


@dataclasses.dataclass(frozen=True)
class OptConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1
    # storage dtype for the m/v moment buffers: "float32" (default) or
    # "bfloat16" (halves optimizer HBM at >100B scale; math stays f32 —
    # §Perf iteration 6, dbrx-132b train_4k)
    moment_dtype: str = "float32"
    # dtype of the microbatch gradient accumulator (the updates themselves
    # are f32 in the optimizer); bf16 halves a params-sized temp buffer
    grad_accum_dtype: str = "float32"


def schedule(cfg: OptConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (
        1 + jnp.cos(math.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init(params, cfg: OptConfig | None = None) -> AdamWState:
    mdt = jnp.dtype((cfg.moment_dtype if cfg else "float32"))
    f32 = lambda p: p.astype(jnp.float32)
    zeros = lambda p: jnp.zeros(p.shape, mdt)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
        master=jax.tree.map(f32, params),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(g.astype(jnp.float32)))
        for g in jax.tree.leaves(tree)))


def update(cfg: OptConfig, grads, state: AdamWState, param_dtype=None):
    """Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    lr = schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    b1, b2 = cfg.b1, cfg.b2
    t = step.astype(jnp.float32)
    bc1 = 1 - b1**t
    bc2 = 1 - b2**t

    mdt = jnp.dtype(cfg.moment_dtype)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m_f = b1 * m.astype(jnp.float32) + (1 - b1) * g
        v_f = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
        u = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        # decoupled weight decay on non-1D params (skip norms/biases)
        wd = cfg.weight_decay if p.ndim >= 2 else 0.0
        p = p - lr * (u + wd * p)
        return m_f.astype(mdt), v_f.astype(mdt), p

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(state.master)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_m = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_master = jax.tree.unflatten(treedef, [o[2] for o in out])
    dt = param_dtype
    new_params = jax.tree.map(
        lambda p: p if dt is None else p.astype(dt), new_master)
    new_state = AdamWState(step=step, m=new_m, v=new_v, master=new_master)
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}


def state_specs(param_specs) -> AdamWState:
    """Partition specs for the optimizer state (mirrors the params)."""
    from jax.sharding import PartitionSpec as P
    return AdamWState(
        step=P(),
        m=param_specs,
        v=param_specs,
        master=param_specs,
    )
