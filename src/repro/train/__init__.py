"""train."""
