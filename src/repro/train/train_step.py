"""Microbatched, remat'd, FSDP-ready train step.

Gradient accumulation runs as a ``lax.scan`` over microbatches so the live
activation set is one microbatch deep; with scan-over-layers + per-layer
remat inside the model, per-device activation memory is
O(seq * d_model * n_layers / microbatches) — the combination that lets
qwen2-72b / dbrx-132b train_4k fit 16 GB v5e HBM (EXPERIMENTS.md §Dry-run).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import common as cm
from repro.models.model_zoo import Model
from repro.train import loss as loss_lib
from repro.train import optimizer as opt


class TrainState(NamedTuple):
    params: Any
    opt: opt.AdamWState


def init_state(model: Model, key, opt_cfg: opt.OptConfig) -> TrainState:
    params = model.init_params(key)
    return TrainState(params=params, opt=opt.init(params, opt_cfg))


def make_train_step(model: Model, opt_cfg: opt.OptConfig,
                    microbatches: int = 1, remat: bool = True):
    """Returns train_step(state, batch) -> (state, metrics).

    batch["tokens"]: (global_batch, S) — sharded over (pod, data) by pjit.
    """
    param_dtype = cm.DTYPES[model.cfg.dtype]

    def loss_fn(params, mb):
        logits, _, aux = model.forward(params, mb, remat=remat)
        S = mb["tokens"].shape[1]
        logits = logits[:, -S:, :]
        ce, metrics = loss_lib.next_token_loss(
            logits.astype(jnp.float32), mb["tokens"])
        return ce + aux, metrics

    def train_step(state: TrainState, batch):
        B = batch["tokens"].shape[0]
        assert B % microbatches == 0
        mb_size = B // microbatches

        def split(x):
            return x.reshape(microbatches, mb_size, *x.shape[1:])

        mbs = jax.tree.map(split, batch)
        acc_dt = jnp.dtype(opt_cfg.grad_accum_dtype)
        zero_grads = jax.tree.map(
            lambda p: jnp.zeros(p.shape, acc_dt), state.params)

        def mb_body(carry, mb):
            acc, metrics_acc = carry
            (l, metrics), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                state.params, mb)
            acc = jax.tree.map(
                lambda a, g: a + (g.astype(jnp.float32)
                                  / microbatches).astype(acc_dt),
                acc, grads)
            metrics = dict(metrics, loss=l)
            metrics_acc = jax.tree.map(
                lambda a, x: a + x / microbatches, metrics_acc, metrics)
            return (acc, metrics_acc), None

        metrics0 = {"loss": jnp.zeros(()), "nll": jnp.zeros(()),
                    "ppl_proxy": jnp.zeros(())}
        if microbatches == 1:
            (grads, metrics), _ = mb_body((zero_grads, metrics0),
                                          jax.tree.map(lambda x: x[0], mbs))
        else:
            (grads, metrics), _ = jax.lax.scan(
                mb_body, (zero_grads, metrics0), mbs)

        new_params, new_opt, opt_metrics = opt.update(
            opt_cfg, grads, state.opt, param_dtype=param_dtype)
        metrics.update(opt_metrics)
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step
