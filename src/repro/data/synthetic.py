"""Deterministic synthetic corpus (WikiText-2 is unavailable offline).

A mixture of Zipfian n-gram "sources": each document picks a source; tokens
are drawn from a source-specific bigram chain over a Zipf-distributed
vocabulary. This produces learnable structure (bigram statistics + topical
clustering) so perplexity deltas between quantization settings behave
qualitatively like real text (DESIGN.md §6.3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


@functools.partial(jax.jit, static_argnames=("vocab", "seq_len", "batch",
                                             "n_sources"))
def sample_batch(key, vocab: int, seq_len: int, batch: int,
                 n_sources: int = 8) -> jax.Array:
    """(batch, seq_len) int32 tokens."""
    k_src, k_start, k_tok = jax.random.split(key, 3)
    # Zipf-ish unigram over vocab, per source rotation
    ranks = jnp.arange(vocab, dtype=jnp.float32) + 1.0
    base_logits = -1.1 * jnp.log(ranks)

    src = jax.random.randint(k_src, (batch,), 0, n_sources)
    # each source permutes the vocab by a fixed stride (cheap deterministic)
    strides = 1 + 2 * jnp.arange(n_sources)

    def sample_row(key_row, s):
        stride = strides[s]
        logits = base_logits[(jnp.arange(vocab) * stride) % vocab]

        def step(carry, k):
            prev = carry
            # bigram structure: strong pull toward prev+delta(source)
            biased = logits.at[(prev * 7 + stride) % vocab].add(4.0)
            biased = biased.at[(prev + 1) % vocab].add(3.0)
            tok = jax.random.categorical(k, biased)
            return tok, tok

        start = jax.random.randint(key_row, (), 0, vocab)
        _, toks = jax.lax.scan(step, start,
                               jax.random.split(key_row, seq_len))
        return toks

    keys = jax.random.split(k_tok, batch)
    return jax.vmap(sample_row)(keys, src).astype(jnp.int32)


class SyntheticStream:
    """Sharded, resumable token stream (step index is the only state)."""

    def __init__(self, vocab: int, seq_len: int, global_batch: int,
                 seed: int = 0, shard_id: int = 0, n_shards: int = 1):
        assert global_batch % n_shards == 0
        self.vocab, self.seq_len = vocab, seq_len
        self.batch = global_batch // n_shards
        self.seed, self.shard_id, self.n_shards = seed, shard_id, n_shards
        self.step = 0

    def next(self) -> jax.Array:
        key = jax.random.fold_in(
            jax.random.fold_in(jax.random.PRNGKey(self.seed), self.step),
            self.shard_id)
        self.step += 1
        return sample_batch(key, self.vocab, self.seq_len, self.batch)

    def state(self) -> dict:
        return {"step": self.step, "seed": self.seed}

    def restore(self, state: dict):
        self.step = int(state["step"])
        self.seed = int(state["seed"])
