"""Calibration dataset for post-training quantization.

The paper samples 128 sequences of 2048 tokens from the WikiText-2 training
set; we mirror the shape with the synthetic corpus (or user token files via
``from_token_file``), and shard sequences across data-parallel quantization
workers (each worker accumulates partial Hessians; one psum merges them).
"""
from __future__ import annotations

import numpy as np
import jax
import jax.numpy as jnp

from repro.data.synthetic import sample_batch


def calibration_tokens(vocab: int, n_sequences: int = 128,
                       seq_len: int = 2048, seed: int = 1234) -> jax.Array:
    """(n_sequences, seq_len) int32, deterministic."""
    key = jax.random.PRNGKey(seed)
    out = []
    bs = min(16, n_sequences)
    for i in range(0, n_sequences, bs):
        out.append(sample_batch(jax.random.fold_in(key, i), vocab, seq_len, bs))
    return jnp.concatenate(out, axis=0)[:n_sequences]


def from_token_file(path: str, n_sequences: int, seq_len: int) -> jax.Array:
    """Load a flat .npy int token file and window it into sequences."""
    toks = np.load(path).astype(np.int32).reshape(-1)
    need = n_sequences * seq_len
    assert toks.size >= need, f"token file too small: {toks.size} < {need}"
    return jnp.asarray(toks[:need].reshape(n_sequences, seq_len))


def shard_for_worker(tokens: jax.Array, worker: int, n_workers: int):
    n = tokens.shape[0]
    per = n // n_workers
    return tokens[worker * per : (worker + 1) * per]
