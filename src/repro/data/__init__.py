"""data."""
