"""Low-bit KV-page quantization math, shared by every paged read/write path.

One module owns the code <-> value mapping so the write site
(models/attention._paged_apply), the XLA gather read path, the oracle
(kernels/ref.paged_attention_ref), and the fused Pallas kernel
(kernels/paged_attention.py) stay bitwise-consistent: they all call
``quantize_kv`` / ``dequant_rows`` here, so a page decodes to the exact
same f32 values no matter which path reads it.

Format: symmetric per-row (per written token), per-kv-head scales —
``scale[row, kv] = amax(|x[row, kv, :]|) / qmax`` stored f32 alongside the
page, codes ``clip(round(x / scale), -qmax, qmax)`` stored int8. int4 packs
two codes per int8 byte along the head dim (column 2j in the low nibble,
2j+1 in the high nibble), so an int4 page is a real byte-for-byte half of
an int8 page, not int4-in-int8 cosplay. Per-row scales make incremental
page writes exact: a decode tick quantizes only the token it appends and
never re-quantizes (or re-scales) rows another tick already wrote — which
is also what makes preemption-replay and interleaved-vs-solo serving
bit-reproducible under a quantized pool.

Zero rows get scale 0 (codes are computed against a div-safe scale of 1
and are all 0); dequant is then exactly 0 — no NaN path. Stale rows in
recycled blocks carry stale codes AND stale scales; both decode to finite
garbage that the serving mask ``kpos <= pos`` discards, the same invariant
that already covers stale fp16 keys.

Vector-quantized pages (``bits == VQ_BITS``, "vq2"): instead of scalar
codes, a row stores 4-bit *codebook indices* over d=2 vectors along the
head dim — 2 bits per value, past the int4 cliff. The codebook is
per-(pool, kv-head), shape (k_c=16, d=2), frozen after engine-load
calibration; rows are amax-normalized to [-1, 1] before assignment and
the per-row f32 scale is kept, so dequant is ``codebook[idx] * scale``
and the zero-row / stale-row invariants above carry over unchanged.
``vq_dequant_rows`` is the single shared decode expression (the lookup
is a one-hot matmul, bitwise-equal to a gather in f32, and usable
verbatim inside the Pallas kernel's VMEM).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# bits -> largest code magnitude (symmetric; int4 uses [-7, 7], leaving
# -8 unused, so dequant needs no asymmetric zero-point)
QMAX = {8: 127, 4: 7}
PASSTHROUGH_BITS = 16

# vector-quantized page format: d=2 vectors along head dim, 16-entry
# per-(pool, kv-head) codebooks -> 4-bit indices, 2 bits per value
VQ_BITS = "vq2"
VQ_D = 2
VQ_K = 16


def storage_cols(hd: int, bits) -> int:
    """Last-axis width of a quantized pool holding ``hd`` head dims."""
    if bits == VQ_BITS:
        # one 4-bit index per d=2 vector, two indices packed per byte
        assert hd % (2 * VQ_D) == 0, \
            f"vq2 packing needs head_dim % 4 == 0, got {hd}"
        return hd // (2 * VQ_D)
    if bits == 4:
        assert hd % 2 == 0, f"int4 packing needs even head_dim, got {hd}"
        return hd // 2
    assert bits == 8, bits
    return hd


def infer_bits(stored_cols: int, hd: int) -> int:
    """Recover the code width from the pool's stored last axis. A packed
    int4 pool stores hd//2 bytes per row; int8 stores hd."""
    if stored_cols == hd:
        return 8
    assert stored_cols == hd // 2, (stored_cols, hd)
    return 4


def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """(..., hd) int8 codes in [-7, 7] -> (..., hd//2) int8 bytes."""
    lo = codes[..., 0::2] & jnp.int8(0x0F)
    hi = codes[..., 1::2] & jnp.int8(0x0F)
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., hd//2) int8 bytes -> (..., hd) int8 codes (sign-extended)."""
    lo = (packed << 4) >> 4          # arithmetic shifts sign-extend
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def quantize_kv(x: jnp.ndarray, bits: int):
    """Quantize fresh K or V rows for a page write.

    x: (..., KV, hd) float -> (codes (..., KV, storage_cols) int8,
    scales (..., KV) f32). Per-(row, kv-head) symmetric amax scaling.
    """
    qmax = QMAX[bits]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = amax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(xf / safe[..., None]), -qmax, qmax)
    codes = codes.astype(jnp.int8)
    if bits == 4:
        codes = pack_int4(codes)
    return codes, scale


def dequant_rows(codes: jnp.ndarray, scales: jnp.ndarray,
                 bits: int) -> jnp.ndarray:
    """codes (..., storage_cols) int8 + scales (...,) f32 -> (..., hd) f32.

    The single decode expression every read path shares (XLA gather,
    oracle, and — op for op — the Pallas kernel's in-VMEM dequant).
    """
    if bits == 4:
        codes = unpack_int4(codes)
    return codes.astype(jnp.float32) * scales[..., None].astype(jnp.float32)


# ---------------------------------------------------------------------------
# vector-quantized pages (vq2)
# ---------------------------------------------------------------------------

def default_codebook(n_kv_heads: int) -> jnp.ndarray:
    """Deterministic uncalibrated codebook: a 4x4 uniform grid over
    [-1, 1]^2, one copy per kv head -> (KV, VQ_K, VQ_D) f32. Rows are
    amax-normalized before assignment, so the grid degrades gracefully
    to ~2-bit uniform scalar quantization until engine-load calibration
    replaces it with an EM fit of the actual K/V distribution."""
    axis = jnp.linspace(-1.0, 1.0, 4, dtype=jnp.float32)
    gx, gy = jnp.meshgrid(axis, axis, indexing="ij")
    grid = jnp.stack([gx.reshape(-1), gy.reshape(-1)], axis=-1)
    return jnp.broadcast_to(grid[None], (n_kv_heads, VQ_K, VQ_D))


def pack_vq2(idx: jnp.ndarray) -> jnp.ndarray:
    """(..., hd//VQ_D) int indices in [0, 16) -> (..., hd//4) int8 bytes
    (index 2j in the low nibble, 2j+1 in the high nibble)."""
    lo = idx[..., 0::2].astype(jnp.int32) & 0x0F
    hi = idx[..., 1::2].astype(jnp.int32) & 0x0F
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_vq2(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., hd//4) int8 bytes -> (..., hd//VQ_D) int32 indices in
    [0, 16). Unlike ``unpack_int4`` the nibbles are UNSIGNED table
    indices, so the high nibble is masked, never sign-extended."""
    p = packed.astype(jnp.int32) & 0xFF
    lo = p & 0x0F
    hi = (p >> 4) & 0x0F
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def vq_quantize_rows(x: jnp.ndarray, codebook: jnp.ndarray):
    """Assign fresh K or V rows to a frozen codebook for a page write.

    x: (..., KV, hd) float, codebook (KV, VQ_K, VQ_D) f32 ->
    (codes (..., KV, hd//4) int8, scales (..., KV) f32). Rows are
    amax-normalized per (row, kv-head), split into d=2 vectors along the
    head dim, and each vector takes the L2-nearest codebook entry
    (argmin, lowest index on ties — deterministic, so replayed writes
    are bit-identical). Zero rows keep scale 0 and decode to exactly 0.
    """
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    safe = jnp.where(amax > 0, amax, 1.0)
    xn = xf / safe[..., None]
    vecs = xn.reshape(*xn.shape[:-1], xn.shape[-1] // VQ_D, VQ_D)
    # (..., KV, S, 1, d) - (KV, 1, k_c, d) -> (..., KV, S, k_c)
    diff = vecs[..., :, None, :] - codebook[..., None, :, :].astype(
        jnp.float32)
    dist = jnp.sum(diff * diff, axis=-1)
    idx = jnp.argmin(dist, axis=-1)
    return pack_vq2(idx), amax


def vq_dequant_rows(codes: jnp.ndarray, scales: jnp.ndarray,
                    codebook: jnp.ndarray) -> jnp.ndarray:
    """codes (..., hd//4) int8 + scales (...,) f32 + codebook
    (..., VQ_K, VQ_D) f32 (leading dims broadcast against codes') ->
    (..., hd) f32.

    The single VQ decode expression every read path shares. The table
    lookup is a one-hot matmul — bitwise-equal to ``codebook[idx]`` in
    f32 (one 1.0 against zeros) and expressible inside the Pallas
    kernel's VMEM, where a gather over a traced index tensor is not.
    """
    idx = unpack_vq2(codes)
    onehot = jax.nn.one_hot(idx, VQ_K, dtype=jnp.float32)
    vecs = jnp.matmul(onehot, codebook.astype(jnp.float32))
    out = vecs.reshape(*codes.shape[:-1], -1)
    return out * scales[..., None].astype(jnp.float32)


# ---------------------------------------------------------------------------
# byte accounting (serve-side pool sizing)
# ---------------------------------------------------------------------------

def row_bytes(hd: int, bits, *, dtype_bytes: int = 2) -> int:
    """Bytes one written token costs per kv head in ONE pool (K or V),
    including its f32 scale. ``dtype_bytes`` is the passthrough pool's
    element size (2 for bf16 serving, 4 for the fp32 CPU bench host)."""
    if bits == PASSTHROUGH_BITS:
        return hd * dtype_bytes
    if bits == VQ_BITS:
        return hd // (2 * VQ_D) + 4
    return hd * bits // 8 + 4


def vq_overhead_bytes(n_kv_heads: int) -> int:
    """Fixed per-layer cost of the two frozen f32 codebooks (K and V
    pools). Charged once against the pool byte budget in
    ``blocks_for_bytes`` — it does not scale with the block count, so
    amortizing it into ``page_bytes`` would misprice every pool size."""
    return 2 * n_kv_heads * VQ_K * VQ_D * 4


def page_bytes(page_size: int, n_kv_heads: int, hd: int, bits, *,
               dtype_bytes: int = 2) -> int:
    """Bytes of one physical block across BOTH K and V pools (+ scales;
    for vq2 the rows are packed indices + scales — the codebook itself
    is per-layer, see ``vq_overhead_bytes``)."""
    return 2 * page_size * n_kv_heads * row_bytes(hd, bits,
                                                  dtype_bytes=dtype_bytes)


def blocks_for_bytes(pool_bytes: int, page_size: int, n_kv_heads: int,
                     hd: int, bits, *, dtype_bytes: int = 2) -> int:
    """How many physical blocks (incl. the reserved scratch block 0) a
    per-layer byte budget buys — the allocator then exposes
    ``blocks - 1`` usable pages, which is where the quantized-page
    headroom at fixed pool bytes becomes visible (2-4x scalar, ~10x
    vq2). For vq2 the codebook overhead is deducted from the budget
    before dividing. An explicit budget too small for scratch + one
    usable block is a config error, not something to silently round up
    past."""
    per_block = page_bytes(page_size, n_kv_heads, hd, bits,
                           dtype_bytes=dtype_bytes)
    budget = pool_bytes
    if bits == VQ_BITS:
        budget = pool_bytes - vq_overhead_bytes(n_kv_heads)
    n = int(budget // per_block)
    if n < 2:
        raise ValueError(
            f"pool_bytes={pool_bytes} buys {n} block(s) of {per_block} B "
            f"(page_size={page_size}, kv_bits={bits}); need >= 2 "
            f"(scratch + one usable)")
    return n
