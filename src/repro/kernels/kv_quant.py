"""Low-bit KV-page quantization math, shared by every paged read/write path.

One module owns the code <-> value mapping so the write site
(models/attention._paged_apply), the XLA gather read path, the oracle
(kernels/ref.paged_attention_ref), and the fused Pallas kernel
(kernels/paged_attention.py) stay bitwise-consistent: they all call
``quantize_kv`` / ``dequant_rows`` here, so a page decodes to the exact
same f32 values no matter which path reads it.

Format: symmetric per-row (per written token), per-kv-head scales —
``scale[row, kv] = amax(|x[row, kv, :]|) / qmax`` stored f32 alongside the
page, codes ``clip(round(x / scale), -qmax, qmax)`` stored int8. int4 packs
two codes per int8 byte along the head dim (column 2j in the low nibble,
2j+1 in the high nibble), so an int4 page is a real byte-for-byte half of
an int8 page, not int4-in-int8 cosplay. Per-row scales make incremental
page writes exact: a decode tick quantizes only the token it appends and
never re-quantizes (or re-scales) rows another tick already wrote — which
is also what makes preemption-replay and interleaved-vs-solo serving
bit-reproducible under a quantized pool.

Zero rows get scale 0 (codes are computed against a div-safe scale of 1
and are all 0); dequant is then exactly 0 — no NaN path. Stale rows in
recycled blocks carry stale codes AND stale scales; both decode to finite
garbage that the serving mask ``kpos <= pos`` discards, the same invariant
that already covers stale fp16 keys.
"""
from __future__ import annotations

import jax.numpy as jnp

# bits -> largest code magnitude (symmetric; int4 uses [-7, 7], leaving
# -8 unused, so dequant needs no asymmetric zero-point)
QMAX = {8: 127, 4: 7}
PASSTHROUGH_BITS = 16


def storage_cols(hd: int, bits: int) -> int:
    """Last-axis width of a quantized pool holding ``hd`` head dims."""
    if bits == 4:
        assert hd % 2 == 0, f"int4 packing needs even head_dim, got {hd}"
        return hd // 2
    assert bits == 8, bits
    return hd


def infer_bits(stored_cols: int, hd: int) -> int:
    """Recover the code width from the pool's stored last axis. A packed
    int4 pool stores hd//2 bytes per row; int8 stores hd."""
    if stored_cols == hd:
        return 8
    assert stored_cols == hd // 2, (stored_cols, hd)
    return 4


def pack_int4(codes: jnp.ndarray) -> jnp.ndarray:
    """(..., hd) int8 codes in [-7, 7] -> (..., hd//2) int8 bytes."""
    lo = codes[..., 0::2] & jnp.int8(0x0F)
    hi = codes[..., 1::2] & jnp.int8(0x0F)
    return (lo | (hi << 4)).astype(jnp.int8)


def unpack_int4(packed: jnp.ndarray) -> jnp.ndarray:
    """(..., hd//2) int8 bytes -> (..., hd) int8 codes (sign-extended)."""
    lo = (packed << 4) >> 4          # arithmetic shifts sign-extend
    hi = packed >> 4
    return jnp.stack([lo, hi], axis=-1).reshape(*packed.shape[:-1], -1)


def quantize_kv(x: jnp.ndarray, bits: int):
    """Quantize fresh K or V rows for a page write.

    x: (..., KV, hd) float -> (codes (..., KV, storage_cols) int8,
    scales (..., KV) f32). Per-(row, kv-head) symmetric amax scaling.
    """
    qmax = QMAX[bits]
    xf = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(xf), axis=-1)
    scale = amax / qmax
    safe = jnp.where(scale > 0, scale, 1.0)
    codes = jnp.clip(jnp.round(xf / safe[..., None]), -qmax, qmax)
    codes = codes.astype(jnp.int8)
    if bits == 4:
        codes = pack_int4(codes)
    return codes, scale


def dequant_rows(codes: jnp.ndarray, scales: jnp.ndarray,
                 bits: int) -> jnp.ndarray:
    """codes (..., storage_cols) int8 + scales (...,) f32 -> (..., hd) f32.

    The single decode expression every read path shares (XLA gather,
    oracle, and — op for op — the Pallas kernel's in-VMEM dequant).
    """
    if bits == 4:
        codes = unpack_int4(codes)
    return codes.astype(jnp.float32) * scales[..., None].astype(jnp.float32)


# ---------------------------------------------------------------------------
# byte accounting (serve-side pool sizing)
# ---------------------------------------------------------------------------

def row_bytes(hd: int, bits: int, *, dtype_bytes: int = 2) -> int:
    """Bytes one written token costs per kv head in ONE pool (K or V),
    including its f32 scale. ``dtype_bytes`` is the passthrough pool's
    element size (2 for bf16 serving, 4 for the fp32 CPU bench host)."""
    if bits == PASSTHROUGH_BITS:
        return hd * dtype_bytes
    return hd * bits // 8 + 4


def page_bytes(page_size: int, n_kv_heads: int, hd: int, bits: int, *,
               dtype_bytes: int = 2) -> int:
    """Bytes of one physical block across BOTH K and V pools (+ scales)."""
    return 2 * page_size * n_kv_heads * row_bytes(hd, bits,
                                                  dtype_bytes=dtype_bytes)


def blocks_for_bytes(pool_bytes: int, page_size: int, n_kv_heads: int,
                     hd: int, bits: int, *, dtype_bytes: int = 2) -> int:
    """How many physical blocks (incl. the reserved scratch block 0) a
    per-layer byte budget buys — the allocator then exposes
    ``blocks - 1`` usable pages, which is where the 2-4x quantized-page
    headroom at fixed pool bytes becomes visible. An explicit budget too
    small for scratch + one usable block is a config error, not something
    to silently round up past."""
    per_block = page_bytes(page_size, n_kv_heads, hd, bits,
                           dtype_bytes=dtype_bytes)
    n = int(pool_bytes // per_block)
    if n < 2:
        raise ValueError(
            f"pool_bytes={pool_bytes} buys {n} block(s) of {per_block} B "
            f"(page_size={page_size}, kv_bits={bits}); need >= 2 "
            f"(scratch + one usable)")
    return n
