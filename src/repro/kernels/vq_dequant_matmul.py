"""Fused VQ-decode + matmul Pallas TPU kernel — the serving hot-spot.

TPU adaptation of the paper's ARM-TBL decode kernel (DESIGN.md §3): the
bit-packed index matrix is the HBM payload (2-4.5 bits/weight); codebooks
live in VMEM; decode happens on-chip and the reconstructed tile feeds the
MXU directly, so the dense weight matrix never round-trips through HBM.

Centroid lookup uses the one-hot-matmul trick (``one_hot(codes) @ codebook``)
instead of a gather: TPU gathers serialize on the scalar unit, whereas the
one-hot contraction runs on the MXU at full tile throughput — this is the
core 'rethink the GPU/CPU algorithm for the TPU memory hierarchy' decision.

Layout contract (matches core/vq_linear.VQLinear):
  x          (M, K)                      activations
  words      (N, K/d * bits / 32)        packed uint32 codes, row-major
  codebooks  (n_cg, n_bands, k_c, d)     fp32 (int8 codebook * scale folded)
  scales     (N, K/Ns) fp32, optional    blockwise normalization plane
with N = n_bands * rows_per_band, K = n_cg * group_cols.

Shape handling (serving reality, not benchmark reality):
  * M is padded up to a sublane-aligned tile (decode batches are 1..8 rows;
    the old ``assert M % tile_m == 0`` rejected them) and the output is
    sliced back.
  * tile_n / tile_k are snapped DOWN to the largest band- / group-aligned
    divisors of N / K, so ragged layer shapes never trip an assert. Row
    bands always divide N and column groups always divide K, so a legal
    tiling always exists; k-tiles additionally snap to the uint32 word
    boundary of the packed rows.
  * Blockwise normalization scales enter as a (N, K/Ns) fp32 plane
    (pre-expanded once at engine load by core/vq_linear.prepare_fused) and
    multiply the decoded tile in VMEM — scale_block != 0 recipes no longer
    fall off the fused path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, c_ref, *rest, d, k_c, code_bits, container_bits,
            rows_per_band, scale_block, n_k_tiles):
    if scale_block:
        s_ref, o_ref = rest
    else:
        (o_ref,) = rest
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]            # (tm, tk)
    words = w_ref[...]        # (tn, wk) uint32
    C = c_ref[...]            # (gk, bands_t, k_c, d) fp32

    tn, wk = words.shape
    tm, tk = x.shape
    lanes = 32 // container_bits
    spans = tk // d           # codes per row in this k-tile
    bands_t = tn // rows_per_band

    # unpack: (tn, wk) -> (tn, wk, lanes) -> (tn, spans)
    shifts = (jnp.arange(lanes, dtype=jnp.uint32) * container_bits)
    mask = jnp.uint32(2**container_bits - 1)
    codes = ((words[:, :, None] >> shifts[None, None, :]) & mask)
    codes = codes.reshape(tn, spans).astype(jnp.int32)

    # decode via one-hot matmul per row-band (MXU-friendly; no gathers)
    gk = C.shape[0]           # column-groups covered by this k-tile
    spans_pg = spans // gk
    codes_b = codes.reshape(bands_t, rows_per_band, gk, spans_pg)
    onehot = (codes_b[..., None] ==
              jnp.arange(k_c, dtype=jnp.int32)).astype(jnp.float32)
    # (bands_t, rg, gk, spans_pg, k_c) x (gk, bands_t, k_c, d)
    w_dec = jax.lax.dot_general(
        onehot.transpose(2, 0, 1, 3, 4).reshape(gk, bands_t, -1, k_c),
        C,
        dimension_numbers=(((3,), (2,)), ((0, 1), (0, 1))),
    )  # (gk, bands_t, rg*spans_pg, d)
    w_tile = (
        w_dec.reshape(gk, bands_t, rows_per_band, spans_pg, d)
        .transpose(1, 2, 0, 3, 4)
        .reshape(tn, tk)
    )
    if scale_block:
        s = s_ref[...]        # (tn, tk // Ns)
        w_tile = (w_tile.reshape(tn, tk // scale_block, scale_block)
                  * s[:, :, None]).reshape(tn, tk)

    o_ref[...] += jax.lax.dot_general(
        x.astype(jnp.float32), w_tile,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


def _round_up(x: int, m: int) -> int:
    return (x + m - 1) // m * m


def _snap_tile_n(N: int, rows_per_band: int, tile_n: int) -> int:
    """Largest band-aligned divisor of N that fits in tile_n (>= one band)."""
    bands = N // rows_per_band
    for bt in range(min(bands, max(1, tile_n // rows_per_band)), 0, -1):
        if bands % bt == 0:
            return bt * rows_per_band
    return rows_per_band


def _snap_tile_k(K: int, group_cols: int, d: int, lanes: int,
                 tile_k: int) -> int:
    """Largest group-aligned divisor of K fitting tile_k whose per-row code
    count lands on a packed-word boundary; falls back to growing the tile
    (full K always aligns — rows are packed whole)."""
    n_cg = K // group_cols
    cap = min(n_cg, max(1, tile_k // group_cols))
    for gk in range(cap, 0, -1):
        if n_cg % gk == 0 and (gk * group_cols // d) % lanes == 0:
            return gk * group_cols
    for gk in range(cap + 1, n_cg + 1):
        if n_cg % gk == 0 and (gk * group_cols // d) % lanes == 0:
            return gk * group_cols
    raise ValueError(
        f"no word-aligned k-tiling for K={K} cg={group_cols} d={d} "
        f"lanes={lanes}")


@functools.partial(
    jax.jit,
    static_argnames=("d", "k_c", "code_bits", "container_bits",
                     "rows_per_band", "group_cols", "scale_block", "tile_m",
                     "tile_n", "tile_k", "interpret"),
)
def vq_dequant_matmul(
    x: jax.Array,
    words: jax.Array,
    codebooks: jax.Array,
    scales: jax.Array | None = None,
    *,
    d: int,
    k_c: int,
    code_bits: int,
    container_bits: int,
    rows_per_band: int,
    group_cols: int,
    scale_block: int = 0,
    tile_m: int = 128,
    tile_n: int = 128,
    tile_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ dequant(words, codebooks).T ; returns (M, N) fp32.

    ``scales`` (required iff scale_block != 0) is the pre-expanded blockwise
    normalization plane (N, K // scale_block)."""
    M, K = x.shape
    N = words.shape[0]
    assert (scales is not None) == bool(scale_block)
    lanes = 32 // container_bits

    tile_n = _snap_tile_n(N, rows_per_band, tile_n)
    tile_k = _snap_tile_k(K, group_cols, d, lanes, tile_k)
    if scale_block:
        assert tile_k % scale_block == 0, (tile_k, scale_block)
    # decode-shaped M: pad rows to a sublane-aligned tile, slice after
    tile_m = min(tile_m, _round_up(M, 8))
    Mp = _round_up(M, tile_m)
    if Mp != M:
        x = jnp.pad(x, ((0, Mp - M), (0, 0)))

    wk = tile_k // d // lanes  # words per row per k-tile
    gk = tile_k // group_cols
    bands_t = tile_n // rows_per_band
    grid = (Mp // tile_m, N // tile_n, K // tile_k)

    in_specs = [
        pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
        pl.BlockSpec((tile_n, wk), lambda i, j, kk: (j, kk)),
        pl.BlockSpec((gk, bands_t, k_c, d), lambda i, j, kk: (kk, j, 0, 0)),
    ]
    operands = [x, words, codebooks]
    if scale_block:
        in_specs.append(
            pl.BlockSpec((tile_n, tile_k // scale_block),
                         lambda i, j, kk: (j, kk)))
        operands.append(scales)

    y = pl.pallas_call(
        functools.partial(
            _kernel, d=d, k_c=k_c, code_bits=code_bits,
            container_bits=container_bits, rows_per_band=rows_per_band,
            scale_block=scale_block, n_k_tiles=grid[2]),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, N), jnp.float32),
        interpret=interpret,
    )(*operands)
    return y[:M] if Mp != M else y
