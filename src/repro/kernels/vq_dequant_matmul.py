"""Fused VQ-decode + matmul Pallas TPU kernel — the serving hot-spot.

TPU adaptation of the paper's ARM-TBL decode kernel (DESIGN.md §3): the
bit-packed index matrix is the HBM payload (2-4.5 bits/weight); codebooks
live in VMEM; decode happens on-chip and the reconstructed tile feeds the
MXU directly, so the dense weight matrix never round-trips through HBM.

Centroid lookup uses the one-hot-matmul trick (``one_hot(codes) @ codebook``)
instead of a gather: TPU gathers serialize on the scalar unit, whereas the
one-hot contraction runs on the MXU at full tile throughput — this is the
core 'rethink the GPU/CPU algorithm for the TPU memory hierarchy' decision.

Layout contract (matches core/vq_linear.VQLinear):
  x          (M, K)                      activations
  words      (N, K/d * bits / 32)        packed uint32 codes, row-major
  codebooks  (n_cg, n_bands, k_c, d)     fp32 (int8 codebook * scale folded)
with N = n_bands * rows_per_band, K = n_cg * group_cols.
Tile sizes must align: tile_k % group_cols == 0 (or group_cols % tile_k == 0
with tile_k % d == 0), tile_n % rows_per_band == 0.
Blockwise normalization scales are folded by ops.py (scale_block=0 path) or
applied via the optional scales ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, c_ref, o_ref, *, d, k_c, code_bits, container_bits,
            rows_per_band, n_k_tiles):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]            # (tm, tk)
    words = w_ref[...]        # (tn, wk) uint32
    C = c_ref[...]            # (gk, bands_t, k_c, d) fp32

    tn, wk = words.shape
    tm, tk = x.shape
    lanes = 32 // container_bits
    spans = tk // d           # codes per row in this k-tile
    bands_t = tn // rows_per_band

    # unpack: (tn, wk) -> (tn, wk, lanes) -> (tn, spans)
    shifts = (jnp.arange(lanes, dtype=jnp.uint32) * container_bits)
    mask = jnp.uint32(2**container_bits - 1)
    codes = ((words[:, :, None] >> shifts[None, None, :]) & mask)
    codes = codes.reshape(tn, spans).astype(jnp.int32)

    # decode via one-hot matmul per row-band (MXU-friendly; no gathers)
    gk = C.shape[0]           # column-groups covered by this k-tile
    spans_pg = spans // gk
    codes_b = codes.reshape(bands_t, rows_per_band, gk, spans_pg)
    onehot = (codes_b[..., None] ==
              jnp.arange(k_c, dtype=jnp.int32)).astype(jnp.float32)
    # (bands_t, rg, gk, spans_pg, k_c) x (gk, bands_t, k_c, d)
    w_dec = jax.lax.dot_general(
        onehot.transpose(2, 0, 1, 3, 4).reshape(gk, bands_t, -1, k_c),
        C,
        dimension_numbers=(((3,), (2,)), ((0, 1), (0, 1))),
    )  # (gk, bands_t, rg*spans_pg, d)
    w_tile = (
        w_dec.reshape(gk, bands_t, rows_per_band, spans_pg, d)
        .transpose(1, 2, 0, 3, 4)
        .reshape(tn, tk)
    )

    o_ref[...] += jax.lax.dot_general(
        x.astype(jnp.float32), w_tile,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(
    jax.jit,
    static_argnames=("d", "k_c", "code_bits", "container_bits",
                     "rows_per_band", "group_cols", "tile_m", "tile_n",
                     "tile_k", "interpret"),
)
def vq_dequant_matmul(
    x: jax.Array,
    words: jax.Array,
    codebooks: jax.Array,
    *,
    d: int,
    k_c: int,
    code_bits: int,
    container_bits: int,
    rows_per_band: int,
    group_cols: int,
    tile_m: int = 128,
    tile_n: int = 128,
    tile_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """y = x @ dequant(words, codebooks).T ; returns (M, N) fp32."""
    M, K = x.shape
    N = words.shape[0]
    n_cg, n_bands = codebooks.shape[0], codebooks.shape[1]
    tile_m = min(tile_m, M)
    tile_n = min(tile_n, N)
    tile_k = min(tile_k, K)
    assert K % tile_k == 0 and N % tile_n == 0 and M % tile_m == 0
    assert tile_k % group_cols == 0, (tile_k, group_cols)
    assert tile_n % rows_per_band == 0
    lanes = 32 // container_bits
    wk = tile_k // d // lanes  # words per row per k-tile
    gk = tile_k // group_cols
    bands_t = tile_n // rows_per_band
    grid = (M // tile_m, N // tile_n, K // tile_k)

    return pl.pallas_call(
        functools.partial(
            _kernel, d=d, k_c=k_c, code_bits=code_bits,
            container_bits=container_bits, rows_per_band=rows_per_band,
            n_k_tiles=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tile_n, wk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((gk, bands_t, k_c, d), lambda i, j, kk: (kk, j, 0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), jnp.float32),
        interpret=interpret,
    )(x, words, codebooks)
