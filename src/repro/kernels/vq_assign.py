"""Hessian-weighted nearest-centroid assignment Pallas kernel (Eq. 4).

The quantization-time hot spot: every d-span of every row computes a
weighted distance to all k centroids. The expanded form

    dist = sum(Hw x^2) - 2 (Hw x) @ C^T + Hw @ (C^2)^T

turns the (n, k, d) broadcast into two (n, d)x(d, k) MXU matmuls; the kernel
tiles n and keeps the codebook resident in VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, hw_ref, c_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)    # (tn, d)
    hw = hw_ref[...].astype(jnp.float32)  # (tn, d)
    C = c_ref[...].astype(jnp.float32)    # (k, d)
    hx2 = jnp.sum(hw * x * x, axis=-1, keepdims=True)
    cross = jax.lax.dot_general(
        hw * x, C, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    c2 = jax.lax.dot_general(
        hw, C * C, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    dist = hx2 - 2.0 * cross + c2         # (tn, k)
    o_ref[...] = jnp.argmin(dist, axis=-1).astype(jnp.int32)


@functools.partial(jax.jit,
                   static_argnames=("tile_n", "interpret"))
def vq_assign(x: jax.Array, hw: jax.Array, codebook: jax.Array,
              *, tile_n: int = 1024, interpret: bool = False) -> jax.Array:
    """x, hw: (n, d); codebook: (k, d) -> (n,) int32 assignments."""
    n, d = x.shape
    k = codebook.shape[0]
    tile_n = min(tile_n, n)
    assert n % tile_n == 0, (n, tile_n)
    grid = (n // tile_n,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tile_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=interpret,
    )(x, hw, codebook)
