"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing


def vq_dequant_matmul_ref(x, words, codebooks, *, d, code_bits,
                          rows_per_band, group_cols):
    """Oracle: unpack -> gather -> dense matmul."""
    M, K = x.shape
    N = words.shape[0]
    n_cg, n_bands, k_c, _ = codebooks.shape
    nspans = K // d
    codes = jax.vmap(lambda row: packing.unpack(row, code_bits, nspans))(words)
    spans_pg = group_cols // d
    idx4 = codes.reshape(n_bands, rows_per_band, n_cg, spans_pg)
    g_ix = jnp.arange(n_cg)[None, None, :, None]
    b_ix = jnp.arange(n_bands)[:, None, None, None]
    W = codebooks[g_ix, b_ix, idx4].reshape(n_bands, rows_per_band,
                                            n_cg, group_cols).reshape(N, K)
    return x.astype(jnp.float32) @ W.T


def vq_assign_ref(x, hw, codebook):
    """Oracle: explicit (n, k, d) broadcast distance + argmin."""
    diff = x[:, None, :] - codebook[None, :, :]
    dist = jnp.sum(hw[:, None, :] * diff * diff, axis=-1)
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)
