"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.kernels import kv_quant


def vq_dequant_matmul_ref(x, words, codebooks, scales=None, *, d, code_bits,
                          rows_per_band, group_cols, scale_block=0):
    """Oracle: unpack -> gather -> (blockwise scale) -> dense matmul.

    Same scale semantics as the Pallas kernel: ``scales`` is the
    pre-expanded (N, K // scale_block) normalization plane. Leading stack
    dims (MoE experts, scanned layers) vmap away."""
    if words.ndim > 2:  # stacked leaves: (E/L/..., N, W) — map over the stack
        out = []
        for i in range(words.shape[0]):
            out.append(vq_dequant_matmul_ref(
                x[i], words[i],
                codebooks[i] if codebooks.ndim > 4 else codebooks,
                None if scales is None else scales[i],
                d=d, code_bits=code_bits, rows_per_band=rows_per_band,
                group_cols=group_cols, scale_block=scale_block))
        return jnp.stack(out)
    M, K = x.shape
    N = words.shape[0]
    n_cg, n_bands, k_c, _ = codebooks.shape
    nspans = K // d
    codes = jax.vmap(lambda row: packing.unpack(row, code_bits, nspans))(words)
    spans_pg = group_cols // d
    idx4 = codes.reshape(n_bands, rows_per_band, n_cg, spans_pg)
    g_ix = jnp.arange(n_cg)[None, None, :, None]
    b_ix = jnp.arange(n_bands)[:, None, None, None]
    W = codebooks[g_ix, b_ix, idx4].reshape(n_bands, rows_per_band,
                                            n_cg, group_cols).reshape(N, K)
    if scale_block:
        W = (W.reshape(N, K // scale_block, scale_block)
             * scales[:, :, None]).reshape(N, K)
    return x.astype(jnp.float32) @ W.T


def vq_assign_ref(x, hw, codebook):
    """Oracle: explicit (n, k, d) broadcast distance + argmin."""
    diff = x[:, None, :] - codebook[None, :, :]
    dist = jnp.sum(hw[:, None, :] * diff * diff, axis=-1)
    return jnp.argmin(dist, axis=-1).astype(jnp.int32)


def paged_attention_ref(q, k_pool, v_pool, page_table, pos,
                        k_scale=None, v_scale=None,
                        k_codebook=None, v_codebook=None):
    """Oracle for the fused paged decode kernel: gather the logical
    (B, n_pages*page_size) K/V view through the page table, mask logical
    positions kpos > pos per slot, dense softmax attention. This is exactly
    the read path models/attention._paged_apply uses at decode — the kernel
    must be bit-for-bit the same math, minus the materialized view.

    q (B, H, hd); pools (num_blocks, page_size, KV, hd);
    page_table (B, n_pages) int32; pos (B,) int32 -> (B, H, hd).

    Quantized pools: pass ``k_scale``/``v_scale`` (num_blocks, page_size,
    KV) f32 — pools then hold int8 codes (int4 packed two-per-byte when
    their last axis is hd//2) and the gathered pages are dequantized
    per-page with kernels/kv_quant.dequant_rows, the identical expression
    the Pallas kernel evaluates in VMEM.

    VQ pools: additionally pass ``k_codebook``/``v_codebook`` (KV, 16, 2)
    f32 — pools then hold packed 4-bit codebook indices (last axis hd//4)
    and decode through kv_quant.vq_dequant_rows, again the literal
    expression the Pallas kernel evaluates in VMEM.
    """
    B, H, hd = q.shape
    page_size, KV = k_pool.shape[1], k_pool.shape[2]
    n_pages = page_table.shape[-1]
    G = H // KV
    Sk = n_pages * page_size
    kg = k_pool[page_table].reshape(B, Sk, KV, -1)
    vg = v_pool[page_table].reshape(B, Sk, KV, -1)
    if k_codebook is not None:
        kg = kv_quant.vq_dequant_rows(
            kg, k_scale[page_table].reshape(B, Sk, KV), k_codebook)
        vg = kv_quant.vq_dequant_rows(
            vg, v_scale[page_table].reshape(B, Sk, KV), v_codebook)
    elif k_scale is not None:
        bits = kv_quant.infer_bits(k_pool.shape[-1], hd)
        kg = kv_quant.dequant_rows(
            kg, k_scale[page_table].reshape(B, Sk, KV), bits)
        vg = kv_quant.dequant_rows(
            vg, v_scale[page_table].reshape(B, Sk, KV), bits)
    qh = q.reshape(B, KV, G, hd)
    s = jnp.einsum("bkgh,bskh->bkgs", qh.astype(jnp.float32),
                   kg.astype(jnp.float32)) / jnp.sqrt(hd).astype(jnp.float32)
    valid = jnp.arange(Sk)[None, None, None, :] <= pos[:, None, None, None]
    s = jnp.where(valid, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgs,bskh->bkgh", p, vg.astype(jnp.float32))
    return o.reshape(B, H, hd).astype(q.dtype)
