"""Jit'd public wrappers around the Pallas kernels.

``use_pallas`` selects the execution path:
  * True  — pl.pallas_call (TPU target; interpret=True on CPU for tests)
  * False — the pure-XLA fallback (used by the multi-pod dry-run: Pallas TPU
            lowering is unavailable on the host-CPU dry-run platform).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.vq_linear import VQLinear
from repro.kernels import ref
from repro.kernels.vq_assign import vq_assign
from repro.kernels.vq_dequant_matmul import vq_dequant_matmul


def vql_matmul(x: jax.Array, vql, *, use_pallas: bool = True,
               interpret: bool = True, tile_m: int = 128, tile_n: int = 128,
               tile_k: int = 256) -> jax.Array:
    """y = x @ W^T for a VQLinear, fused on TPU.

    Blockwise-normalized layouts (scale_block != 0) are folded here: the
    scale plane is pre-expanded by core/vq_linear.prepare_fused and applied
    inside the kernel tile — no layout is rejected anymore. Accepts an
    already-prepped FusedVQLinear directly (serving path: fold once at
    engine load instead of per call)."""
    from repro.core import vq_linear as vql_mod

    if isinstance(vql, VQLinear):
        vql = vql_mod.prepare_fused(vql)
        assert isinstance(vql, vql_mod.FusedVQLinear), \
            "rows not packed on word boundaries — no fused layout"
    if use_pallas:
        return vq_dequant_matmul(
            x, vql.words, vql.codebooks_f, vql.scales,
            d=vql.d, k_c=vql.k, code_bits=vql.code_bits,
            container_bits=packing.container_bits(vql.code_bits),
            rows_per_band=vql.rows_per_band, group_cols=vql.group_cols,
            scale_block=vql.scale_block, tile_m=tile_m,
            tile_n=min(tile_n, vql.r), tile_k=min(tile_k, vql.c),
            interpret=interpret)
    return ref.vq_dequant_matmul_ref(
        x, vql.words, vql.codebooks_f, vql.scales, d=vql.d,
        code_bits=vql.code_bits, rows_per_band=vql.rows_per_band,
        group_cols=vql.group_cols, scale_block=vql.scale_block)


def paged_attention(q, k_pool, v_pool, page_table, pos, *,
                    k_scale=None, v_scale=None,
                    k_codebook=None, v_codebook=None,
                    use_pallas: bool = True, interpret: bool = True):
    """Fused paged-attention decode: one query token per slot attends over
    its page-table-mapped KV blocks (kpos <= pos masking) without
    materializing the logical per-slot view. q (B, H, hd) -> (B, H, hd).

    ``k_scale``/``v_scale`` mark a quantized pool (int8/int4 code pages +
    per-row per-kv-head f32 scales): the Pallas path DMAs code pages and
    their scale tiles and dequantizes in VMEM; the XLA path dequantizes
    the gathered pages in the oracle. ``k_codebook``/``v_codebook`` mark
    a VQ pool (packed 4-bit index pages + frozen per-kv-head codebooks):
    the Pallas path keeps the codebook tile resident in VMEM and does
    the table lookup there. All paths share kernels/kv_quant.py."""
    if use_pallas:
        from repro.kernels.paged_attention import paged_attention_tpu
        return paged_attention_tpu(q, k_pool, v_pool, page_table, pos,
                                   k_scale=k_scale, v_scale=v_scale,
                                   k_codebook=k_codebook,
                                   v_codebook=v_codebook,
                                   interpret=interpret)
    return ref.paged_attention_ref(q, k_pool, v_pool, page_table, pos,
                                   k_scale=k_scale, v_scale=v_scale,
                                   k_codebook=k_codebook,
                                   v_codebook=v_codebook)


def assign(x, hw, codebook, *, use_pallas: bool = True,
           interpret: bool = True, tile_n: int = 1024):
    if use_pallas:
        n = x.shape[0]
        t = min(tile_n, n)
        while n % t != 0:
            t -= 1
        return vq_assign(x, hw, codebook, tile_n=t, interpret=interpret)
    return ref.vq_assign_ref(x, hw, codebook)
