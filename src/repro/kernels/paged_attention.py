"""Fused paged-attention decode Pallas TPU kernel.

One query token per slot (S == 1 decode) attends over that slot's paged
KV blocks *in place*: the per-slot page table rides in as a scalar-prefetch
operand, so the k/v BlockSpec index maps resolve ``page_table[slot, page]``
to a physical block row of the shared pool and the DMA engine streams
exactly the pages the slot owns — the (B, n_pages*page_size, KV, hd)
logical view the XLA gather path materializes per layer never exists.

Grid (B, KV, n_pages): one program per (slot, kv-head, logical page), with
the page dimension innermost so the online-softmax running max/sum/acc live
in VMEM scratch across pages (same structure as kernels/flash_attention.py).
All G = H // KV query heads of a kv head share its pages in one program, so
GQA needs no materialized head expansion.

Quantized pools (KVQuantSpec bits 8/4, kernels/kv_quant.py): the pools hold
int8 code pages (int4 packed two codes per byte along the head dim) plus
per-row per-kv-head f32 scales. The scale tiles are extra inputs whose
BlockSpec index maps read the SAME scalar-prefetched page table as k/v —
``(table[b, pg], 0, kv)`` — so a program DMAs its page's codes and the
matching (page_size,) scale lane together and dequantizes in VMEM
(``dequant_rows``: sign-extend/unpack, multiply by scale, f32). Quantized
pages are decoded only inside the kernel; no fp16 logical view of the pool
ever materializes anywhere in the serving path.

VQ pools (KVQuantSpec mode "vq", "vq2"): pages hold packed 4-bit codebook
indices over d=2 vectors along the head dim. Each program additionally
receives its kv head's frozen (16, 2) codebook tile (page-invariant index
map, so it stays VMEM-resident across the page grid dim) and decodes via
``vq_dequant_rows`` — a one-hot matmul table lookup, bitwise-equal to a
gather in f32 and shared verbatim with the oracle and the XLA gather path.

Masking is the serving invariant ``kpos <= pos[slot]`` over *logical*
positions: stale rows in recycled blocks, the tail of the slot's last page,
the reserved scratch block 0 (where inactive slots' page-table entries
point), and table rows past the slot's depth are all strictly above
``pos`` and never contribute. Stale *scales* ride the same masked rows:
they decode stale codes to finite garbage whose scores die at the mask,
exactly like stale fp16 keys. An idle slot (pos == 0, table all-scratch)
attends exactly one scratch row — defined output, discarded by the engine.

``kernels/ref.py:paged_attention_ref`` is the pure-XLA oracle (same
``dequant_rows`` expression on the gathered view);
``tests/kernels/test_paged_attention.py`` is the differential harness.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import kv_quant

NEG_INF = -1e30


def _kernel(table_ref, pos_ref, q_ref, k_ref, v_ref, *rest,
            scale, n_pages, page_size, kv_bits):
    vq = kv_bits == kv_quant.VQ_BITS
    if vq:
        ks_ref, vs_ref, kcb_ref, vcb_ref, o_ref, m_scr, l_scr, acc_scr = rest
    elif kv_bits != kv_quant.PASSTHROUGH_BITS:
        ks_ref, vs_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        o_ref, m_scr, l_scr, acc_scr = rest
    b = pl.program_id(0)
    pg = pl.program_id(2)

    @pl.when(pg == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)      # (G, hd)
    if vq:
        # in-VMEM table lookup: the page's packed 4-bit indices and its
        # (page_size,) scale lane arrive by DMA through the table-driven
        # index maps; the kv head's (16, 2) codebook tile stays VMEM-
        # resident across pages. Decode is the shared vq_dequant_rows
        # expression (one-hot matmul == gather in f32), so kernel ==
        # oracle == gather path bit for bit — no fp view of the pool
        # ever materializes
        k = kv_quant.vq_dequant_rows(k_ref[0, :, 0], ks_ref[0, :, 0],
                                     kcb_ref[0])
        v = kv_quant.vq_dequant_rows(v_ref[0, :, 0], vs_ref[0, :, 0],
                                     vcb_ref[0])
    elif kv_bits != kv_quant.PASSTHROUGH_BITS:
        # in-VMEM dequant: the page's int8 codes and its (page_size,)
        # scale lane arrived by DMA through the same table-driven index
        # maps; decode is the shared kv_quant expression, so kernel ==
        # oracle == gather path bit for bit on the decoded values
        k = kv_quant.dequant_rows(k_ref[0, :, 0], ks_ref[0, :, 0], kv_bits)
        v = kv_quant.dequant_rows(v_ref[0, :, 0], vs_ref[0, :, 0], kv_bits)
    else:
        k = k_ref[0, :, 0].astype(jnp.float32)   # (page_size, hd)
        v = v_ref[0, :, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # logical position of every row of this page; the single serving mask:
    # scratch block 0, recycled-block staleness (codes AND scales), and
    # the last-page tail are all `kpos > pos` and die here
    kpos = pg * page_size + jax.lax.broadcasted_iota(
        jnp.int32, s.shape, 1)
    s = jnp.where(kpos <= pos_ref[b], s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(pg == n_pages - 1)
    def _done():
        o_ref[0, 0] = (acc_scr[...] /
                       jnp.maximum(l_scr[...], 1e-30)[:, None]
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention_tpu(q, k_pool, v_pool, page_table, pos, *,
                        k_scale=None, v_scale=None,
                        k_codebook=None, v_codebook=None,
                        interpret: bool = False):
    """Fused paged decode attention.

    q          : (B, H, hd)  — the decode token's query per slot
    k_pool/v_pool : (num_blocks, page_size, KV, hd) shared block pools;
                 with ``k_scale``/``v_scale`` given they are int8 code
                 pools instead (last axis hd for int8, hd//2 for packed
                 int4) and are dequantized in VMEM
    page_table : (B, n_pages) int32 physical block per logical page
                 (0 = reserved scratch block)
    pos        : (B,) int32 per-slot position of the decode token; the
                 kernel attends logical positions kpos <= pos[b]
    k_scale/v_scale : optional (num_blocks, page_size, KV) f32 per-row
                 per-kv-head scales of a quantized pool
    k_codebook/v_codebook : optional (KV, 16, 2) f32 frozen codebooks of
                 a VQ pool; pools then hold packed 4-bit index pages
                 (last axis hd//4) looked up in VMEM
    returns    : (B, H, hd) in q.dtype
    """
    B, H, hd = q.shape
    num_blocks, page_size, KV, _ = k_pool.shape
    n_pages = page_table.shape[-1]
    G = H // KV
    scale = 1.0 / (hd ** 0.5)
    quantized = k_scale is not None
    vq = k_codebook is not None
    if vq:
        kv_bits = kv_quant.VQ_BITS
    elif quantized:
        kv_bits = kv_quant.infer_bits(k_pool.shape[-1], hd)
    else:
        kv_bits = kv_quant.PASSTHROUGH_BITS
    cols = k_pool.shape[-1]

    qh = q.reshape(B, KV, G, hd)

    def q_index(b, kv, pg, table, pos):
        return b, kv, 0, 0

    def kv_index(b, kv, pg, table, pos):
        # the in-kernel gather: logical page pg of slot b lives in physical
        # block table[b, pg] — resolved here, in the index map, so only the
        # slot's own pages are ever DMA'd
        return table[b, pg], 0, kv, 0

    def scale_index(b, kv, pg, table, pos):
        # scale tiles resolve through the SAME scalar-prefetched table, so
        # a quantized page and its scale lane always travel together
        return table[b, pg], 0, kv

    in_specs = [
        pl.BlockSpec((1, 1, G, hd), q_index),
        pl.BlockSpec((1, page_size, 1, cols), kv_index),
        pl.BlockSpec((1, page_size, 1, cols), kv_index),
    ]
    operands = [qh, k_pool, v_pool]
    if quantized:
        in_specs += [pl.BlockSpec((1, page_size, 1), scale_index),
                     pl.BlockSpec((1, page_size, 1), scale_index)]
        operands += [k_scale.astype(jnp.float32),
                     v_scale.astype(jnp.float32)]
    if vq:
        def cb_index(b, kv, pg, table, pos):
            # one (16, 2) codebook tile per kv head, page-invariant: it
            # stays resident in VMEM while the page grid dim streams
            return kv, 0, 0
        in_specs += [
            pl.BlockSpec((1, kv_quant.VQ_K, kv_quant.VQ_D), cb_index),
            pl.BlockSpec((1, kv_quant.VQ_K, kv_quant.VQ_D), cb_index)]
        operands += [k_codebook.astype(jnp.float32),
                     v_codebook.astype(jnp.float32)]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, KV, n_pages),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, G, hd), q_index),
        scratch_shapes=[
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G,), jnp.float32),
            pltpu.VMEM((G, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_pages=n_pages,
                          page_size=page_size, kv_bits=kv_bits),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, KV, G, hd), q.dtype),
        interpret=interpret,
    )(page_table.astype(jnp.int32), pos.astype(jnp.int32), *operands)
    return out.reshape(B, H, hd)
