"""Flash attention Pallas TPU kernel (online-softmax, GQA-aware).

Grid (B*H, n_q_blocks, n_kv_blocks); running max/sum/accumulator live in
VMEM scratch across the kv dimension, the output tile is written once on
the last kv step. GQA is handled in the k/v BlockSpec index maps (query
head h reads kv head h // group) — no materialized head expansion.

Used as the TPU fast path for models/attention.flash_attention (the
pure-JAX two-level scan remains the portable/XLA path and the oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            causal, scale, nk, block_q, block_k, q_offset):
    qi = pl.program_id(1)
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)   # (bq, hd)
    k = k_ref[0].astype(jnp.float32)   # (bk, hd)
    v = v_ref[0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    if causal:
        # absolute row position = q_offset + row index (a decode/chunked
        # caller's queries start q_offset tokens into the kv range)
        rows = q_offset + qi * block_q + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 0)
        cols = kk * block_k + jax.lax.broadcasted_iota(
            jnp.int32, (block_q, block_k), 1)
        s = jnp.where(cols <= rows, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=-1)
    acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(kk == nk - 1)
    def _done():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret",
                     "q_offset"))
def flash_attention_tpu(q, k, v, *, causal: bool = True, block_q: int = 128,
                        block_k: int = 128, interpret: bool = False,
                        q_offset: int = 0):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) -> (B, Sq, H, hd).

    ``q_offset`` is the absolute position of q's first row within the kv
    range (0 for self-attention over the same span; nonzero when the
    queries continue a prefix — including the empty-cache-prefix chunked
    case where Sk == Sq and the mask uses absolute positions)."""
    B, Sq, H, hd = q.shape
    Sk, KV = k.shape[1], k.shape[2]
    G = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0
    nq, nk = Sq // block_q, Sk // block_k
    scale = 1.0 / (hd ** 0.5)

    qh = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, hd)
    kh = k.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)
    vh = v.transpose(0, 2, 1, 3).reshape(B * KV, Sk, hd)

    def kv_index(b, qi, kk):
        # query head -> its GQA kv head: b = batch*H + h; kv row =
        # batch*KV + h // G
        return (b // H) * KV + (b % H) // G, kk, 0

    out = pl.pallas_call(
        functools.partial(_kernel, causal=causal, scale=scale, nk=nk,
                          block_q=block_q, block_k=block_k,
                          q_offset=q_offset),
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, hd), lambda b, qi, kk: (b, qi, 0)),
            pl.BlockSpec((1, block_k, hd), kv_index),
            pl.BlockSpec((1, block_k, hd), kv_index),
        ],
        out_specs=pl.BlockSpec((1, block_q, hd), lambda b, qi, kk: (b, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3)
