"""Pluggable inner solvers for the GPTVQ column sweep.

Three solvers share the sweep skeleton in gptvq.py (recipe field
``solver``, launcher flag ``--solver``):

``gptq`` (default)
    The paper's assignment rule: Hessian-weighted nearest centroid under
    the *diagonal* conditioned metric ``1/U_qq^2`` per column
    (hessian.cholesky_diag_weights). Bitwise-identical to the historical
    path.

``babai``
    Nearest-plane reading of GPTQ (arXiv 2507.18553): GPTQ's sequential
    rounding is exactly Babai's nearest-plane algorithm on the lattice
    whose Gram matrix is the conditioned Hessian. For a d-span P the
    exact conditional metric is the full d x d matrix

        M = (U_PP^T U_PP)^{-1} = U_PP^{-1} U_PP^{-T}

    (the inverse of the span's conditioned inverse-Hessian block), not
    just its diagonal. Assignment minimizes ``e M e^T`` per row, which
    accounts for intra-span correlation the diagonal rule ignores; at
    d=1 it reduces to ``1/U_qq^2`` and matches ``gptq`` exactly.

``cd``
    CDQuant-style greedy coordinate descent (arXiv 2406.17542) run as a
    refinement pass after the ``gptq`` sweep: with E = Q - W and
    G = E H, re-deciding span P of one row from centroid q to candidate
    q' changes the objective tr(E H E^T) by

        Δf = 2 δ G[row, P]^T + δ H_PP δ^T,   δ = q' - q

    Each pass visits every span once, switches to the best candidate
    only when Δf < 0 (so the objective is monotonically non-increasing
    and never worse than the sweep it refines), and rank-1-updates G.
    Cost O(r c^2) per pass — same order as the sweep itself.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.bpv import VQConfig

VALID_SOLVERS = ("gptq", "babai", "cd")


def span_metric(U_PP: jax.Array) -> jax.Array:
    """Exact conditional span metric ``M = (U_PP^T U_PP)^{-1}``.

    ``U_PP`` is the upper-triangular d x d diagonal block of U (where
    ``H^{-1} = U^T U`` conditioned on all previously-quantized columns),
    so ``U_PP^T U_PP`` is the span's conditioned inverse-Hessian block
    and M is the metric under which joint-span rounding error is
    measured when the remaining columns are optimally compensated.
    """
    d = U_PP.shape[0]
    eye = jnp.eye(d, dtype=U_PP.dtype)
    Uinv = jax.scipy.linalg.solve_triangular(U_PP, eye, lower=False)
    return Uinv @ Uinv.T


def assign_babai(xb: jax.Array, Sb: jax.Array, M: jax.Array,
                 Cg: jax.Array) -> jax.Array:
    """Full-metric nearest-centroid assignment for one d-span.

    xb: (n_bands, rg, d) normalized span values; Sb: (n_bands, rg, d)
    per-row normalization scales over the span (all-ones when blockwise
    normalization is off); M: (d, d) span metric in *weight* space;
    Cg: (n_bands, k, d) band codebooks. The weight-space error of row i
    against centroid m is ``(x - c_m) * S`` elementwise, so the scaled
    metric is ``D_S M D_S`` per row. Returns (n_bands, rg) argmin ids.
    """
    diff = xb[:, :, None, :] - Cg[:, None, :, :]     # (n_bands, rg, k, d)
    y = diff * Sb[:, :, None, :]                     # scale into weight space
    dist = jnp.einsum("brkd,de,brke->brk", y, M, y)
    return jnp.argmin(dist, axis=-1)


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "group_cols", "rows_per_band", "passes"),
)
def cd_refine(
    W: jax.Array,
    Q: jax.Array,
    indices: jax.Array,
    codebooks: jax.Array,
    S_full: jax.Array,
    H: jax.Array,
    *,
    cfg: VQConfig,
    group_cols: int,
    rows_per_band: int,
    passes: int,
):
    """Greedy coordinate-descent refinement of assigned indices.

    Revisits every d-span ``passes`` times; per span all rows are
    re-decided simultaneously (rows are independent in tr(E H E^T)).
    Only strictly-improving switches are taken, so the final objective
    is <= the input's. Codebooks and scales are fixed — only ``indices``
    (and the matching ``Q``) change, keeping packed payloads consistent.

    Returns (Q, indices, n_changed).
    """
    r, c = W.shape
    d, k = cfg.d, cfg.k
    cg, rg = group_cols, rows_per_band
    n_bands = r // rg
    spans_pg = cg // d
    nspans = c // d

    W = W.astype(jnp.float32)
    Q = Q.astype(jnp.float32)
    H = H.astype(jnp.float32)
    E = Q - W
    G = E @ H

    def span_body(j, carry):
        Q, G, idx_all, changed = carry
        col = j * d
        g = j // spans_pg
        Cg = jax.lax.dynamic_index_in_dim(codebooks, g, axis=0,
                                          keepdims=False)  # (n_bands, k, d)
        S_span = jax.lax.dynamic_slice(S_full, (0, col), (r, d))
        Sb = S_span.reshape(n_bands, rg, d)
        # candidate weight-space values and deltas against current Q
        q_cand = Cg[:, None, :, :] * Sb[:, :, None, :]   # (n_bands, rg, k, d)
        Q_span = jax.lax.dynamic_slice(Q, (0, col), (r, d))
        delta = q_cand - Q_span.reshape(n_bands, rg, 1, d)
        G_span = jax.lax.dynamic_slice(G, (0, col), (r, d))
        Gb = G_span.reshape(n_bands, rg, d)
        H_PP = jax.lax.dynamic_slice(H, (col, col), (d, d))
        df = (2.0 * jnp.einsum("brkd,brd->brk", delta, Gb)
              + jnp.einsum("brkd,de,brke->brk", delta, H_PP, delta))
        best = jnp.argmin(df, axis=-1)                       # (n_bands, rg)
        best_df = jnp.take_along_axis(df, best[..., None], axis=-1)[..., 0]
        accept = best_df < 0.0
        step = jnp.take_along_axis(
            delta, best[..., None, None], axis=2
        )[:, :, 0, :]                                        # (n_bands, rg, d)
        step = jnp.where(accept[..., None], step, 0.0).reshape(r, d)
        Q = jax.lax.dynamic_update_slice(Q, Q_span + step, (0, col))
        G = G + step @ jax.lax.dynamic_slice(H, (col, 0), (d, c))
        old = jax.lax.dynamic_slice(idx_all, (0, j), (r, 1))[:, 0]
        new = jnp.where(accept.reshape(r), best.reshape(r), old)
        idx_all = jax.lax.dynamic_update_slice(
            idx_all, new.astype(jnp.int32)[:, None], (0, j)
        )
        changed = changed + jnp.sum(accept)
        return Q, G, idx_all, changed

    def pass_body(_, carry):
        return jax.lax.fori_loop(0, nspans, span_body, carry)

    Q, G, indices, changed = jax.lax.fori_loop(
        0, passes, pass_body, (Q, G, indices, jnp.zeros((), jnp.int32))
    )
    return Q, indices, changed
