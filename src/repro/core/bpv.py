"""Bits-per-value accounting (GPTVQ §3.2 'Total bits per value').

bpv = index_bits/weight + codebook_bits/weight + scale_bits/weight
    = log2(k)/d        + k*d*b_c/l             + b_s/N_s

with k = 2^(d*b) centroids, group size l weights per codebook, codebook
entries stored at b_c bits, and blockwise normalization scales at b_s bits
per N_s weights (0 if normalization is off).

The paper picks l to hit the uniform-baseline overheads (0.125/0.25 bpv).
Those nominal figures assume the tensor is large enough to amortize its
codebooks; ``effective_bpv`` accounts for the group plan a concrete
(r, c) matrix actually gets, which is what the recipe layer
(core/recipe.py — PAPER_SETTINGS are also exposed there as single-rule
recipe presets) and the budget allocator reason about.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

# accounting rate for weights a recipe leaves (or stores) dense: the
# serving reference dtype is bf16 regardless of the fp32 smoke configs
DENSE_BITS = 16.0


@dataclass(frozen=True)
class VQConfig:
    """Static hyper-parameters of one GPTVQ run (per weight tensor)."""

    d: int = 2                      # VQ dimensionality
    bits_per_dim: float = 2.0       # b: index bits per weight dimension
    group_size: int = 2048          # l: weights per codebook
    group_cols: int = 256           # max columns a group spans (paper: 256)
    codebook_bits: int = 8          # b_c (8 = int8 codebooks; 16 = fp16)
    scale_block: int = 0            # N_s (0 = blockwise normalization off)
    scale_bits: int = 4             # b_s
    em_iters: int = 50              # EM iterations for codebook init
    em_seed: str = "mahalanobis"    # or "kmeans++"
    block_size: int = 128           # GPTQ lazy-update block B
    codebook_update_iters: int = 25 # GD steps on ||WX - QX||^2 (0 = off)
    codebook_update_lr: float = 1e-3
    svd_rank_frac: float = 0.0      # >0: SVD codebook compression (1D only)
    percdamp: float = 0.01
    exact_span_solve: bool = True   # exact joint d-column compensation
    cd_passes: int = 2              # coordinate-descent passes (solver="cd")

    @property
    def k(self) -> int:
        k = 2 ** (self.d * self.bits_per_dim)
        assert abs(k - round(k)) < 1e-9, "log2(k) must be integer"
        return int(round(k))

    @property
    def index_bits_per_value(self) -> float:
        return math.log2(self.k) / self.d

    @property
    def codebook_bits_per_value(self) -> float:
        eff_k = self.k if self.svd_rank_frac <= 0 else self.k * self.svd_rank_frac
        return eff_k * self.d * self.codebook_bits / self.group_size

    @property
    def scale_bits_per_value(self) -> float:
        if self.scale_block <= 0:
            return 0.0
        return self.scale_bits / self.scale_block

    @property
    def bits_per_value(self) -> float:
        return (
            self.index_bits_per_value
            + self.codebook_bits_per_value
            + self.scale_bits_per_value
        )


def group_size_for_overhead(
    d: int, bits_per_dim: float, target_overhead: float, codebook_bits: int = 8,
    scale_block: int = 0, scale_bits: int = 4,
) -> int:
    """Smallest power-of-two group size whose codebook+scale overhead is
    <= target (paper §4.1: e.g. 2D/2b/int8 @ 0.125 bpv -> l = 2048)."""
    k = int(round(2 ** (d * bits_per_dim)))
    scale_oh = scale_bits / scale_block if scale_block > 0 else 0.0
    budget = target_overhead - scale_oh
    assert budget > 0, "scale overhead alone exceeds the target"
    l = k * d * codebook_bits / budget
    return 2 ** math.ceil(math.log2(l))


def effective_bpv(cfg: VQConfig, r: int, c: int) -> float:
    """Achieved bits-per-value of ``cfg`` on a concrete (r, c) matrix.

    Small tensors cannot amortize a codebook over the full nominal group
    size: the group plan caps a group at the matrix extent, so the
    codebook overhead term uses the group actually used (cols * band
    rows) rather than ``cfg.group_size``. Equals ``cfg.bits_per_value``
    whenever the matrix is large enough for the nominal plan.
    """
    from repro.core.gptvq import plan_groups  # deferred: gptvq imports us

    cg, rg = plan_groups(r, c, cfg)
    # same per-codebook storage as the nominal figure, amortized over the
    # group actually planned instead of cfg.group_size
    codebook = cfg.codebook_bits_per_value * cfg.group_size / (cg * rg)
    return cfg.index_bits_per_value + codebook + cfg.scale_bits_per_value


def int_quant_bpv(bits: int, group_size: int, c: int) -> float:
    """Achieved bpv of uniform integer quantization on ``c`` input columns:
    index bits + one fp16 scale per (row, group). Groups fall back to the
    largest divisor of c, mirroring quant.compute_qparams."""
    gs = c if group_size in (-1, None) else min(group_size, c)
    while c % gs != 0:
        gs -= 1
    return bits + DENSE_BITS / gs


def weighted_bpv(items) -> float:
    """Model-wide bits-per-value: ``items`` is an iterable of
    (numel, bpv) pairs; returns the numel-weighted mean."""
    total_bits = total_w = 0.0
    for numel, bpv in items:
        total_bits += numel * bpv
        total_w += numel
    return total_bits / max(total_w, 1.0)


# Paper's main configurations, matched to uniform W2@g128 / W2@g64 / W3@g128
# overheads (Table 2).  Keys: (d, bits_per_dim, total bpv).
PAPER_SETTINGS = {
    "2.125bpv_1d": VQConfig(d=1, bits_per_dim=2, group_size=256, codebook_bits=8),
    "2.125bpv_2d": VQConfig(d=2, bits_per_dim=2, group_size=2048, codebook_bits=8),
    "2.25bpv_1d": VQConfig(d=1, bits_per_dim=2, group_size=128, codebook_bits=8),
    "2.25bpv_2d": VQConfig(d=2, bits_per_dim=2, group_size=1024, codebook_bits=8),
    "2.25bpv_4d": VQConfig(d=4, bits_per_dim=2, group_size=32768, codebook_bits=8),
    "3.125bpv_1d": VQConfig(d=1, bits_per_dim=3, group_size=512, codebook_bits=8),
    "3.125bpv_2d": VQConfig(d=2, bits_per_dim=3, group_size=8192, codebook_bits=8),
    "4.125bpv_1d": VQConfig(d=1, bits_per_dim=4, group_size=1024, codebook_bits=8),
    "4.125bpv_2d": VQConfig(d=2, bits_per_dim=4, group_size=32768, codebook_bits=8),
}
