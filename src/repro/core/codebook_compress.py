"""Codebook post-processing (GPTVQ §3.3).

1. ``codebook_update``      — gradient descent on the convex layer objective
                              ||W X - Q(C) X||_F^2 = tr(E H E^T) w.r.t. the
                              codebook entries (assignments fixed).
2. ``quantize_codebooks``   — symmetric int8 min-max quantization, one scale
                              per codebook.
3. ``svd_compress``         — rank reduction of the (N_G, k) codebook tensor
                              for 1D VQ, with GD fine-tuning of the factors
                              U'' and V' on the same layer objective.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.gptvq import VQResult


def _adam_run(loss_fn, params, iters: int, lr: float):
    """Minimal Adam loop (pure JAX; optax is unavailable offline)."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)

    def step(carry, t):
        params, m, v = carry
        g = jax.grad(loss_fn)(params)
        m = jax.tree.map(lambda m_, g_: b1 * m_ + (1 - b1) * g_, m, g)
        v = jax.tree.map(lambda v_, g_: b2 * v_ + (1 - b2) * g_ * g_, v, g)
        tf = t.astype(jnp.float32) + 1.0
        mhat = jax.tree.map(lambda m_: m_ / (1 - b1**tf), m)
        vhat = jax.tree.map(lambda v_: v_ / (1 - b2**tf), v)
        params = jax.tree.map(
            lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps), params, mhat, vhat
        )
        return (params, m, v), None

    (params, _, _), _ = jax.lax.scan(step, (params, m, v), jnp.arange(iters))
    return params


def codebook_update(res: VQResult, W: jax.Array, H: jax.Array) -> VQResult:
    """GD on ||WX - QX||^2 w.r.t. codebooks; assignments and scales fixed."""
    iters = res.cfg.codebook_update_iters
    if iters <= 0:
        return res
    Wf = W.astype(jnp.float32)
    Hf = H.astype(jnp.float32)
    # normalize the objective so a single lr works across layers
    denom = jnp.maximum(jnp.sum(Wf * (Wf @ Hf)), 1e-12)
    # lr is relative to typical centroid magnitude
    scale = jnp.maximum(jnp.std(res.arrays.codebooks), 1e-8)

    def loss(C):
        E = Wf - res.reconstruct(C)
        return jnp.sum(E * (E @ Hf)) / denom

    C = _adam_run(
        loss, res.arrays.codebooks, iters, res.cfg.codebook_update_lr * scale
    )
    arrays = res.arrays._replace(codebooks=C, Q=res.reconstruct(C))
    return VQResult(
        arrays=arrays, cfg=res.cfg, r=res.r, c=res.c,
        group_cols=res.group_cols, rows_per_band=res.rows_per_band,
        codebook_scale=res.codebook_scale,
    )


def quantize_codebooks(res: VQResult) -> VQResult:
    """Symmetric min-max int8 (or cfg.codebook_bits) codebook quantization."""
    bits = res.cfg.codebook_bits
    if bits >= 16:
        return res
    C = res.arrays.codebooks  # (n_cg, n_bands, k, d)
    qmax = 2 ** (bits - 1) - 1
    absmax = jnp.max(jnp.abs(C), axis=(2, 3), keepdims=True)
    s = jnp.where(absmax == 0, 1.0, absmax / qmax)
    Cq = jnp.clip(jnp.round(C / s), -qmax - 1, qmax) * s
    arrays = res.arrays._replace(codebooks=Cq, Q=res.reconstruct(Cq))
    return VQResult(
        arrays=arrays, cfg=res.cfg, r=res.r, c=res.c,
        group_cols=res.group_cols, rows_per_band=res.rows_per_band,
        codebook_scale=s[..., 0, 0],
    )


class SVDCodebooks(NamedTuple):
    """Rank-reduced codebook tensor C-hat = U'' V'^T (1D VQ)."""

    U: jax.Array      # (N_G, rho)   quantized at codebook_bits
    V: jax.Array      # (k, rho)     kept in fp (negligible overhead)
    perm: jax.Array   # (N_G, k) int32: per-codebook sort permutation applied


def svd_compress(res: VQResult, W: jax.Array, H: jax.Array,
                 rank_frac: float | None = None,
                 gd_iters: int = 25) -> tuple[VQResult, SVDCodebooks]:
    """Paper's SVD codebook compression (applied to 1D VQ only).

    Sorts centroids within each codebook (reassigning indices), stacks the
    (N_G, k) codebook matrix, takes a rank-rho SVD, fine-tunes the factors by
    GD on the layer objective, and quantizes only U''.
    """
    assert res.cfg.d == 1, "SVD codebook compression is a 1D-VQ feature"
    frac = res.cfg.svd_rank_frac if rank_frac is None else rank_frac
    C = res.arrays.codebooks  # (n_cg, n_bands, k, 1)
    n_cg, n_bands, k, _ = C.shape
    N_G = n_cg * n_bands
    flat = C.reshape(N_G, k)

    # sort centroids per codebook, remap indices so gather stays valid
    order = jnp.argsort(flat, axis=1)                  # (N_G, k) old idx at new pos
    sorted_flat = jnp.take_along_axis(flat, order, axis=1)
    rank_of_old = jnp.argsort(order, axis=1)           # new idx of old centroid

    idx = res.arrays.indices  # (r, c/d)
    rg, cg = res.rows_per_band, res.group_cols
    idx4 = idx.reshape(n_bands, rg, n_cg, cg)          # d=1 -> spans_pg = cg
    # flat index layout: C.reshape(N_G, k) flattens (n_cg, n_bands) row-major
    flat_id = (
        jnp.arange(n_cg)[None, None, :, None] * n_bands
        + jnp.arange(n_bands)[:, None, None, None]
    )
    new_idx4 = rank_of_old[flat_id, idx4]
    new_idx = new_idx4.reshape(res.r, res.c // res.cfg.d)

    rho = max(1, int(round(frac * k)))
    Um, s, Vt = jnp.linalg.svd(sorted_flat, full_matrices=False)
    U2 = (Um * s[None, :])[:, :rho]          # (N_G, rho), Sigma folded in
    V2 = Vt.T[:, :rho]                       # (k, rho)

    Wf = W.astype(jnp.float32)
    Hf = H.astype(jnp.float32)
    denom = jnp.maximum(jnp.sum(Wf * (Wf @ Hf)), 1e-12)

    def rebuild(U2, V2):
        Chat = U2 @ V2.T                      # (N_G, k)
        return Chat.reshape(n_cg, n_bands, k, 1)

    base = VQResult(
        arrays=res.arrays._replace(indices=new_idx), cfg=res.cfg, r=res.r,
        c=res.c, group_cols=res.group_cols, rows_per_band=res.rows_per_band,
    )

    def loss(params):
        U2, V2 = params
        E = Wf - base.reconstruct(rebuild(U2, V2))
        return jnp.sum(E * (E @ Hf)) / denom

    lr = 1e-3 * jnp.maximum(jnp.std(U2), 1e-8)
    U2, V2 = _adam_run(loss, (U2, V2), gd_iters, lr)

    # quantize only U'' (paper: V' overhead negligible)
    bits = res.cfg.codebook_bits
    qmax = 2 ** (bits - 1) - 1
    absmax = jnp.maximum(jnp.max(jnp.abs(U2), axis=1, keepdims=True), 1e-12)
    su = absmax / qmax
    U2q = jnp.clip(jnp.round(U2 / su), -qmax - 1, qmax) * su

    Cq = rebuild(U2q, V2)
    arrays = base.arrays._replace(codebooks=Cq)
    out = VQResult(
        arrays=arrays._replace(Q=base.reconstruct(Cq)), cfg=res.cfg, r=res.r,
        c=res.c, group_cols=res.group_cols, rows_per_band=res.rows_per_band,
    )
    return out, SVDCodebooks(U=U2q, V=V2, perm=order)
