"""Model-level GPTVQ pipeline: sequential layerwise PTQ (paper §4).

Mirrors the GPTQ/GPTVQ flow: walk the blocks in order; for each block,
accumulate the input Hessian of every target matmul from the *current*
(already partially quantized) activation stream, quantize the block's
weights, then push the activations through the quantized block before moving
on — so downstream Hessians see upstream quantization error.

Distribution: calibration sequences shard across data-parallel workers; each
accumulates partial Hessians and a single all-reduce merges them (the
quantizer itself is layer-local). On this single-process container the same
code runs with world size 1.

Supported: the transformer family (dense / MoE / VLM text stack). Weight
convention note: model kernels are (in, out); GPTVQ operates on (out, in) so
every matrix is transposed on entry and the packed VQLinear stores (r=out,
c=in) — see core/vq_linear.dequant_tree.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hessian as hes
from repro.core import vq_linear as vql_mod
from repro.core.bpv import VQConfig
from repro.core.codebook_compress import codebook_update, quantize_codebooks
from repro.core.gptvq import gptvq_quantize_matrix, layer_error
from repro.core.quant import gptq_quantize, rtn_quantize
from repro.models import attention, common as cm, mlp, moe, transformer


@dataclasses.dataclass
class QuantizeReport:
    per_layer: list
    total_seconds: float
    method: str
    bits_per_value: float


def _quantize_matrix(W_io, H, method: str, cfg, key):
    """W_io: (in, out) kernel. Returns (fake-quant (in,out), VQLinear|None)."""
    W = W_io.T.astype(jnp.float32)  # (out, in)
    if method == "rtn":
        return rtn_quantize(W, cfg["bits"], cfg["group_size"]).T.astype(
            W_io.dtype), None
    if method == "kmeans":
        # Table-1 baseline: plain k-means clustering, no Hessian weighting,
        # no error feedback (identity H => EM == k-means, U == I)
        res = gptvq_quantize_matrix(
            W, jnp.eye(W.shape[1], dtype=jnp.float32), cfg, key)
        return res.arrays.Q.T.astype(W_io.dtype), None
    U = hes.inv_hessian_cholesky(H)
    if method == "kmeans_data":
        # Table-1 middle row: k-means WITH layer input data (Hessian-weighted
        # EM/assignment) but no GPTQ-style error feedback: diagonal-only U
        Ud = jnp.diag(jnp.diagonal(U))
        res = gptvq_quantize_matrix(W, Ud, cfg, key)
        return res.arrays.Q.T.astype(W_io.dtype), None
    if method == "gptq":
        Q = gptq_quantize(W, U, bits=cfg["bits"], group_size=cfg["group_size"])
        return Q.T.astype(W_io.dtype), None
    assert method == "gptvq"
    vq_cfg: VQConfig = cfg
    res = gptvq_quantize_matrix(W, U, vq_cfg, key)
    res = codebook_update(res, W, H)
    res = quantize_codebooks(res)
    packed = vql_mod.from_vq_result(res)
    return res.arrays.Q.T.astype(W_io.dtype), packed


def _attn_pre_out(p, cfg: ModelConfig, x1, pos=0):
    """Attention up to (but not including) wo; returns (B,S,H*hd)."""
    B, S, _ = x1.shape
    q, k, v = attention._project_qkv(p, cfg, x1)
    pos_arr = jnp.broadcast_to((jnp.asarray(pos) + jnp.arange(S))[None], (B, S))
    q = cm.apply_rope(q, pos_arr, cfg.rope_theta)
    k = cm.apply_rope(k, pos_arr, cfg.rope_theta)
    if S > 2048:
        o = attention.flash_attention(q, k, v, causal=True)
    else:
        msk = (jnp.arange(S)[None, :] <= jnp.arange(S)[:, None])[None, None, None]
        o = attention._plain_attention(q, k, v, msk)
    return o.reshape(B, S, -1)


def _accumulate(H: hes.HessianState | None, x) -> hes.HessianState:
    c = x.shape[-1]
    if H is None:
        H = hes.init_hessian(c)
    return hes.accumulate(H, x)


def quantize_model(
    model,
    params,
    tokens: jax.Array,       # (n_seq, S) calibration tokens
    method: str = "gptvq",
    cfg: Any = None,         # VQConfig for gptvq; {"bits","group_size"} else
    *,
    pack: bool = False,      # True -> VQLinear leaves (serving format)
    chunk: int = 8,          # calibration sequences per forward chunk
    quantize_attn: bool = True,
    quantize_mlp: bool = True,
    seed: int = 0,
    progress: Callable[[str], None] | None = None,
):
    """Quantize a transformer-family model. Returns (new_params, report)."""
    mcfg: ModelConfig = model.cfg
    assert transformer.homogeneous(mcfg) or mcfg.family in ("dense", "moe", "vlm")
    t0 = time.time()
    key = jax.random.PRNGKey(seed)
    if cfg is None:
        cfg = VQConfig() if method == "gptvq" else {"bits": 4, "group_size": 128}

    n_seq = tokens.shape[0]
    chunks = [tokens[i : i + chunk] for i in range(0, n_seq, chunk)]
    # current activations per chunk (updated as blocks quantize)
    xs = [transformer.embed_tokens(params, mcfg, c) for c in chunks]

    L = mcfg.n_layers
    layers = params["layers"]
    get_layer = (lambda i: jax.tree.map(lambda a: a[i], layers)) \
        if not isinstance(layers, list) else (lambda i: layers[i])

    new_layers = []
    report_rows = []
    kind = transformer.block_kind(mcfg, 0)

    for li in range(L):
        lp = {k: v for k, v in get_layer(li).items()}
        lp_attn = dict(lp["attn"])
        lp_ffn = dict(lp["ffn"])
        row = {"layer": li}

        # ---- pass 1: Hessians from current activations --------------------
        H_qkv = H_wo = H_in = H_out = None
        H_experts_in = H_experts_out = None
        for x in xs:
            x1 = cm.rmsnorm(x, lp["norm1"], mcfg.norm_eps)
            if quantize_attn:
                H_qkv = _accumulate(H_qkv, x1)
                o = _attn_pre_out(lp["attn"], mcfg, x1)
                H_wo = _accumulate(H_wo, o)
            a, _ = attention.apply(lp["attn"], mcfg, x1, pos=0)
            xa = x + a
            x2 = cm.rmsnorm(xa, lp["norm2"], mcfg.norm_eps)
            if quantize_mlp:
                if kind == "dense":
                    H_in = _accumulate(H_in, x2)
                    h = x2 @ lp["ffn"]["w_in"]
                    if cm.is_gated(mcfg.activation):
                        h = jax.nn.silu(x2 @ lp["ffn"]["w_gate"]) * h \
                            if mcfg.activation == "swiglu" else \
                            jax.nn.gelu(x2 @ lp["ffn"]["w_gate"]) * h
                    else:
                        h = cm.act_fn(mcfg.activation)(h)
                    H_out = _accumulate(H_out, h)
                else:  # moe: per-expert Hessians from routed tokens
                    eh_in, eh_out = _moe_hessians(lp["ffn"], mcfg, x2)
                    H_experts_in = _merge_expert_h(H_experts_in, eh_in)
                    H_experts_out = _merge_expert_h(H_experts_out, eh_out)

        # ---- pass 2: quantize weights -------------------------------------
        def do(W, H, subkey):
            Hm = hes.finalize(H) if H is not None else None
            return _quantize_matrix(W, Hm, method, cfg, subkey)

        if quantize_attn:
            for i, w in enumerate(("wq", "wk", "wv")):
                key, sub = jax.random.split(key)
                q, packed = do(lp_attn[w], H_qkv, sub)
                lp_attn[w] = packed if (pack and packed is not None) else q
            key, sub = jax.random.split(key)
            q, packed = do(lp_attn["wo"], H_wo, sub)
            lp_attn["wo"] = packed if (pack and packed is not None) else q
        if quantize_mlp and kind == "dense":
            names = ["w_in", "w_out"] + (
                ["w_gate"] if cm.is_gated(mcfg.activation) else [])
            hmap = {"w_in": H_in, "w_gate": H_in, "w_out": H_out}
            for w in names:
                key, sub = jax.random.split(key)
                q, packed = do(lp_ffn[w], hmap[w], sub)
                lp_ffn[w] = packed if (pack and packed is not None) else q
        elif quantize_mlp and kind == "moe":
            lp_ffn = _quantize_experts(
                lp_ffn, mcfg, H_experts_in, H_experts_out, method, cfg, key)

        new_lp = dict(lp, attn=lp_attn, ffn=lp_ffn)
        new_layers.append(new_lp)

        # ---- pass 3: advance activations through the quantized block ------
        dense_lp = vql_mod.dequant_tree(new_lp, jnp.float32)
        xs = [
            transformer._block_apply(dense_lp, mcfg, kind, x, pos=0,
                                     cache=None)[0]
            for x in xs
        ]
        if progress:
            progress(f"layer {li + 1}/{L} done")
        report_rows.append(row)

    # reassemble
    if isinstance(layers, list):
        out_layers = new_layers
    else:
        out_layers = jax.tree.map(lambda *ls: jnp.stack(ls), *new_layers) \
            if not pack else _stack_with_vq(new_layers)
    new_params = dict(params, layers=out_layers)
    bpv = cfg.bits_per_value if isinstance(cfg, VQConfig) else (
        cfg["bits"] + 16.0 / cfg["group_size"])
    return new_params, QuantizeReport(report_rows, time.time() - t0, method, bpv)


def _stack_with_vq(layer_list):
    """Stack per-layer trees where leaves may be VQLinear dataclasses."""
    def is_leaf(x):
        return isinstance(x, vql_mod.VQLinear) or not isinstance(
            x, (dict, list, tuple))

    def stack(*ls):
        if isinstance(ls[0], vql_mod.VQLinear):
            arrays = jax.tree.map(lambda *a: jnp.stack(a), *ls)
            return arrays
        return jnp.stack(ls)

    return jax.tree.map(stack, *layer_list, is_leaf=is_leaf)


def _moe_hessians(p, mcfg: ModelConfig, x2):
    """Per-expert input/output-side Hessian accumulation for one chunk."""
    B, S, D = x2.shape
    E, K = mcfg.n_experts, mcfg.n_experts_active
    xf = x2.reshape(B * S, D)
    logits = xf.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    _, eids = jax.lax.top_k(probs, K)
    onehot = jax.nn.one_hot(eids, E, dtype=jnp.float32).sum(1)  # (N, E)
    # input-side: H_e = sum over tokens routed to e of x x^T
    Hin = jnp.einsum("ne,nd,nc->edc", onehot, xf, xf)
    # output-side: inputs to w_out are h = act(...) per expert
    act = cm.act_fn(mcfg.activation)
    h = jnp.einsum("nd,edf->enf", xf, p["w_in"].astype(jnp.float32))
    if cm.is_gated(mcfg.activation):
        g = jnp.einsum("nd,edf->enf", xf, p["w_gate"].astype(jnp.float32))
        h = act(g) * h
    else:
        h = act(h)
    h = h * onehot.T[..., None]  # zero out tokens not routed to e
    Hout = jnp.einsum("enf,eng->efg", h, h)
    n = jnp.maximum(onehot.sum(0), 1.0)
    return (Hin, n), (Hout, n)


def _merge_expert_h(acc, new):
    if acc is None:
        return new
    return (acc[0] + new[0], acc[1] + new[1])


def _quantize_experts(lp_ffn, mcfg, Hin_acc, Hout_acc, method, cfg, key):
    """Quantize each expert matrix with its routed-token Hessian."""
    E = mcfg.n_experts
    Hin, n_in = Hin_acc
    Hout, _ = Hout_acc
    out = dict(lp_ffn)
    names = ["w_in", "w_out"] + (["w_gate"] if cm.is_gated(mcfg.activation)
                                 else [])
    for wname in names:
        Ws = lp_ffn[wname]  # (E, d_in, d_out)
        Hs = Hin if wname in ("w_in", "w_gate") else Hout
        qs = []
        for e in range(E):
            key, sub = jax.random.split(key)
            He = Hs[e] / jnp.maximum(n_in[e], 1.0)
            q, _ = _quantize_matrix(Ws[e], He, method, cfg, sub)
            qs.append(q)
        out[wname] = jnp.stack(qs)
    return out
