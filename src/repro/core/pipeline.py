"""Model-level GPTVQ pipeline: a family-agnostic sequential PTQ driver.

GPTVQ (paper §4) is a per-layer method over a stack of linear maps: walk
the blocks in order; for each block, accumulate the input Hessian of every
target matmul from the *current* (already partially quantized) activation
stream, quantize the block's weights, then push the activations through
the quantized block before moving on — so downstream Hessians see upstream
quantization error.

Nothing in that loop is transformer-specific, so the driver here is
written once against the ``ModelAdapter`` / ``BlockAdapter`` registry in
core/adapters/ (the SliceGPT/QuaRot adapter pattern): the adapter names
each block's quantizable weight leaves as ``WeightSpec`` (name, path,
hessian-tap) triples, owns the block sub-forwards that accumulate the tap
Hessians (``capture``), and advances calibration activations through the
quantized block (``advance``). All block anatomy — what feeds q/k/v vs the
output projection, per-expert routed-token Hessians, Mamba scan params
that stay dense, cross-attention memory taps — lives in the family's
adapter module. Supported families: transformer dense/MoE, VLM text
stacks, xLSTM (ssm), Mamba+shared-attention hybrids, and audio
encoder-decoders.

Distribution: calibration sequences shard across data-parallel workers;
each accumulates partial Hessians and a single all-reduce merges them (the
quantizer itself is layer-local). On a single-process container the same
code runs with world size 1.

Weight convention: model kernels are (in, out); GPTVQ operates on
(out, in) so every matrix is transposed on entry and the packed VQLinear
stores (r=out, c=in) — see core/vq_linear.dequant_tree.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import adapters
from repro.core import hessian as hes
from repro.core import vq_linear as vql_mod
from repro.core.bpv import VQConfig
from repro.core.codebook_compress import codebook_update, quantize_codebooks
from repro.core.gptvq import gptvq_quantize_matrix, layer_error
from repro.core.quant import gptq_quantize, rtn_quantize


@dataclasses.dataclass
class QuantizeReport:
    per_layer: list     # one row per block: {"layer", "block", target: err}
    total_seconds: float
    method: str
    bits_per_value: float

    def total_error(self) -> float:
        """Summed Hessian-weighted reconstruction error over all targets."""
        return float(sum(
            v for row in self.per_layer for k, v in row.items()
            if k not in ("layer", "block")))


def _quantize_matrix(W_io, H, method: str, cfg, key):
    """W_io: (in, out) kernel. Returns (fake-quant (in,out), VQLinear|None)."""
    W = W_io.T.astype(jnp.float32)  # (out, in)
    if method == "rtn":
        return rtn_quantize(W, cfg["bits"], cfg["group_size"]).T.astype(
            W_io.dtype), None
    if method == "kmeans":
        # Table-1 baseline: plain k-means clustering, no Hessian weighting,
        # no error feedback (identity H => EM == k-means, U == I)
        res = gptvq_quantize_matrix(
            W, jnp.eye(W.shape[1], dtype=jnp.float32), cfg, key)
        return res.arrays.Q.T.astype(W_io.dtype), None
    U = hes.inv_hessian_cholesky(H)
    if method == "kmeans_data":
        # Table-1 middle row: k-means WITH layer input data (Hessian-weighted
        # EM/assignment) but no GPTQ-style error feedback: diagonal-only U
        Ud = jnp.diag(jnp.diagonal(U))
        res = gptvq_quantize_matrix(W, Ud, cfg, key)
        return res.arrays.Q.T.astype(W_io.dtype), None
    if method == "gptq":
        Q = gptq_quantize(W, U, bits=cfg["bits"], group_size=cfg["group_size"])
        return Q.T.astype(W_io.dtype), None
    assert method == "gptvq"
    vq_cfg: VQConfig = cfg
    res = gptvq_quantize_matrix(W, U, vq_cfg, key)
    res = codebook_update(res, W, H)
    res = quantize_codebooks(res)
    packed = vql_mod.from_vq_result(res)
    return res.arrays.Q.T.astype(W_io.dtype), packed


def _recon_error(W_io, q_io, H) -> float:
    """Hessian-weighted reconstruction error of one quantized matrix."""
    W = W_io.T.astype(jnp.float32)
    Q = q_io.T.astype(jnp.float32)
    if H is None:
        H = jnp.eye(W.shape[1], dtype=jnp.float32)
    return float(layer_error(W, Q, H))


def _quantize_expert_stack(Ws, tap, method, cfg, key, pack):
    """Quantize an (E, in, out) expert stack, one routed-token Hessian per
    expert. Returns (key, new leaf, summed reconstruction error)."""
    E = Ws.shape[0]
    Hs, n = tap if tap is not None else (None, None)
    # n: raw routed-token counts summed over chunks; clamp exactly once here
    qs, packs = [], []
    err = 0.0
    for e in range(E):
        key, sub = jax.random.split(key)
        He = Hs[e] / jnp.maximum(n[e], 1.0) if Hs is not None else None
        q, packed = _quantize_matrix(Ws[e], He, method, cfg, sub)
        qs.append(q)
        packs.append(packed)
        err += _recon_error(Ws[e], q, He)
    if pack and packs[0] is not None:
        leaf = jax.tree.map(lambda *a: jnp.stack(a), *packs)
    else:
        leaf = jnp.stack(qs)
    return key, leaf, err


def quantize_model(
    model,
    params,
    tokens: jax.Array,       # (n_seq, S) calibration tokens
    method: str = "gptvq",
    cfg: Any = None,         # VQConfig for gptvq; {"bits","group_size"} else
    *,
    pack: bool = False,      # True -> VQLinear leaves (serving format)
    chunk: int = 8,          # calibration sequences per forward chunk
    quantize_attn: bool = True,   # quantize the "attn" (mixer) weight group
    quantize_mlp: bool = True,    # quantize the "mlp" (feed-forward) group
    seed: int = 0,
    progress: Callable[[str], None] | None = None,
):
    """Quantize any registered model family. Returns (new_params, report).

    The driver is three passes per block, mediated by the family's
    adapter: (1) Hessian capture from the current calibration activations,
    (2) quantization of every ``WeightSpec`` target against its tap,
    (3) advancing the activations through the quantized block.
    """
    t0 = time.time()
    adapter = adapters.get_adapter(model, params)
    groups = frozenset(
        g for g, on in (("attn", quantize_attn), ("mlp", quantize_mlp)) if on)
    key = jax.random.PRNGKey(seed)
    if cfg is None:
        cfg = VQConfig() if method == "gptvq" else {"bits": 4, "group_size": 128}

    n_seq = tokens.shape[0]
    chunks = [tokens[i : i + chunk] for i in range(0, n_seq, chunk)]
    states = [adapter.calib_state(c, ci) for ci, c in enumerate(chunks)]

    blocks = adapter.blocks()
    report_rows = []
    for bi, blk in enumerate(blocks):
        # ---- pass 1: Hessian taps from current activations ----------------
        taps: dict = {}
        for st in states:
            taps = blk.capture(st, taps, groups)

        # ---- pass 2: quantize this block's targets ------------------------
        new_block = blk.params()
        row = {"layer": bi, "block": blk.name}
        for spec in blk.targets():
            if spec.group not in groups:
                continue
            W = adapters.tree_get(new_block, spec.path)
            tap = taps.get(spec.tap)
            if tap is None and method not in ("rtn", "kmeans"):
                # data-aware methods need the tap; a miss is an adapter bug
                # (capture never accumulated what targets() promised)
                raise KeyError(
                    f"block {blk.name!r}: Hessian tap {spec.tap!r} for "
                    f"target {spec.name!r} was never captured")
            if spec.per_expert:
                key, leaf, err = _quantize_expert_stack(
                    W, tap, method, cfg, key, pack)
            else:
                H = hes.finalize(tap) if tap is not None else None
                key, sub = jax.random.split(key)
                q, packed = _quantize_matrix(W, H, method, cfg, sub)
                leaf = packed if (pack and packed is not None) else q
                err = _recon_error(W, q, H)
            new_block = adapters.tree_set(new_block, spec.path, leaf)
            row[spec.name] = err
        blk.install(new_block)

        # ---- pass 3: advance activations through the quantized block ------
        states = [blk.advance(st) for st in states]
        if progress:
            progress(f"block {bi + 1}/{len(blocks)} [{blk.name}] done")
        report_rows.append(row)

    new_params = adapter.finalize()
    bpv = cfg.bits_per_value if isinstance(cfg, VQConfig) else (
        cfg["bits"] + 16.0 / cfg["group_size"])
    return new_params, QuantizeReport(report_rows, time.time() - t0, method,
                                      bpv)
