"""Model-level GPTVQ pipeline: a family-agnostic sequential PTQ driver.

GPTVQ (paper §4) is a per-layer method over a stack of linear maps: walk
the blocks in order; for each block, accumulate the input Hessian of every
target matmul from the *current* (already partially quantized) activation
stream, quantize the block's weights, then push the activations through
the quantized block before moving on — so downstream Hessians see upstream
quantization error.

Nothing in that loop is transformer-specific, so the driver here is
written once against the ``ModelAdapter`` / ``BlockAdapter`` registry in
core/adapters/ (the SliceGPT/QuaRot adapter pattern): the adapter names
each block's quantizable weight leaves as ``WeightSpec`` (name, path,
hessian-tap) triples, owns the block sub-forwards that accumulate the tap
Hessians (``capture``), and advances calibration activations through the
quantized block (``advance``). All block anatomy — what feeds q/k/v vs the
output projection, per-expert routed-token Hessians, Mamba scan params
that stay dense, cross-attention memory taps — lives in the family's
adapter module. Supported families: transformer dense/MoE, VLM text
stacks, xLSTM (ssm), Mamba+shared-attention hybrids, and audio
encoder-decoders.

Configuration is a declarative ``QuantRecipe`` (core/recipe.py): ordered
``Rule(pattern, action)`` entries over the canonical target names
(``<block_prefix>.<spec.name>``) resolve every leaf to Quantize /
IntQuant / KeepDense before any compute; adapter-declared dense
exclusions (e.g. sLSTM ``r_*``) surface in the report instead of being
silently skipped. ``budget_bpv`` turns on Hessian-budgeted mixed
precision: a pre-pass over the unquantized model collects per-target
diagonal Hessians, and a greedy allocator picks each target's setting so
the model-wide weighted bpv stays on budget. The legacy
``(method, cfg, quantize_attn, quantize_mlp)`` kwargs remain as a shim
that compiles to an equivalent recipe with bitwise-identical packed
payloads.

Distribution: calibration sequences shard across data-parallel workers;
each accumulates partial Hessians and a single all-reduce merges them (the
quantizer itself is layer-local). In-process, ``hessian_mesh=`` runs the
same scheme over a ``jax.sharding`` mesh: calibration rows shard across
the mesh's data axis and one psum per accumulate merges the partials
(hessian.accumulate_sharded). On a single-process container the same code
runs with world size 1.

Weight convention: model kernels are (in, out); GPTVQ operates on
(out, in) so every matrix is transposed on entry and the packed VQLinear
stores (r=out, c=in) — see core/vq_linear.dequant_tree.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time
import warnings
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core import adapters
from repro.core import hessian as hes
from repro.core import vq_linear as vql_mod
from repro.core.bpv import VQConfig, weighted_bpv
from repro.core.codebook_compress import codebook_update, quantize_codebooks
from repro.core.gptvq import gptvq_quantize_matrix, layer_error
from repro.core.quant import gptq_quantize, rtn_quantize
from repro.core.recipe import (
    BudgetEntry,
    IntQuant,
    KeepDense,
    QuantRecipe,
    Quantize,
    RecipeError,
    Resolved,
    TargetInfo,
    allocate_budget,
)


@dataclasses.dataclass
class QuantizeReport:
    per_layer: list     # one row per block: {"layer", "block", target: err}
    total_seconds: float
    method: str
    bits_per_value: float   # nominal cfg bpv (legacy uniform) / achieved
    # per canonical target: {"action", "rule", "bpv", "numel", "error", ...}
    per_target: dict = dataclasses.field(default_factory=dict)
    achieved_bpv: float = 0.0   # numel-weighted model-wide bpv, overhead incl.
    recipe: dict | None = None  # the resolved recipe, JSON-able
    # host-side seconds per pipeline stage (hessian_capture, em_init,
    # column_sweep, cd_refine, codebook_update, advance). Approximate under
    # jax async dispatch, but the gptvq stages sync on exit and each block
    # ends in a float() sync, so drift stays within a block.
    stage_seconds: dict = dataclasses.field(default_factory=dict)
    # human-readable anomalies (e.g. budget pre-pass targets whose Hessian
    # tap never fired, scored by weight variance instead)
    warnings: list = dataclasses.field(default_factory=list)

    def total_error(self) -> float:
        """Summed Hessian-weighted reconstruction error over all targets."""
        return float(sum(
            v for row in self.per_layer for k, v in row.items()
            if k not in ("layer", "block")))


def _null_stage(name):
    return contextlib.nullcontext()


def _apply_action(W_io, H, action, key, stage=_null_stage):
    """W_io: (in, out) kernel. Returns (fake-quant (in,out), VQLinear|None).

    Dispatch mirrors the legacy method strings exactly (same ops, same
    jitted functions) so shim-compiled recipes stay bitwise-identical.

    ``stage(name)`` yields a context manager timing one pipeline stage
    (telemetry span + stage-seconds accumulation). The GPTVQ sweep takes
    the timer itself and splits its phases honestly: ``em_init`` (jitted
    per-group codebook init), ``column_sweep`` (the d-span sweep), and
    ``cd_refine`` (solver="cd" only).
    """
    W = W_io.T.astype(jnp.float32)  # (out, in)
    # gptvq takes the timer itself; None keeps untimed callers fully async
    gstage = stage if stage is not _null_stage else None
    if isinstance(action, IntQuant):
        if action.method == "rtn":
            with stage("column_sweep"):
                q = rtn_quantize(W, action.bits, action.group_size)
            return q.T.astype(W_io.dtype), None
        U = hes.inv_hessian_cholesky(
            H if H is not None else jnp.eye(W.shape[1], dtype=jnp.float32))
        with stage("column_sweep"):
            Q = gptq_quantize(W, U, bits=action.bits,
                              group_size=action.group_size)
        return Q.T.astype(W_io.dtype), None
    assert isinstance(action, Quantize)
    cfg = action.cfg
    if action.method == "kmeans":
        # Table-1 baseline: plain k-means clustering, no Hessian weighting,
        # no error feedback (identity H => EM == k-means, U == I)
        res = gptvq_quantize_matrix(
            W, jnp.eye(W.shape[1], dtype=jnp.float32), cfg, key,
            stage=gstage)
        return res.arrays.Q.T.astype(W_io.dtype), None
    U = hes.inv_hessian_cholesky(
        H if H is not None else jnp.eye(W.shape[1], dtype=jnp.float32))
    if action.method == "kmeans_data":
        # Table-1 middle row: k-means WITH layer input data (Hessian-weighted
        # EM/assignment) but no GPTQ-style error feedback: diagonal-only U
        Ud = jnp.diag(jnp.diagonal(U))
        res = gptvq_quantize_matrix(W, Ud, cfg, key, stage=gstage)
        return res.arrays.Q.T.astype(W_io.dtype), None
    assert action.method == "gptvq"
    solver = getattr(action, "solver", "gptq")
    H_eff = H if H is not None else jnp.eye(W.shape[1], dtype=jnp.float32)
    res = gptvq_quantize_matrix(W, U, cfg, key, solver=solver,
                                H=H_eff if solver == "cd" else None,
                                stage=gstage)
    with stage("codebook_update"):
        if H is not None:
            res = codebook_update(res, W, H)
        res = quantize_codebooks(res)
        packed = vql_mod.from_vq_result(res)
    return res.arrays.Q.T.astype(W_io.dtype), packed


def _recon_error(W_io, q_io, H) -> float:
    """Hessian-weighted reconstruction error of one quantized matrix."""
    W = W_io.T.astype(jnp.float32)
    Q = q_io.T.astype(jnp.float32)
    if H is None:
        H = jnp.eye(W.shape[1], dtype=jnp.float32)
    return float(layer_error(W, Q, H))


def _quantize_expert_stack(Ws, tap, action, key, pack, rule: str,
                           stage=_null_stage):
    """Quantize an (E, in, out) expert stack, one routed-token Hessian per
    expert. Returns (key, new leaf, summed reconstruction error)."""
    E = Ws.shape[0]
    Hs, n = tap if tap is not None else (None, None)
    # n: raw routed-token counts summed over chunks; clamp exactly once here
    qs, packs = [], []
    err = 0.0
    for e in range(E):
        key, sub = jax.random.split(key)
        He = Hs[e] / jnp.maximum(n[e], 1.0) if Hs is not None else None
        q, packed = _apply_action(Ws[e], He, action, sub, stage)
        qs.append(q)
        if packed is not None:
            packed = dataclasses.replace(packed, rule=rule)
        packs.append(packed)
        err += _recon_error(Ws[e], q, He)
    if pack and packs[0] is not None:
        leaf = jax.tree.map(lambda *a: jnp.stack(a), *packs)
    else:
        leaf = jnp.stack(qs)
    return key, leaf, err


def _block_prefix(blk) -> str:
    """Canonical name prefix for a block's targets (adapters set
    ``prefix``; the display ``name`` is the fallback)."""
    return getattr(blk, "prefix", blk.name)


def _collect_targets(blocks) -> list[TargetInfo]:
    """Flatten every block's WeightSpecs into resolver TargetInfo rows."""
    out = []
    for blk in blocks:
        prefix = _block_prefix(blk)
        block_params = blk.params()
        for spec in blk.targets():
            W = adapters.tree_get(block_params, spec.path)
            if spec.per_expert:
                E, c, r = W.shape
                numel = E * c * r
            elif W.ndim == 2:
                c, r = W.shape           # (in, out) kernel
                numel = c * r
            else:
                # non-matmul leaf (e.g. sLSTM block-diagonal r_*): only
                # KeepDense can apply; record extents for bpv weighting
                r, c = W.shape[-1], W.shape[-2]
                numel = W.size
            default = (KeepDense(spec.keep_dense) if spec.keep_dense
                       is not None else None)
            out.append(TargetInfo(
                name=f"{prefix}.{spec.name}", group=spec.group, r=r, c=c,
                numel=numel, default_action=default))
    return out


def _check_plan(blocks, plan) -> None:
    """Fail fast on actions the target's leaf cannot support."""
    for blk in blocks:
        prefix = _block_prefix(blk)
        block_params = blk.params()
        for spec in blk.targets():
            res = plan[f"{prefix}.{spec.name}"]
            if isinstance(res.action, KeepDense):
                continue
            W = adapters.tree_get(block_params, spec.path)
            if W.ndim != (3 if spec.per_expert else 2):
                raise RecipeError(
                    f"target {prefix}.{spec.name!r} has shape "
                    f"{tuple(W.shape)}; only 2-D kernels (or 3-D expert "
                    f"stacks) can quantize — use keep_dense "
                    f"(matched {res.rule})")


def _budget_prepass(adapter, chunks, plan, progress, mesh=None,
                    mesh_axis: str = "data"):
    """Collect per-target diagonal Hessians from the *unquantized* model.

    One cheap forward sweep under ``adapters.diag_capture()``: every tap
    accumulates an O(c) ``DiagHessianState`` (per-expert taps an (E, c)
    stack) — the full (c, c) Hessian is never materialized, which is what
    lets the pre-pass scale to 70B-class column counts. With ``mesh`` set,
    accumulation additionally shards calibration rows data-parallel over
    the mesh axis. Installs the original params and advances, using a
    fresh blocks() list so the real sweep starts clean.

    Returns (diag, missed): ``missed`` maps target names whose Hessian
    could not be collected to a reason string — the caller scores those
    by weight variance explicitly and surfaces a warning.
    """
    states = [adapter.calib_state(c, ci) for ci, c in enumerate(chunks)]
    blocks = adapter.blocks()
    diag: dict[str, jax.Array] = {}
    missed: dict[str, str] = {}
    with contextlib.ExitStack() as cm:
        cm.enter_context(adapters.diag_capture())
        if mesh is not None:
            cm.enter_context(adapters.hessian_mesh(mesh, mesh_axis))
        for blk in blocks:
            prefix = _block_prefix(blk)
            eligible = [
                spec for spec in blk.targets()
                if isinstance(plan[f"{prefix}.{spec.name}"].action, Quantize)
                and spec.tap is not None]
            groups = frozenset(spec.group for spec in eligible)
            taps: dict = {}
            if groups:
                for st in states:
                    taps = blk.capture(st, taps, groups)
            for spec in eligible:
                tap = taps.get(spec.tap)
                name = f"{prefix}.{spec.name}"
                if tap is None:
                    missed[name] = f"tap {spec.tap!r} never fired"
                    continue
                if spec.per_expert:
                    Hd, n = tap  # (E, c) diag stack under diag_capture
                    He = Hd / jnp.maximum(n, 1.0)[:, None]
                    diag[name] = jnp.mean(He, axis=0)
                else:
                    diag[name] = hes.finalize_diag(tap)
            blk.install(blk.params())
            states = [blk.advance(st) for st in states]
            if progress:
                progress(f"budget pre-pass: {blk.name}")
    return diag, missed


def _allocate(blocks, plan, diag, missed, budget_bpv, progress,
              scorer: str = "closed_form"):
    """Rewrite Quantize plan entries with the budget allocator's choice.

    Returns (plan, warnings): targets the pre-pass could not collect a
    Hessian for are scored by weight variance (explicit identity
    weights) and reported in ``warnings``.
    """
    entries, fixed_bits, fixed_numel = [], 0.0, 0
    warn_rows: list[str] = []
    for blk in blocks:
        prefix = _block_prefix(blk)
        block_params = blk.params()
        for spec in blk.targets():
            name = f"{prefix}.{spec.name}"
            res = plan[name]
            W = adapters.tree_get(block_params, spec.path)
            if spec.per_expert:
                replicas = W.shape[0]
                Wq, numel = W[0].T.astype(jnp.float32), W.size
            else:
                replicas = 1
                Wq, numel = W.T.astype(jnp.float32), W.size
            if isinstance(res.action, Quantize):
                diag_h = diag.get(name)
                if diag_h is None:
                    # explicit weight-variance fallback: identity column
                    # weights make the proxy the plain variance of W
                    why = missed.get(name, "no Hessian tap declared")
                    msg = (f"budget pre-pass: {why} for {name}; scoring "
                           f"by weight variance (identity Hessian)")
                    warn_rows.append(msg)
                    warnings.warn(msg, stacklevel=3)
                    diag_h = jnp.ones((Wq.shape[-1],), jnp.float32)
                entries.append(BudgetEntry(
                    name=name, W=Wq, diag_h=diag_h,
                    base_cfg=res.action.cfg, numel=numel,
                    replicas=replicas))
            else:
                r, c = Wq.shape[-2], Wq.shape[-1]
                fixed_bits += numel * res.action.bpv(r, c)
                fixed_numel += numel
    alloc = allocate_budget(entries, budget_bpv, fixed_bits=fixed_bits,
                            fixed_numel=fixed_numel, scorer=scorer,
                            progress=progress)
    for name, (setting, cfg) in alloc.items():
        old = plan[name]
        plan[name] = Resolved(
            Quantize(cfg, method=old.action.method,
                     solver=getattr(old.action, "solver", "gptq")),
            rule=f"budget[{setting}]<-{old.rule}")
    return plan, warn_rows


def quantize_model(
    model,
    params,
    tokens: jax.Array,       # (n_seq, S) calibration tokens
    method: str = "gptvq",
    cfg: Any = None,         # VQConfig for gptvq; {"bits","group_size"} else
    *,
    recipe: QuantRecipe | None = None,  # declarative per-target rules
    budget_bpv: float | None = None,    # Hessian-budgeted mixed precision
    budget_scorer: str = "closed_form",  # or "refit" (validation oracle)
    hessian_mesh=None,       # jax.sharding.Mesh: data-parallel capture
    hessian_mesh_axis: str = "data",
    pack: bool = False,      # True -> VQLinear leaves (serving format)
    chunk: int = 8,          # calibration sequences per forward chunk
    quantize_attn: bool = True,   # deprecated: use a recipe rule instead
    quantize_mlp: bool = True,    # deprecated: use a recipe rule instead
    seed: int = 0,
    progress: Callable[[str], None] | None = None,
    telemetry=None,               # obs.Telemetry: spans + quant_* events
):
    """Quantize any registered model family. Returns (new_params, report).

    The driver resolves a per-target plan from the recipe (or from the
    legacy kwargs via ``QuantRecipe.from_legacy`` — bitwise-identical
    packed payloads), then runs three passes per block, mediated by the
    family's adapter: (1) Hessian capture from the current calibration
    activations for the taps the plan actually needs, (2) per-target
    application of the resolved action, (3) advancing the activations
    through the quantized block.

    With ``hessian_mesh`` set, Hessian accumulation (the budget pre-pass
    and pass 1) shards calibration rows across the mesh's
    ``hessian_mesh_axis`` devices and merges partials with one psum per
    accumulate call — numerically equivalent to single-device capture.

    With ``telemetry`` set, each stage additionally records a
    ``span.quant/<stage>`` histogram and the event log gains
    ``quant_stage`` (per block) and ``quant_target`` (per target) rows;
    ``report.stage_seconds`` aggregates stage wall time either way.
    """
    t0 = time.time()
    stage_seconds: dict[str, float] = {}

    @contextlib.contextmanager
    def _stage(name: str, block: str | None = None):
        ts = time.perf_counter()
        with contextlib.ExitStack() as cm:
            if telemetry is not None:
                # nested spans -> "span.quant/<stage>" flame-graph paths
                cm.enter_context(telemetry.spans.span("quant"))
                cm.enter_context(telemetry.spans.span(name))
            yield
        dt = time.perf_counter() - ts
        stage_seconds[name] = stage_seconds.get(name, 0.0) + dt
        if telemetry is not None and block is not None:
            telemetry.events.emit("quant_stage", stage=name, block=block,
                                  seconds=dt)

    legacy = recipe is None
    if not legacy and (method != "gptvq" or cfg is not None):
        raise ValueError(
            "pass either a recipe or the legacy (method, cfg) pair — "
            "explicit method/cfg would be silently ignored alongside "
            "recipe=")
    adapter = adapters.get_adapter(model, params)
    if legacy:
        if not (quantize_attn and quantize_mlp):
            warnings.warn(
                "quantize_attn/quantize_mlp are deprecated; pass a "
                "QuantRecipe with keep_dense rules instead",
                DeprecationWarning, stacklevel=2)
        if cfg is None:
            cfg = (VQConfig()
                   if method in ("gptvq", "kmeans", "kmeans_data")
                   else {"bits": 4, "group_size": 128})
        recipe = QuantRecipe.from_legacy(
            method, cfg, quantize_attn=quantize_attn,
            quantize_mlp=quantize_mlp)
    key = jax.random.PRNGKey(seed)

    n_seq = tokens.shape[0]
    chunks = [tokens[i : i + chunk] for i in range(0, n_seq, chunk)]

    blocks = adapter.blocks()
    plan = recipe.resolve(_collect_targets(blocks))
    _check_plan(blocks, plan)
    report_warnings: list[str] = []
    if budget_bpv is not None:
        with _stage("budget_prepass"):
            diag, missed = _budget_prepass(
                adapter, chunks, plan, progress, mesh=hessian_mesh,
                mesh_axis=hessian_mesh_axis)
        with _stage("budget_allocate"):
            plan, report_warnings = _allocate(
                blocks, plan, diag, missed, budget_bpv, progress,
                scorer=budget_scorer)

    states = [adapter.calib_state(c, ci) for ci, c in enumerate(chunks)]
    report_rows = []
    per_target: dict[str, dict] = {}
    for bi, blk in enumerate(blocks):
        prefix = _block_prefix(blk)
        specs = blk.targets()
        resolved = {spec.name: plan[f"{prefix}.{spec.name}"]
                    for spec in specs}
        blk_stage = lambda name: _stage(name, blk.name)  # noqa: B023

        # ---- pass 1: Hessian taps the plan needs --------------------------
        needed = frozenset(
            spec.group for spec in specs
            if resolved[spec.name].needs_hessian and spec.tap is not None)
        taps: dict = {}
        if needed:
            with _stage("hessian_capture", blk.name):
                with contextlib.ExitStack() as cm:
                    if hessian_mesh is not None:
                        cm.enter_context(adapters.hessian_mesh(
                            hessian_mesh, hessian_mesh_axis))
                    for st in states:
                        taps = blk.capture(st, taps, needed)

        # ---- pass 2: apply each target's resolved action ------------------
        new_block = blk.params()
        row = {"layer": bi, "block": blk.name}
        for spec in specs:
            res = resolved[spec.name]
            name = f"{prefix}.{spec.name}"
            W = adapters.tree_get(new_block, spec.path)
            entry = _target_entry(res, spec, W)
            if isinstance(res.action, KeepDense):
                per_target[name] = entry
                continue
            tap = taps.get(spec.tap) if spec.tap is not None else None
            if res.needs_hessian and spec.tap is not None and tap is None:
                # data-aware actions need the tap; a miss is an adapter bug
                # (capture never accumulated what targets() promised)
                raise KeyError(
                    f"block {blk.name!r}: Hessian tap {spec.tap!r} for "
                    f"target {spec.name!r} was never captured")
            t_tgt = time.perf_counter()
            if spec.per_expert:
                key, leaf, err = _quantize_expert_stack(
                    W, tap, res.action, key, pack, res.rule, blk_stage)
            else:
                H = hes.finalize(tap) if tap is not None else None
                key, sub = jax.random.split(key)
                q, packed = _apply_action(W, H, res.action, sub, blk_stage)
                if packed is not None:
                    packed = dataclasses.replace(packed, rule=res.rule)
                leaf = packed if (pack and packed is not None) else q
                err = _recon_error(W, q, H)
            if telemetry is not None:
                telemetry.events.emit(
                    "quant_target", name=name, action=entry["action"],
                    seconds=time.perf_counter() - t_tgt)
            new_block = adapters.tree_set(new_block, spec.path, leaf)
            row[spec.name] = err
            entry["error"] = err
            per_target[name] = entry
        blk.install(new_block)

        # ---- pass 3: advance activations through the quantized block ------
        with _stage("advance", blk.name):
            states = [blk.advance(st) for st in states]
        if progress:
            progress(f"block {bi + 1}/{len(blocks)} [{blk.name}] done")
        report_rows.append(row)

    new_params = adapter.finalize()
    achieved = weighted_bpv(
        (e["numel"], e["bpv"]) for e in per_target.values())
    if legacy and budget_bpv is None:
        # uniform legacy accounting: the nominal per-tensor formula
        bpv = cfg.bits_per_value if isinstance(cfg, VQConfig) else (
            cfg["bits"] + 16.0 / cfg["group_size"])
        label = method
    else:
        bpv = achieved
        label = f"recipe:{recipe.name}" if recipe.name else "recipe"
    return new_params, QuantizeReport(
        report_rows, time.time() - t0, label, bpv,
        per_target=per_target, achieved_bpv=achieved,
        recipe=recipe.to_json(), stage_seconds=stage_seconds,
        warnings=report_warnings)


def _target_entry(res: Resolved, spec, W) -> dict:
    """JSON-able per-target report row (checkpoint metadata payload)."""
    if spec.per_expert:
        r, c = W.shape[2], W.shape[1]
    else:
        r, c = W.shape[-1], W.shape[-2]
    action = res.action
    entry: dict[str, Any] = {
        "rule": res.rule, "numel": int(W.size),
        "bpv": float(action.bpv(r, c)), "group": spec.group,
    }
    if isinstance(action, Quantize):
        entry.update(action="quantize", method=action.method,
                     d=action.cfg.d, bits_per_dim=action.cfg.bits_per_dim,
                     group_size=action.cfg.group_size)
    elif isinstance(action, IntQuant):
        entry.update(action="int_quant", method=action.method,
                     bits=action.bits, group_size=action.group_size)
    else:
        entry.update(action="keep_dense", reason=action.reason)
    return entry
