"""Uniform scalar quantization primitives (RTN baseline + GPTQ building blocks).

Conventions (GPTQ-style):
  * A weight matrix ``W`` has shape ``(r, c)`` = (out_features, in_features).
  * The layer computes ``y = x @ W.T`` for ``x`` of shape ``(..., c)``.
  * The layer Hessian is ``H = X X^T`` over inputs, shape ``(c, c)``.
  * Quantization groups run along the *input* (column) dimension.

All math is float32 on host; these functions are jit-compatible.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class UniformQParams(NamedTuple):
    """Per-group affine quantization parameters.

    ``scale``/``zero`` have shape (r, n_groups); group g covers columns
    [g*group_size, (g+1)*group_size).
    """

    scale: jax.Array
    zero: jax.Array
    bits: int
    group_size: int
    symmetric: bool


def _minmax_scale_zero(w: jax.Array, bits: int, symmetric: bool):
    """Min/max affine params for the last axis of ``w``."""
    qmax = 2**bits - 1
    if symmetric:
        absmax = jnp.max(jnp.abs(w), axis=-1)
        # symmetric signed grid: [-2^{b-1}, 2^{b-1}-1]
        scale = absmax / (2 ** (bits - 1) - 1 + 1e-12)
        scale = jnp.where(scale == 0, 1.0, scale)
        zero = jnp.zeros_like(scale)
        return scale, zero
    lo = jnp.min(w, axis=-1)
    hi = jnp.max(w, axis=-1)
    lo = jnp.minimum(lo, 0.0)
    hi = jnp.maximum(hi, 0.0)
    scale = (hi - lo) / qmax
    scale = jnp.where(scale == 0, 1.0, scale)
    zero = jnp.round(-lo / scale)
    return scale, zero


def compute_qparams(
    W: jax.Array, bits: int, group_size: int = -1, symmetric: bool = False
) -> UniformQParams:
    """Compute per-(row, column-group) affine quantization parameters."""
    r, c = W.shape
    gs = c if group_size in (-1, None) else min(group_size, c)
    while c % gs != 0:  # fall back to the largest divisor <= requested
        gs -= 1
    wg = W.reshape(r, c // gs, gs)
    scale, zero = _minmax_scale_zero(wg, bits, symmetric)
    return UniformQParams(scale, zero, bits, gs, symmetric)


def quantize_column(w: jax.Array, scale: jax.Array, zero: jax.Array, bits: int,
                    symmetric: bool) -> jax.Array:
    """Fake-quantize a column (or any array broadcastable with scale/zero)."""
    if symmetric:
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        q = jnp.clip(jnp.round(w / scale), lo, hi)
        return q * scale
    q = jnp.clip(jnp.round(w / scale) + zero, 0, 2**bits - 1)
    return (q - zero) * scale


def rtn_quantize(
    W: jax.Array, bits: int, group_size: int = -1, symmetric: bool = False
) -> jax.Array:
    """Round-to-nearest baseline: fake-quantized copy of ``W``."""
    r, c = W.shape
    p = compute_qparams(W, bits, group_size, symmetric)
    wg = W.reshape(r, c // p.group_size, p.group_size)
    qg = quantize_column(wg, p.scale[..., None], p.zero[..., None], bits, symmetric)
    return qg.reshape(r, c)


def rtn_int_weights(
    W: jax.Array, bits: int, group_size: int = -1, symmetric: bool = False
):
    """RTN returning integer codes + params (for packing / serving)."""
    r, c = W.shape
    p = compute_qparams(W, bits, group_size, symmetric)
    wg = W.reshape(r, c // p.group_size, p.group_size)
    if symmetric:
        lo, hi = -(2 ** (bits - 1)), 2 ** (bits - 1) - 1
        q = jnp.clip(jnp.round(wg / p.scale[..., None]), lo, hi)
    else:
        q = jnp.clip(jnp.round(wg / p.scale[..., None]) + p.zero[..., None], 0, 2**bits - 1)
    return q.reshape(r, c).astype(jnp.int32), p


def dequantize_int(q: jax.Array, p: UniformQParams):
    r, c = q.shape
    qg = q.reshape(r, c // p.group_size, p.group_size).astype(jnp.float32)
    if p.symmetric:
        return (qg * p.scale[..., None]).reshape(r, c)
    return ((qg - p.zero[..., None]) * p.scale[..., None]).reshape(r, c)


# ---------------------------------------------------------------------------
# GPTQ: column-sequential uniform quantization with Hessian error feedback.
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit, static_argnames=("bits", "group_size", "block_size", "symmetric")
)
def gptq_quantize(
    W: jax.Array,
    U: jax.Array,
    *,
    bits: int,
    group_size: int = 128,
    block_size: int = 128,
    symmetric: bool = False,
) -> jax.Array:
    """GPTQ (Frantar et al. 2022) with the Cholesky formulation.

    Args:
      W: (r, c) weights.
      U: upper-triangular Cholesky factor of ``H^{-1}`` (``H^{-1} = U^T U``),
         from :func:`repro.core.hessian.inv_hessian_cholesky`.
      bits/group_size: quantization grid (group along columns).
      block_size: lazy-update block B; errors inside a block are propagated
        eagerly, the tail update is applied once per block.

    Returns the fake-quantized weight matrix (same shape/dtype as W).
    """
    r, c = W.shape
    gs = c if group_size in (-1, None) else min(group_size, c)
    while c % gs != 0:
        gs -= 1
    B = min(block_size, c, gs if gs >= 16 else c)
    while c % B != 0 or not (gs % B == 0 or B % gs == 0):
        B -= 1
    W = W.astype(jnp.float32)
    U = U.astype(jnp.float32)
    Q = jnp.zeros_like(W)

    n_blocks = c // B

    def block_body(b, carry):
        W, Q = carry
        start = b * B
        Wb = jax.lax.dynamic_slice(W, (0, start), (r, B))
        Ub = jax.lax.dynamic_slice(U, (start, start), (B, B))  # within-block rows

        def col_body(j, inner):
            Wb, Qb, E = inner
            col = start + j
            w = jax.lax.dynamic_slice(Wb, (0, j), (r, 1))[:, 0]
            # group params computed from the *current* (error-compensated)
            # weights at each group boundary, matching the GPTQ reference.
            gstart_in_b = (j // min(gs, B)) * min(gs, B) if gs <= B else 0
            if gs <= B:
                wgrp = jax.lax.dynamic_slice(Wb, (0, gstart_in_b), (r, gs))
            else:
                # group spans multiple blocks: slice from W at the group start
                gcol = (col // gs) * gs
                wgrp = jax.lax.dynamic_slice(W, (0, gcol), (r, gs))
            scale, zero = _minmax_scale_zero(wgrp, bits, symmetric)
            q = quantize_column(w, scale, zero, bits, symmetric)
            d = Ub[j, j]
            err = (w - q) / d
            # propagate into remaining columns of the block
            row = Ub[j]  # (B,)
            mask = (jnp.arange(B) > j).astype(W.dtype)
            Wb = Wb - err[:, None] * (row * mask)[None, :]
            Qb = jax.lax.dynamic_update_slice(Qb, q[:, None], (0, j))
            E = jax.lax.dynamic_update_slice(E, err[:, None], (0, j))
            return Wb, Qb, E

        Qb0 = jnp.zeros((r, B), W.dtype)
        E0 = jnp.zeros((r, B), W.dtype)
        Wb, Qb, E = jax.lax.fori_loop(0, B, col_body, (Wb, Qb0, E0))
        Q = jax.lax.dynamic_update_slice(Q, Qb, (0, start))
        # lazy tail update: W[:, start+B:] -= E @ U[start:start+B, start+B:]
        Urows = jax.lax.dynamic_slice(U, (start, 0), (B, c))
        tail_mask = (jnp.arange(c) >= start + B).astype(W.dtype)
        delta = E @ (Urows * tail_mask[None, :])
        W = W - delta
        # also write back the processed block so group-boundary slices that
        # span blocks see compensated values
        W = jax.lax.dynamic_update_slice(W, Wb, (0, start))
        return W, Q

    W, Q = jax.lax.fori_loop(0, n_blocks, block_body, (W, Q))
    return Q
