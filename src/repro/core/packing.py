"""Bit-packing of VQ index tensors for storage / HBM transfer.

Indices are ``log2(k)``-bit codes; we pack them into uint32 words (TPU has no
uint8 arithmetic advantage, and 32-bit words keep the unpack shift/mask fully
vectorizable on the VPU). Packing is exact for any bit-width that divides 32
(1,2,4,8,16); other widths (e.g. 3/5/6-bit codes) use the smallest container
that divides 32 and we account the true entropy separately in bpv.py —
matching the paper, which also stores ceil(log2 k)-bit indices.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def container_bits(code_bits: int) -> int:
    """Smallest b in {1,2,4,8,16,32} with b >= code_bits."""
    for b in (1, 2, 4, 8, 16, 32):
        if b >= code_bits:
            return b
    raise ValueError(code_bits)


@functools.partial(jax.jit, static_argnames=("code_bits",))
def pack(idx: jax.Array, code_bits: int) -> jax.Array:
    """Pack int32 codes (flat, multiple of per-word lanes) into uint32 words."""
    bits = container_bits(code_bits)
    lanes = 32 // bits
    flat = idx.reshape(-1)
    assert flat.shape[0] % lanes == 0, (flat.shape, lanes)
    w = flat.reshape(-1, lanes).astype(jnp.uint32)
    shifts = (jnp.arange(lanes, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    return jnp.bitwise_or.reduce(w << shifts[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("code_bits", "n"))
def unpack(words: jax.Array, code_bits: int, n: int) -> jax.Array:
    """Unpack uint32 words back into ``n`` int32 codes."""
    bits = container_bits(code_bits)
    lanes = 32 // bits
    mask = jnp.uint32(2**bits - 1)
    shifts = (jnp.arange(lanes, dtype=jnp.uint32) * bits).astype(jnp.uint32)
    codes = (words[:, None] >> shifts[None, :]) & mask
    return codes.reshape(-1)[:n].astype(jnp.int32)
