"""VQ-compressed linear layers: the serving-side representation.

A quantized linear stores, per weight matrix W (r=out, c=in):

  * ``words``      — bit-packed centroid indices (uint32), the HBM payload:
                     ``log2(k)``-bit codes, ``c/d`` codes per row.
  * ``codebooks``  — int8 centroids (n_cg, n_bands, k, d) + per-codebook
                     fp32 scale (n_cg, n_bands). Tiny; lives in VMEM on TPU.
  * ``scale_sint`` — optional 4-bit log-domain blockwise normalization codes
                     (packed as int8 here; 2 codes/byte in the bpv math).

Three execution paths, selected per-engine via ``vq_matmul_impl``:
  * "gather" — ``dequant_tree`` densifies VQLinear leaves per layer-slice
    inside the model forward (portable default; what every caller did
    before the fused path existed).
  * "xla"    — fused-boundary oracle over ``FusedVQLinear`` leaves, two
    M-shaped regimes: decode-shaped calls (M <= 4) reconstruct the dense
    tile from the PRE-FOLDED artifacts and GEMV — the gather path's
    structure minus its per-tick ``cb_scale`` multiply and ``exp2``, so
    it is strictly cheaper; prefill-shaped calls gather the codebook
    d-vectors straight from the packed words (per-call unpack is two
    iota broadcasts and a shift) and contract them with the activation
    spans in one einsum, never materializing the dense weight. Runs
    everywhere; pinned bitwise-close to the Pallas kernel by the
    differential suite.
  * "pallas" — kernels/vq_dequant_matmul.py decodes codes+codebooks inside
    VMEM and feeds the MXU directly; the dense weight never exists in HBM.

FusedVQLinear prep-pass contract (``prepare_fused`` / ``prepare_fused_tree``,
run ONCE at engine load — serve/engine.Engine calls it when
``vq_matmul_impl != "gather"``):
  * ``codebooks_f`` = int8 codebooks x ``cb_scale``, folded to fp32 — the
    per-step codebook-side scale work becomes zero.
  * codes stay PACKED: both fused paths stream only ``words`` (the true
    HBM payload, reported by payload_bytes()) and decode in-flight. An
    earlier prep variant materialized int32 offset codes for the XLA
    path; the 4-byte-per-code index traffic made decode-shaped matmuls
    slower than the gather path it replaced, so the prep artifact is
    gone and the flat (group, band) codebook offsets are rebuilt per
    call from two iota vectors (see ``_flat_codes``).
  * ``scales``     = the blockwise normalization plane
    exp2(a*sint + z) pre-expanded to (r, c / scale_block) fp32 — folding
    into the shared codebooks is impossible (scales vary per row within a
    band), so the plane multiplies the decoded tile instead; scale_block
    != 0 recipes keep the fused path.
  * leading stack dims (MoE (E, ...), scanned layers (L, ...), hybrid
    trunk (n_groups, per, ...)) are preserved verbatim: layer scans slice
    the stacked leaves exactly like dense params, and
    models/common.expert_matmul maps the fused matmul over expert stacks.
  * leaves whose rows are not packed on word boundaries stay VQLinear
    (gather path per-leaf) — the kernel needs row-aligned words.
  * the chosen impl is stamped on each leaf (static metadata), so it is
    baked into any jitted closure that captures the tree; the model
    forwards' ``vq_matmul_impl=`` argument re-stamps at trace time.

Sharding: indices shard along rows together with ``n_bands`` (row bands) and
along columns together with ``n_cg`` (column groups); both group boundaries
are multiples of 128/256 so TP shard edges always align.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.bpv import VQConfig
from repro.core.gptvq import VQResult
from repro.obs import dispatch as obs_dispatch


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class VQLinear:
    """Pytree holding one VQ-compressed weight matrix."""

    words: jax.Array        # (r, c/d*code_bits/32) uint32 packed indices
    codebooks: jax.Array    # (n_cg, n_bands, k, d) int8
    cb_scale: jax.Array     # (n_cg, n_bands) f32
    scale_sint: jax.Array   # (n_cg, r, cg/Ns) int8 (zeros if normalization off)
    scale_a: jax.Array      # (n_cg,) f32
    scale_z: jax.Array      # (n_cg,) f32
    # -- static metadata --
    r: int = dataclasses.field(metadata=dict(static=True), default=0)
    c: int = dataclasses.field(metadata=dict(static=True), default=0)
    d: int = dataclasses.field(metadata=dict(static=True), default=1)
    k: int = dataclasses.field(metadata=dict(static=True), default=2)
    group_cols: int = dataclasses.field(metadata=dict(static=True), default=256)
    rows_per_band: int = dataclasses.field(metadata=dict(static=True), default=1)
    scale_block: int = dataclasses.field(metadata=dict(static=True), default=0)
    # recipe provenance: the rule that produced this leaf ("" when packed
    # outside a recipe run) — lets serve/report reconstruct the mix
    rule: str = dataclasses.field(metadata=dict(static=True), default="")

    @property
    def code_bits(self) -> int:
        return max(1, (self.k - 1).bit_length())

    @property
    def n_cg(self) -> int:
        return self.c // self.group_cols

    @property
    def n_bands(self) -> int:
        return self.r // self.rows_per_band

    def payload_bytes(self) -> int:
        """True HBM footprint of the compressed layer."""
        return (
            self.words.size * 4
            + self.codebooks.size
            + self.cb_scale.size * 4
            + (self.scale_sint.size // 2 if self.scale_block else 0)
            + self.scale_a.size * 4
            + self.scale_z.size * 4
        )


def from_vq_result(res: VQResult) -> VQLinear:
    """Pack a quantizer output into the serving format."""
    cfg = res.cfg
    idx = res.arrays.indices  # (r, c/d)
    code_bits = max(1, (cfg.k - 1).bit_length())
    cbits = packing.container_bits(code_bits)
    lanes = 32 // cbits
    r, nspans = idx.shape
    # pack per row so row-sharding stays trivial
    assert nspans % lanes == 0 or (nspans * r) % lanes == 0
    if nspans % lanes == 0:
        words = jax.vmap(lambda row: packing.pack(row, code_bits))(idx)
    else:
        words = packing.pack(idx.reshape(-1), code_bits).reshape(r, -1)

    C = res.arrays.codebooks
    if res.codebook_scale is not None:
        s = res.codebook_scale
    else:
        qmax = 2 ** (cfg.codebook_bits - 1) - 1
        absmax = jnp.max(jnp.abs(C), axis=(2, 3))
        s = jnp.where(absmax == 0, 1.0, absmax / qmax)
    Cq = jnp.clip(jnp.round(C / s[..., None, None]), -128, 127).astype(jnp.int8)

    return VQLinear(
        words=words,
        codebooks=Cq,
        cb_scale=s.astype(jnp.float32),
        scale_sint=res.arrays.scale_sint.astype(jnp.int8),
        scale_a=res.arrays.scale_a,
        scale_z=res.arrays.scale_z,
        r=res.r,
        c=res.c,
        d=cfg.d,
        k=cfg.k,
        group_cols=res.group_cols,
        rows_per_band=res.rows_per_band,
        scale_block=cfg.scale_block,
    )


def unpack_indices(vql: VQLinear) -> jax.Array:
    """(r, c/d) int32 codes from the packed words (in-graph shifts/masks)."""
    nspans = vql.c // vql.d
    code_bits = vql.code_bits
    cbits = packing.container_bits(code_bits)
    lanes = 32 // cbits
    if nspans % lanes == 0:
        return jax.vmap(lambda row: packing.unpack(row, code_bits, nspans))(
            vql.words
        )
    return packing.unpack(vql.words.reshape(-1), code_bits, vql.r * nspans).reshape(
        vql.r, nspans
    )


def dequantize(vql: VQLinear, dtype=jnp.bfloat16) -> jax.Array:
    """Reconstruct W (r, c) — the XLA (non-fused) path."""
    idx = unpack_indices(vql)
    n_cg, n_bands = vql.n_cg, vql.n_bands
    rg, cg, d = vql.rows_per_band, vql.group_cols, vql.d
    spans_pg = cg // d
    C = vql.codebooks.astype(jnp.float32) * vql.cb_scale[..., None, None]
    idx4 = idx.reshape(n_bands, rg, n_cg, spans_pg)
    g_ix = jnp.arange(n_cg)[None, None, :, None]
    b_ix = jnp.arange(n_bands)[:, None, None, None]
    Wn = C[g_ix, b_ix, idx4].reshape(n_bands, rg, n_cg, cg).reshape(vql.r, vql.c)
    if vql.scale_block:
        s = jnp.exp2(
            vql.scale_a[:, None, None] * vql.scale_sint.astype(jnp.float32)
            + vql.scale_z[:, None, None]
        )
        s = jnp.repeat(s, vql.scale_block, axis=2).transpose(1, 0, 2).reshape(
            vql.r, vql.c
        )
        Wn = Wn * s
    return Wn.astype(dtype)


def apply(vql: VQLinear, x: jax.Array, *, dtype=jnp.bfloat16) -> jax.Array:
    """y = x @ W^T with on-the-fly dequantization (XLA path)."""
    W = dequantize(vql, dtype)
    return x.astype(dtype) @ W.T


def dequant_tree(tree, dtype=jnp.bfloat16, densify_fused=False):
    """Replace any VQLinear leaves with dense (in, out) weight arrays.

    Layout-agnostic across the model zoo: non-matmul leaves (norm scales,
    conv kernels, SSM scan parameters A_log/dt_bias/D_skip, LoRA factors,
    biases) pass through untouched, and VQLinear leaves with leading stack
    dims — MoE expert stacks (E, ...), scanned layer stacks (L, ...), the
    hybrid trunk's (n_groups, per, ...) — vmap the dequantization over
    every leading axis of the packed words.

    FusedVQLinear leaves pass through UNtouched (they are consumed at the
    matmul sites via models/common.matmul) unless ``densify_fused=True`` —
    used by callers that must mutate the dense weight (the hybrid family's
    shared-attention LoRA deltas are added onto the base matrix).

    Called by the model assemblies on each *layer slice* inside their layer
    scan, so only one layer's weights are ever dense at a time; everything
    else streams through HBM bit-packed. No-op for plain parameter trees.
    """
    def f(x):
        if isinstance(x, FusedVQLinear):
            if not densify_fused:
                return x
            deq = lambda v: fused_dequantize(v, dtype).T
            for _ in range(x.words.ndim - 2):
                deq = jax.vmap(deq)
            return deq(x)
        if not isinstance(x, VQLinear):
            return x
        _VQ_IMPL["counts"]["gather"] += 1  # trace-time dispatch pin
        # leading batch dims (expert / layer / group stacks) vmap away
        deq = lambda v: dequantize(v, dtype).T
        for _ in range(x.words.ndim - 2):
            deq = jax.vmap(deq)
        return deq(x)

    return jax.tree.map(f, tree, is_leaf=_is_vq_leaf)


def _is_vq_leaf(x) -> bool:
    return isinstance(x, (VQLinear, FusedVQLinear))


def tree_has_vq(tree) -> bool:
    """True if the tree holds any packed leaves (raw or engine-prepped)."""
    return any(_is_vq_leaf(x) for x in jax.tree.leaves(
        tree, is_leaf=_is_vq_leaf))


# ---------------------------------------------------------------------------
# Fused serving path: engine-load prep pass + per-matmul dispatch
# ---------------------------------------------------------------------------

# Trace-time dispatch counter, same contract as models/attention._PAGED_IMPL:
# counts bump when a path is *traced* into a computation, pinning regressions
# where a requested impl silently falls back. "gather" counts dense
# materializations in dequant_tree; "xla"/"pallas" count fused matmuls.
# Registered in obs.dispatch so snapshot/reset_dispatch_counters cover it.
_VQ_IMPL = {"impl": "gather",
            "counts": obs_dispatch.register_dispatch(
                "vq", ("gather", "xla", "pallas"))}


def set_vq_impl(impl: str) -> None:
    """Set the module-default VQ matmul impl (leaf stamps take precedence)."""
    assert impl in ("gather", "xla", "pallas", "fused"), impl
    _VQ_IMPL["impl"] = impl


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class FusedVQLinear:
    """Engine-prepped VQLinear: all per-step scale/unpack work pre-folded.

    Produced once at engine load by ``prepare_fused`` (see the module
    docstring for the full contract); consumed at the model matmul sites by
    ``fused_matmul`` via models/common.matmul."""

    words: jax.Array        # (..., r, c/d*code_bits/32) uint32 — the payload
    codebooks_f: jax.Array  # (..., n_cg, n_bands, k, d) f32, cb_scale folded
    scales: Any             # (..., r, c/Ns) f32 plane, or None
    # -- static metadata (mirrors VQLinear) --
    r: int = dataclasses.field(metadata=dict(static=True), default=0)
    c: int = dataclasses.field(metadata=dict(static=True), default=0)
    d: int = dataclasses.field(metadata=dict(static=True), default=1)
    k: int = dataclasses.field(metadata=dict(static=True), default=2)
    group_cols: int = dataclasses.field(metadata=dict(static=True), default=256)
    rows_per_band: int = dataclasses.field(metadata=dict(static=True), default=1)
    scale_block: int = dataclasses.field(metadata=dict(static=True), default=0)
    rule: str = dataclasses.field(metadata=dict(static=True), default="")
    impl: str = dataclasses.field(metadata=dict(static=True), default="xla")

    @property
    def code_bits(self) -> int:
        return max(1, (self.k - 1).bit_length())

    def payload_bytes(self) -> int:
        """HBM bytes streamed per decode tick (packed words + folded
        codebooks + scale plane — both fused paths decode in-flight)."""
        return (self.words.size * 4 + self.codebooks_f.size * 4
                + (self.scales.size * 4 if self.scales is not None else 0))


def prepare_fused(vql: VQLinear, impl: str = "xla") -> FusedVQLinear | VQLinear:
    """One-time VQLinear -> FusedVQLinear prep (leading stack dims kept).

    Returns the leaf unchanged when its rows are not packed on uint32 word
    boundaries (the kernel's layout precondition) — that leaf simply stays
    on the gather path."""
    nspans = vql.c // vql.d
    cbits = packing.container_bits(vql.code_bits)
    lanes = 32 // cbits
    if nspans % lanes != 0:
        return vql
    lead = vql.words.shape[:-2]

    codebooks_f = (vql.codebooks.astype(jnp.float32)
                   * vql.cb_scale[..., None, None])

    scales = None
    if vql.scale_block:
        s = jnp.exp2(
            vql.scale_a[..., :, None, None]
            * vql.scale_sint.astype(jnp.float32)
            + vql.scale_z[..., :, None, None]
        )  # (..., n_cg, r, cg/Ns)
        scales = jnp.swapaxes(s, -3, -2).reshape(
            *lead, vql.r, vql.c // vql.scale_block)

    return FusedVQLinear(
        words=vql.words, codebooks_f=codebooks_f, scales=scales,
        r=vql.r, c=vql.c, d=vql.d, k=vql.k, group_cols=vql.group_cols,
        rows_per_band=vql.rows_per_band, scale_block=vql.scale_block,
        rule=vql.rule, impl=impl)


def prepare_fused_tree(tree, impl: str = "xla"):
    """Engine-load prep pass: VQLinear leaves -> FusedVQLinear (in place of
    the tree; dense leaves untouched)."""
    def f(x):
        if isinstance(x, VQLinear):
            return prepare_fused(x, impl)
        return x

    return jax.tree.map(f, tree, is_leaf=_is_vq_leaf)


def retag_fused(tree, impl: str):
    """Re-stamp the impl on every FusedVQLinear leaf (trace-time only — the
    stamp is static metadata, no device work)."""
    def f(x):
        if isinstance(x, FusedVQLinear) and x.impl != impl:
            return dataclasses.replace(x, impl=impl)
        return x

    return jax.tree.map(f, tree, is_leaf=_is_vq_leaf)


def _flat_codes(fvl: FusedVQLinear) -> jax.Array:
    """(r, c/d) int32 codes with the flat (group, band) codebook offset
    added — rebuilt per call from the packed ``words``. The unpack is a
    broadcast shift/mask and the offsets are two iota vectors, so the
    per-call index traffic stays at the packed-words footprint (a
    materialized int32 code plane costs 4 bytes per code and made
    decode-shaped XLA matmuls slower than the gather path)."""
    nspans = fvl.c // fvl.d
    cbits = packing.container_bits(fvl.code_bits)
    lanes = 32 // cbits
    mask = jnp.uint32(2**cbits - 1)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * cbits
    codes = ((fvl.words[..., None] >> shifts) & mask).reshape(
        fvl.r, nspans).astype(jnp.int32)
    spans_pg = fvl.group_cols // fvl.d
    n_bands = fvl.r // fvl.rows_per_band
    g = jnp.arange(nspans, dtype=jnp.int32) // spans_pg
    b = jnp.arange(fvl.r, dtype=jnp.int32) // fvl.rows_per_band
    return codes + (g[None, :] * n_bands + b[:, None]) * fvl.k


def _reconstruct(fvl: FusedVQLinear) -> jax.Array:
    """Dense f32 W (r, c) from the pre-folded artifacts.

    Mirrors ``dequantize``'s 4-D advanced-index gather (XLA lowers the
    small per-(group, band) codebook lookup measurably better than a flat
    ``take`` over concatenated codebooks) but reads ``codebooks_f`` and
    the pre-expanded ``scales`` plane, so the per-tick ``cb_scale``
    multiply and ``exp2`` of the gather path are gone — this is the
    gather path minus the folding work, which is why the decode-shaped
    fused matmul uses it."""
    nspans = fvl.c // fvl.d
    cbits = packing.container_bits(fvl.code_bits)
    lanes = 32 // cbits
    mask = jnp.uint32(2**cbits - 1)
    shifts = jnp.arange(lanes, dtype=jnp.uint32) * cbits
    idx = ((fvl.words[..., None] >> shifts) & mask).reshape(
        fvl.r, nspans).astype(jnp.int32)
    n_bands = fvl.r // fvl.rows_per_band
    n_cg = fvl.c // fvl.group_cols
    rg, spans_pg = fvl.rows_per_band, fvl.group_cols // fvl.d
    idx4 = idx.reshape(n_bands, rg, n_cg, spans_pg)
    g_ix = jnp.arange(n_cg)[None, None, :, None]
    b_ix = jnp.arange(n_bands)[:, None, None, None]
    W = fvl.codebooks_f[g_ix, b_ix, idx4].reshape(
        n_bands, rg, n_cg, fvl.group_cols).reshape(fvl.r, fvl.c)
    if fvl.scales is not None:
        W = (W.reshape(fvl.r, -1, fvl.scale_block)
             * fvl.scales[:, :, None]).reshape(fvl.r, fvl.c)
    return W


def fused_dequantize(fvl: FusedVQLinear, dtype=jnp.bfloat16) -> jax.Array:
    """Dense W (r, c) from a prepped leaf (hybrid LoRA densify + tests)."""
    return _reconstruct(fvl).astype(dtype)


def fused_matmul(x: jax.Array, fvl: FusedVQLinear, *, impl: str | None = None,
                 interpret: bool | None = None, tile_m: int = 128,
                 tile_n: int = 128, tile_k: int = 256) -> jax.Array:
    """y = x @ W_io where W_io is the (in, out) dense view of ``fvl``.

    x may carry any leading dims (``(B, S, K)`` decode shapes flatten to a
    single M). Dispatch: explicit ``impl`` > leaf stamp > module default;
    "fused" resolves to "pallas" on TPU, "xla" elsewhere."""
    assert fvl.words.ndim == 2, (
        "stacked FusedVQLinear must go through models/common.expert_matmul "
        "or a layer scan slice")
    impl = impl or fvl.impl or _VQ_IMPL["impl"]
    if impl == "fused":
        impl = "pallas" if jax.default_backend() == "tpu" else "xla"
    assert impl in ("gather", "xla", "pallas"), impl
    _VQ_IMPL["counts"][impl] += 1

    lead, K = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, K)
    if impl == "pallas":
        from repro.kernels.vq_dequant_matmul import vq_dequant_matmul

        if interpret is None:
            interpret = jax.default_backend() != "tpu"
        y = vq_dequant_matmul(
            x2, fvl.words, fvl.codebooks_f, fvl.scales,
            d=fvl.d, k_c=fvl.k, code_bits=fvl.code_bits,
            container_bits=packing.container_bits(fvl.code_bits),
            rows_per_band=fvl.rows_per_band, group_cols=fvl.group_cols,
            scale_block=fvl.scale_block, tile_m=tile_m, tile_n=tile_n,
            tile_k=tile_k, interpret=interpret)
    else:
        # "xla" (and the "gather" stamp, which at a fused leaf means the
        # same fused contraction), two M-shaped regimes measured on the
        # bench host:
        #   decode-shaped (M <= 4): reconstruct the dense tile from the
        #     PRE-FOLDED artifacts and GEMV. Same structure as the gather
        #     path minus its per-tick cb_scale multiply and exp2, so it
        #     wins ~1.1-1.3x at every layer shape; every span-contraction
        #     formulation tried here lost to the plain GEMV at M=1.
        #   prefill-shaped (M > 4): gather codebook d-vectors straight
        #     from the packed words and contract them with the activation
        #     spans (dense W never materialized) — 1.9-2.6x over gather
        #     at M=8.
        M = x2.shape[0]
        Ns, d_, c_ = fvl.scale_block, fvl.d, fvl.c
        if M <= 4 or (fvl.scales is not None and Ns % d_ != 0):
            # (the Ns % d != 0 case — spans straddling scale blocks —
            # also lands here at any M: the span contraction can't apply
            # a sub-span scale)
            y = jax.lax.dot_general(
                x2.astype(jnp.float32), _reconstruct(fvl),
                dimension_numbers=(((1,), (1,)), ((), ())),
                preferred_element_type=jnp.float32)
        else:
            cb_flat = fvl.codebooks_f.reshape(-1, d_)
            g = jnp.take(cb_flat, _flat_codes(fvl), axis=0)  # (r, c/d, d)
            P = x2.astype(jnp.float32).reshape(M, c_ // d_, d_)
            if fvl.scales is None:
                y = jnp.einsum("nsd,msd->mn", g, P)
            else:
                gb = g.reshape(fvl.r, c_ // Ns, Ns // d_, d_)
                Pb = P.reshape(M, c_ // Ns, Ns // d_, d_)
                y = jnp.einsum("nbsd,mbsd->mn",
                               gb * fvl.scales[:, :, None, None], Pb)
    return y.reshape(*lead, fvl.r)


def quantize_array(
    W: jax.Array, H: jax.Array | None, cfg: VQConfig, key=None
) -> VQLinear:
    """Convenience: full GPTVQ pipeline on one matrix -> serving format."""
    from repro.core import hessian as hes
    from repro.core.codebook_compress import codebook_update, quantize_codebooks
    from repro.core.gptvq import gptvq_quantize_matrix

    if H is None:
        H = jnp.eye(W.shape[1], dtype=jnp.float32)
    U = hes.inv_hessian_cholesky(H, cfg.percdamp)
    res = gptvq_quantize_matrix(W, U, cfg, key)
    res = codebook_update(res, W, H)
    res = quantize_codebooks(res)
    return from_vq_result(res)
