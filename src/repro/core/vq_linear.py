"""VQ-compressed linear layers: the serving-side representation.

A quantized linear stores, per weight matrix W (r=out, c=in):

  * ``words``      — bit-packed centroid indices (uint32), the HBM payload:
                     ``log2(k)``-bit codes, ``c/d`` codes per row.
  * ``codebooks``  — int8 centroids (n_cg, n_bands, k, d) + per-codebook
                     fp32 scale (n_cg, n_bands). Tiny; lives in VMEM on TPU.
  * ``scale_sint`` — optional 4-bit log-domain blockwise normalization codes
                     (packed as int8 here; 2 codes/byte in the bpv math).

Two execution paths:
  * XLA path (``dequantize`` + matmul): portable, used by the multi-pod
    dry-run. XLA materializes the dequantized tile; the fused Pallas kernel
    (kernels/vq_dequant_matmul.py) avoids that HBM round-trip on real TPUs.
  * Pallas path: fused unpack+lookup+scale+matmul per VMEM tile.

Sharding: indices shard along rows together with ``n_bands`` (row bands) and
along columns together with ``n_cg`` (column groups); both group boundaries
are multiples of 128/256 so TP shard edges always align.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import packing
from repro.core.bpv import VQConfig
from repro.core.gptvq import VQResult


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class VQLinear:
    """Pytree holding one VQ-compressed weight matrix."""

    words: jax.Array        # (r, c/d*code_bits/32) uint32 packed indices
    codebooks: jax.Array    # (n_cg, n_bands, k, d) int8
    cb_scale: jax.Array     # (n_cg, n_bands) f32
    scale_sint: jax.Array   # (n_cg, r, cg/Ns) int8 (zeros if normalization off)
    scale_a: jax.Array      # (n_cg,) f32
    scale_z: jax.Array      # (n_cg,) f32
    # -- static metadata --
    r: int = dataclasses.field(metadata=dict(static=True), default=0)
    c: int = dataclasses.field(metadata=dict(static=True), default=0)
    d: int = dataclasses.field(metadata=dict(static=True), default=1)
    k: int = dataclasses.field(metadata=dict(static=True), default=2)
    group_cols: int = dataclasses.field(metadata=dict(static=True), default=256)
    rows_per_band: int = dataclasses.field(metadata=dict(static=True), default=1)
    scale_block: int = dataclasses.field(metadata=dict(static=True), default=0)
    # recipe provenance: the rule that produced this leaf ("" when packed
    # outside a recipe run) — lets serve/report reconstruct the mix
    rule: str = dataclasses.field(metadata=dict(static=True), default="")

    @property
    def code_bits(self) -> int:
        return max(1, (self.k - 1).bit_length())

    @property
    def n_cg(self) -> int:
        return self.c // self.group_cols

    @property
    def n_bands(self) -> int:
        return self.r // self.rows_per_band

    def payload_bytes(self) -> int:
        """True HBM footprint of the compressed layer."""
        return (
            self.words.size * 4
            + self.codebooks.size
            + self.cb_scale.size * 4
            + (self.scale_sint.size // 2 if self.scale_block else 0)
            + self.scale_a.size * 4
            + self.scale_z.size * 4
        )


def from_vq_result(res: VQResult) -> VQLinear:
    """Pack a quantizer output into the serving format."""
    cfg = res.cfg
    idx = res.arrays.indices  # (r, c/d)
    code_bits = max(1, (cfg.k - 1).bit_length())
    cbits = packing.container_bits(code_bits)
    lanes = 32 // cbits
    r, nspans = idx.shape
    # pack per row so row-sharding stays trivial
    assert nspans % lanes == 0 or (nspans * r) % lanes == 0
    if nspans % lanes == 0:
        words = jax.vmap(lambda row: packing.pack(row, code_bits))(idx)
    else:
        words = packing.pack(idx.reshape(-1), code_bits).reshape(r, -1)

    C = res.arrays.codebooks
    if res.codebook_scale is not None:
        s = res.codebook_scale
    else:
        qmax = 2 ** (cfg.codebook_bits - 1) - 1
        absmax = jnp.max(jnp.abs(C), axis=(2, 3))
        s = jnp.where(absmax == 0, 1.0, absmax / qmax)
    Cq = jnp.clip(jnp.round(C / s[..., None, None]), -128, 127).astype(jnp.int8)

    return VQLinear(
        words=words,
        codebooks=Cq,
        cb_scale=s.astype(jnp.float32),
        scale_sint=res.arrays.scale_sint.astype(jnp.int8),
        scale_a=res.arrays.scale_a,
        scale_z=res.arrays.scale_z,
        r=res.r,
        c=res.c,
        d=cfg.d,
        k=cfg.k,
        group_cols=res.group_cols,
        rows_per_band=res.rows_per_band,
        scale_block=cfg.scale_block,
    )


def unpack_indices(vql: VQLinear) -> jax.Array:
    """(r, c/d) int32 codes from the packed words (in-graph shifts/masks)."""
    nspans = vql.c // vql.d
    code_bits = vql.code_bits
    cbits = packing.container_bits(code_bits)
    lanes = 32 // cbits
    if nspans % lanes == 0:
        return jax.vmap(lambda row: packing.unpack(row, code_bits, nspans))(
            vql.words
        )
    return packing.unpack(vql.words.reshape(-1), code_bits, vql.r * nspans).reshape(
        vql.r, nspans
    )


def dequantize(vql: VQLinear, dtype=jnp.bfloat16) -> jax.Array:
    """Reconstruct W (r, c) — the XLA (non-fused) path."""
    idx = unpack_indices(vql)
    n_cg, n_bands = vql.n_cg, vql.n_bands
    rg, cg, d = vql.rows_per_band, vql.group_cols, vql.d
    spans_pg = cg // d
    C = vql.codebooks.astype(jnp.float32) * vql.cb_scale[..., None, None]
    idx4 = idx.reshape(n_bands, rg, n_cg, spans_pg)
    g_ix = jnp.arange(n_cg)[None, None, :, None]
    b_ix = jnp.arange(n_bands)[:, None, None, None]
    Wn = C[g_ix, b_ix, idx4].reshape(n_bands, rg, n_cg, cg).reshape(vql.r, vql.c)
    if vql.scale_block:
        s = jnp.exp2(
            vql.scale_a[:, None, None] * vql.scale_sint.astype(jnp.float32)
            + vql.scale_z[:, None, None]
        )
        s = jnp.repeat(s, vql.scale_block, axis=2).transpose(1, 0, 2).reshape(
            vql.r, vql.c
        )
        Wn = Wn * s
    return Wn.astype(dtype)


def apply(vql: VQLinear, x: jax.Array, *, dtype=jnp.bfloat16) -> jax.Array:
    """y = x @ W^T with on-the-fly dequantization (XLA path)."""
    W = dequantize(vql, dtype)
    return x.astype(dtype) @ W.T


def dequant_tree(tree, dtype=jnp.bfloat16):
    """Replace any VQLinear leaves with dense (in, out) weight arrays.

    Layout-agnostic across the model zoo: non-matmul leaves (norm scales,
    conv kernels, SSM scan parameters A_log/dt_bias/D_skip, LoRA factors,
    biases) pass through untouched, and VQLinear leaves with leading stack
    dims — MoE expert stacks (E, ...), scanned layer stacks (L, ...), the
    hybrid trunk's (n_groups, per, ...) — vmap the dequantization over
    every leading axis of the packed words.

    Called by the model assemblies on each *layer slice* inside their layer
    scan, so only one layer's weights are ever dense at a time; everything
    else streams through HBM bit-packed. No-op for plain parameter trees.
    """
    def f(x):
        if not isinstance(x, VQLinear):
            return x
        # leading batch dims (expert / layer / group stacks) vmap away
        deq = lambda v: dequantize(v, dtype).T
        for _ in range(x.words.ndim - 2):
            deq = jax.vmap(deq)
        return deq(x)

    return jax.tree.map(f, tree, is_leaf=lambda x: isinstance(x, VQLinear))


def tree_has_vq(tree) -> bool:
    return any(isinstance(x, VQLinear) for x in jax.tree.leaves(
        tree, is_leaf=lambda x: isinstance(x, VQLinear)))


def quantize_array(
    W: jax.Array, H: jax.Array | None, cfg: VQConfig, key=None
) -> VQLinear:
    """Convenience: full GPTVQ pipeline on one matrix -> serving format."""
    from repro.core import hessian as hes
    from repro.core.codebook_compress import codebook_update, quantize_codebooks
    from repro.core.gptvq import gptvq_quantize_matrix

    if H is None:
        H = jnp.eye(W.shape[1], dtype=jnp.float32)
    U = hes.inv_hessian_cholesky(H, cfg.percdamp)
    res = gptvq_quantize_matrix(W, U, cfg, key)
    res = codebook_update(res, W, H)
    res = quantize_codebooks(res)
    return from_vq_result(res)
