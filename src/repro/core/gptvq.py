"""GPTVQ Algorithm 1: Hessian-compensated vector quantization of a matrix.

Generalizes GPTQ's column-sequential sweep to d-dimensional VQ:

  * columns are processed left to right in spans of ``d``;
  * every ``group_cols`` columns a new *weight group* starts: blockwise
    normalization scales are computed and per-row-band codebooks are
    initialized with Hessian-weighted EM (codebook.py) from the *current*
    (error-compensated) weights — Algorithm 1 lines 9-11;
  * each d-span of each row is assigned to its band codebook with the
    Hessian-weighted distance (Eq. 4);
  * the quantization error is propagated into the not-yet-quantized columns
    through the upper Cholesky factor U of H^{-1}.

Joint d-column compensation (DESIGN.md §6.2)
-------------------------------------------
For a span P of d columns quantized jointly with raw error E = W_P - Q_P,
the optimal update to the remaining columns R is

    delta_R = - E (H~^{-1}_PP)^{-1} H~^{-1}_{P,R}
            = - (E U_PP^{-1}) U[P, R]

where H~ is the Hessian conditioned on all already-quantized columns and
U_PP = U[P, P].  We therefore scale the raw error by U_PP^{-1} once
(``exact_span_solve=True``; a triangular d x d solve) and reuse GPTQ's
row-broadcast update.  With ``exact_span_solve=False`` the paper's literal
per-column reading E_p / U[p,p] is used (identical for d=1).
"""
from __future__ import annotations

import contextlib
import dataclasses
import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import codebook as cb
from repro.core import normalization as norm
from repro.core.bpv import VQConfig
from repro.core.hessian import cholesky_diag_weights
from repro.core.solvers import (
    VALID_SOLVERS, assign_babai, cd_refine, span_metric,
)


class VQArrays(NamedTuple):
    """Jit-friendly array outputs of the sweep (static layout in VQResult)."""

    Q: jax.Array          # (r, c) fake-quantized weights (float codebooks)
    indices: jax.Array    # (r, c // d) int32 centroid ids
    codebooks: jax.Array  # (n_cg, n_bands, k, d) float32, normalized space
    scale_sint: jax.Array # (n_cg, r, cg // Ns) int32 log-domain scale codes
    scale_a: jax.Array    # (n_cg,) log-grid step per group
    scale_z: jax.Array    # (n_cg,) log offset per group


@dataclasses.dataclass
class VQResult:
    """GPTVQ output for one weight matrix."""

    arrays: VQArrays
    cfg: VQConfig
    r: int
    c: int
    group_cols: int   # cg actually used (divides c)
    rows_per_band: int
    # post-processing state (filled by codebook_compress)
    codebook_scale: jax.Array | None = None  # (n_cg, n_bands) int8 cb scales

    @property
    def n_col_groups(self) -> int:
        return self.c // self.group_cols

    @property
    def n_bands(self) -> int:
        return self.r // self.rows_per_band

    @property
    def scale_block(self) -> int:
        return self.cfg.scale_block if self.cfg.scale_block > 0 else self.group_cols

    def expanded_scales(self) -> jax.Array:
        """Per-element normalization scales, (r, c)."""
        a = self.arrays
        if self.cfg.scale_block <= 0:
            return jnp.ones((self.r, self.c), jnp.float32)
        s = jnp.exp2(
            a.scale_a[:, None, None] * a.scale_sint.astype(jnp.float32)
            + a.scale_z[:, None, None]
        )  # (n_cg, r, cg//Ns)
        s = jnp.repeat(s, self.scale_block, axis=2)  # (n_cg, r, cg)
        return s.transpose(1, 0, 2).reshape(self.r, self.c)

    def reconstruct(self, codebooks: jax.Array | None = None) -> jax.Array:
        """Differentiable dequantization Q = S * codebooks[indices]."""
        C = self.arrays.codebooks if codebooks is None else codebooks
        Qn = gather_codebooks(
            C, self.arrays.indices, self.group_cols, self.rows_per_band,
            self.cfg.d,
        )
        return Qn * self.expanded_scales()


def gather_codebooks(
    codebooks: jax.Array, indices: jax.Array, group_cols: int,
    rows_per_band: int, d: int,
) -> jax.Array:
    """Reconstruct normalized weights from (n_cg, n_bands, k, d) codebooks."""
    n_cg, n_bands, k, _ = codebooks.shape
    r, nspans = indices.shape
    rg = rows_per_band
    spans_pg = group_cols // d
    idx4 = indices.reshape(n_bands, rg, n_cg, spans_pg)
    g_ix = jnp.arange(n_cg)[None, None, :, None]
    b_ix = jnp.arange(n_bands)[:, None, None, None]
    Qn = codebooks[g_ix, b_ix, idx4]  # (n_bands, rg, n_cg, spans_pg, d)
    return Qn.reshape(n_bands, rg, n_cg, group_cols).reshape(r, n_cg * group_cols)


def _pick_divisor(n: int, target: int, multiple_of: int = 1) -> int:
    """Largest divisor of n that is <= target and a multiple of
    ``multiple_of`` (falls back to multiple_of itself)."""
    best = multiple_of
    for cand in range(multiple_of, min(n, target) + 1, multiple_of):
        if n % cand == 0:
            best = cand
    return best


def plan_groups(r: int, c: int, cfg: VQConfig) -> tuple[int, int]:
    """Resolve (group_cols, rows_per_band) for a (r, c) matrix.

    A group holds cfg.group_size weights spanning at most cfg.group_cols
    columns (paper §4.1: 'each weight group spans (at most) 256 columns,
    e.g. a group of 1024 weights is 4 rows x 256 columns')."""
    cg = _pick_divisor(c, min(cfg.group_cols, cfg.group_size),
                       multiple_of=cfg.d)
    assert c % cg == 0 and cg % cfg.d == 0, (c, cg, cfg.d)
    rg_target = max(1, cfg.group_size // cg)
    rg = _pick_divisor(r, rg_target)
    return cg, rg


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "group_cols", "rows_per_band"),
)
def _group_init(
    Wg: jax.Array,
    wgt_g: jax.Array,
    keys_g: jax.Array,
    *,
    cfg: VQConfig,
    group_cols: int,
    rows_per_band: int,
):
    """Group-entry work (Algorithm 1 lines 9-11): blockwise normalization
    scales + per-row-band Hessian-weighted EM codebook init from the
    current (error-compensated) weights. Jitted separately from the span
    sweep so the ``em_init`` stage can be timed honestly."""
    r = Wg.shape[0]
    d, k = cfg.d, cfg.k
    cg, rg = group_cols, rows_per_band
    n_bands = r // rg
    spans_pg = cg // d
    Ns = cfg.scale_block if cfg.scale_block > 0 else cg

    Wg = Wg.astype(jnp.float32)
    if cfg.scale_block > 0:
        bs = norm.compute_block_scales(Wg, block=Ns, bits=cfg.scale_bits)
        Sg = bs.expand(cg)  # (r, cg)
        sint_g, a_g, z_g = bs.s_int, bs.a, bs.z
    else:
        Sg = jnp.ones((r, cg), jnp.float32)
        sint_g = jnp.zeros((r, cg // Ns), jnp.int32)
        a_g = jnp.zeros((), jnp.float32)
        z_g = jnp.zeros((), jnp.float32)

    Wn = Wg / Sg
    Xb = Wn.reshape(n_bands, rg, spans_pg, d).reshape(n_bands, rg * spans_pg, d)
    Hw1 = jnp.tile(wgt_g.reshape(1, spans_pg, d), (rg, 1, 1)).reshape(
        rg * spans_pg, d
    )

    def init_one(Xband, key_b):
        return cb.init_codebook(
            Xband, Hw1, k=k, iters=cfg.em_iters, method=cfg.em_seed,
            key=key_b,
        )

    Cg = jax.vmap(init_one)(Xb, keys_g)  # (n_bands, k, d)
    return Sg, sint_g, a_g, z_g, Cg


@functools.partial(
    jax.jit,
    static_argnames=("cfg", "group_cols", "rows_per_band", "solver"),
)
def _group_sweep(
    W: jax.Array,
    U: jax.Array,
    Sg: jax.Array,
    Cg: jax.Array,
    wgt_g: jax.Array,
    gstart: jax.Array,
    *,
    cfg: VQConfig,
    group_cols: int,
    rows_per_band: int,
    solver: str = "gptq",
):
    """d-span sweep of one column group with error feedback through U,
    plus the lazy tail update beyond the group. ``gstart`` is traced so
    all groups share one compilation. Returns (W', Qg, idxg)."""
    r, c = W.shape
    d = cfg.d
    cg, rg = group_cols, rows_per_band
    n_bands = r // rg
    spans_pg = cg // d

    W = W.astype(jnp.float32)
    U = U.astype(jnp.float32)
    Wg = jax.lax.dynamic_slice(W, (0, gstart), (r, cg))

    def span_body(j, inner):
        Wg, Qg, idxg, Eg = inner
        col = j * d
        x = jax.lax.dynamic_slice(Wg, (0, col), (r, d))
        S_span = jax.lax.dynamic_slice(Sg, (0, col), (r, d))
        xn = x / S_span
        wgt_span = jax.lax.dynamic_slice(wgt_g, (col,), (d,))
        U_PP = jax.lax.dynamic_slice(U, (gstart + col, gstart + col), (d, d))

        xb = xn.reshape(n_bands, rg, d)
        if solver == "babai" and d > 1:
            # nearest-plane: full conditional span metric, not just its
            # diagonal (solvers.span_metric docstring; identical at d=1)
            M = span_metric(U_PP)
            ab = assign_babai(xb, S_span.reshape(n_bands, rg, d), M, Cg)
        else:
            Hw = jnp.tile(wgt_span[None], (rg, 1))

            def assign_band(Xband, Cband):
                return cb.assign(Xband, Hw, Cband)

            ab = jax.vmap(assign_band)(xb, Cg)  # (n_bands, rg)
        # gather centroids: Cg (n_bands, k, d), ab (n_bands, rg)
        qn = jax.vmap(lambda Cb, ib: Cb[ib])(Cg, ab)  # (n_bands, rg, d)
        q = (qn.reshape(r, d)) * S_span

        E_raw = x - q
        if cfg.exact_span_solve and d > 1:
            # Etilde = E_raw @ U_PP^{-1}
            Et = jax.scipy.linalg.solve_triangular(
                U_PP.T, E_raw.T, lower=True
            ).T
        else:
            Et = E_raw / jnp.diagonal(U_PP)[None, :]

        # update remaining columns within this group
        Urow = jax.lax.dynamic_slice(U, (gstart + col, gstart), (d, cg))
        mask = (jnp.arange(cg) >= col + d).astype(jnp.float32)
        Wg = Wg - Et @ (Urow * mask[None, :])

        Qg = jax.lax.dynamic_update_slice(Qg, q, (0, col))
        idxg = jax.lax.dynamic_update_slice(
            idxg, ab.reshape(r, 1).astype(jnp.int32), (0, j)
        )
        Eg = jax.lax.dynamic_update_slice(Eg, Et, (0, col))
        return Wg, Qg, idxg, Eg

    Qg0 = jnp.zeros((r, cg), jnp.float32)
    idxg0 = jnp.zeros((r, spans_pg), jnp.int32)
    Eg0 = jnp.zeros((r, cg), jnp.float32)
    Wg, Qg, idxg, Eg = jax.lax.fori_loop(
        0, spans_pg, span_body, (Wg, Qg0, idxg0, Eg0)
    )

    # ---- lazy tail update beyond the group -------------------------------
    Urows = jax.lax.dynamic_slice(U, (gstart, 0), (cg, c))
    tail_mask = (jnp.arange(c) >= gstart + cg).astype(jnp.float32)
    W = W - Eg @ (Urows * tail_mask[None, :])
    W = jax.lax.dynamic_update_slice(W, Wg, (0, gstart))
    return W, Qg, idxg


@contextlib.contextmanager
def _null_stage(name):
    yield


def gptvq_quantize_matrix(
    W: jax.Array,
    U: jax.Array,
    cfg: VQConfig,
    key: jax.Array | None = None,
    *,
    solver: str = "gptq",
    H: jax.Array | None = None,
    stage=None,
) -> VQResult:
    """Run Algorithm 1 on one weight matrix. ``U`` from inv_hessian_cholesky.

    ``solver`` picks the inner assignment rule (solvers.VALID_SOLVERS):
    "gptq" is the paper's diagonal-metric sweep, "babai" the full-metric
    nearest-plane variant, "cd" adds a coordinate-descent refinement pass
    (requires ``H``). ``stage`` is an optional 1-arg context-manager
    factory (the pipeline's stage timer); when provided, device syncs are
    inserted so ``em_init`` / ``column_sweep`` / ``cd_refine`` wall times
    are attributed honestly — untimed callers stay fully async.
    """
    if solver not in VALID_SOLVERS:
        raise ValueError(f"unknown solver {solver!r}; expected one of "
                         f"{VALID_SOLVERS}")
    if solver == "cd" and H is None:
        raise ValueError("solver='cd' needs the Hessian H for its "
                         "coordinate-descent objective")
    r, c = W.shape
    cg, rg = plan_groups(r, c, cfg)
    if key is None:
        key = jax.random.PRNGKey(0)
    n_cg = c // cg
    n_bands = r // rg
    timed = stage is not None
    stage = stage if stage is not None else _null_stage

    group_keys = jax.random.split(key, n_cg * n_bands).reshape(n_cg, n_bands, 2)
    Wcur = W.astype(jnp.float32)
    U = U.astype(jnp.float32)
    wgt_all = cholesky_diag_weights(U)  # (c,), 1/U_qq^2

    Qs, idxs, cbs, sints, a_list, z_list = [], [], [], [], [], []
    for g in range(n_cg):
        gstart = g * cg
        Wg = Wcur[:, gstart:gstart + cg]
        wgt_g = wgt_all[gstart:gstart + cg]
        with stage("em_init"):
            Sg, sint_g, a_g, z_g, Cg = _group_init(
                Wg, wgt_g, group_keys[g], cfg=cfg, group_cols=cg,
                rows_per_band=rg,
            )
            if timed:
                jax.block_until_ready(Cg)
        with stage("column_sweep"):
            Wcur, Qg, idxg = _group_sweep(
                Wcur, U, Sg, Cg, wgt_g, jnp.int32(gstart), cfg=cfg,
                group_cols=cg, rows_per_band=rg, solver=solver,
            )
            if timed:
                jax.block_until_ready(Wcur)
        Qs.append(Qg)
        idxs.append(idxg)
        cbs.append(Cg)
        sints.append(sint_g)
        a_list.append(a_g)
        z_list.append(z_g)

    arrays = VQArrays(
        Q=jnp.concatenate(Qs, axis=1),
        indices=jnp.concatenate(idxs, axis=1),
        codebooks=jnp.stack(cbs, axis=0),
        scale_sint=jnp.stack(sints, axis=0),
        scale_a=jnp.stack(a_list, axis=0).reshape(n_cg),
        scale_z=jnp.stack(z_list, axis=0).reshape(n_cg),
    )
    res = VQResult(arrays=arrays, cfg=cfg, r=r, c=c, group_cols=cg,
                   rows_per_band=rg)
    if solver == "cd" and cfg.cd_passes > 0:
        with stage("cd_refine"):
            Q, idx, _changed = cd_refine(
                W.astype(jnp.float32), arrays.Q, arrays.indices,
                arrays.codebooks, res.expanded_scales(), H, cfg=cfg,
                group_cols=cg, rows_per_band=rg, passes=cfg.cd_passes,
            )
            if timed:
                jax.block_until_ready(Q)
        res = dataclasses.replace(
            res, arrays=arrays._replace(Q=Q, indices=idx)
        )
    return res


def layer_error(W: jax.Array, Q: jax.Array, H: jax.Array) -> jax.Array:
    """Hessian-weighted output reconstruction error tr(E H E^T) (Eq. 1)."""
    E = (W - Q).astype(jnp.float32)
    return jnp.sum(E * (E @ H))
