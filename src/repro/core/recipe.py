"""QuantRecipe: declarative per-target quantization rules.

The pipeline's configuration surface. A recipe is an ordered list of
``Rule(pattern, action)`` entries matched against the canonical target
names the family adapters emit (``<block_prefix>.<leaf>``, e.g.
``layers.3.attn.wq``, ``shared.attn.wo``, ``mamba.0.1.mixer.in_proj``,
``layers.5.core.r_z``). Patterns are shell globs (``fnmatch``) plus the
special forms ``group:attn`` / ``group:mlp`` that match a target's
``WeightSpec.group``. **First match wins.** Targets matched by no rule
fall back to (in order) the adapter-declared default action (e.g. the
sLSTM ``r_*`` ``keep_dense``), then the recipe's ``default`` action; in
``strict`` mode an unmatched target without an adapter default is an
error instead. Adapter-declared exclusions yield only to *explicit*
exact-name rules — broad glob / ``group:`` patterns skip them, so a
blanket ``group:attn`` rule never forces tap-less recurrent weights
into quantization.

Actions:
  * ``Quantize(cfg)``      — GPTVQ (or its kmeans ablations) at a
                             per-target ``VQConfig``.
  * ``IntQuant(bits, gs)`` — uniform integer quantization (GPTQ error
                             feedback by default, plain RTN optionally).
  * ``KeepDense(reason)``  — leave the leaf untouched; the reason is
                             surfaced in ``QuantizeReport.per_target``.

On top of rules, ``allocate_budget`` solves Hessian-budgeted mixed
precision: given a global bits-per-value budget it scores every
Quantize-resolved target at each candidate setting with a
diagonal-Hessian-weighted proxy — by default the O(r*c)
rate-distortion closed form (``closed_form_proxy_error``; the original
trimmed-EM refit survives as ``scorer="refit"``, the validation
oracle) — and greedily upgrades the most error-reducing targets per
bit spent until the model-wide weighted bpv (shape-aware codebook /
scale overhead included, via ``bpv.effective_bpv``) meets the budget.

JSON schema (see ROADMAP.md "Recipes" for worked per-family examples) —
omitting "default" means the rules (plus adapter defaults) must cover
every target; unmatched targets error rather than silently quantize::

    {"name": "mixed-demo", "strict": false,
     "default": {"action": "quantize", "setting": "2.25bpv_2d"},
     "rules": [
       {"pattern": "group:attn", "action": "quantize",
        "setting": "2.25bpv_2d", "overrides": {"em_iters": 25}},
       {"pattern": "group:mlp", "action": "int_quant",
        "bits": 4, "group_size": 128},
       {"pattern": "layers.0.ffn.w_in", "action": "keep_dense",
        "reason": "first-layer sensitivity"}]}
"""
from __future__ import annotations

import dataclasses
import fnmatch
import functools
import json
from typing import Any, Union

import jax
import jax.numpy as jnp

from repro.core.bpv import (
    DENSE_BITS,
    PAPER_SETTINGS,
    VQConfig,
    effective_bpv,
    int_quant_bpv,
)

# ---------------------------------------------------------------------------
# actions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Quantize:
    """Vector-quantize with GPTVQ (method="gptvq") or one of its data
    ablations ("kmeans": identity Hessian, no feedback; "kmeans_data":
    diagonal Hessian, no feedback).

    ``solver`` picks the inner assignment rule of the GPTVQ sweep
    (core/solvers.py): "gptq" (default, the paper's diagonal-metric
    sweep), "babai" (full conditional span metric / nearest-plane), or
    "cd" (gptq sweep + coordinate-descent refinement). Only meaningful
    for method="gptvq"; bpv accounting is unaffected (same index and
    codebook layout)."""

    cfg: VQConfig = VQConfig()
    method: str = "gptvq"
    solver: str = "gptq"

    @property
    def needs_hessian(self) -> bool:
        return self.method != "kmeans"

    def bpv(self, r: int, c: int) -> float:
        return effective_bpv(self.cfg, r, c)


@dataclasses.dataclass(frozen=True)
class IntQuant:
    """Uniform integer quantization: GPTQ error feedback by default,
    plain round-to-nearest with method="rtn"."""

    bits: int = 4
    group_size: int = 128
    method: str = "gptq"

    @property
    def needs_hessian(self) -> bool:
        return self.method == "gptq"

    def bpv(self, r: int, c: int) -> float:
        return int_quant_bpv(self.bits, self.group_size, c)


@dataclasses.dataclass(frozen=True)
class KeepDense:
    """Leave the leaf dense; counted at DENSE_BITS in the weighted bpv."""

    reason: str = ""

    needs_hessian = False

    def bpv(self, r: int, c: int) -> float:
        return DENSE_BITS


RuleAction = Union[Quantize, IntQuant, KeepDense]


@dataclasses.dataclass(frozen=True)
class Rule:
    pattern: str
    action: RuleAction

    def matches(self, name: str, group: str) -> bool:
        if self.pattern.startswith("group:"):
            return group == self.pattern[len("group:"):]
        return fnmatch.fnmatchcase(name, self.pattern)

    @property
    def explicit(self) -> bool:
        """True for an exact-name rule (no glob metacharacters, not a
        group: pattern) — the only kind that can override an
        adapter-declared keep_dense default. Broad patterns fall through
        to those defaults so e.g. ``group:attn`` never drags the sLSTM
        recurrent r_* (no tap, 3-D) into quantization."""
        return (not self.pattern.startswith("group:")
                and not any(ch in self.pattern for ch in "*?["))


# ---------------------------------------------------------------------------
# target descriptors / resolution
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TargetInfo:
    """What the resolver needs to know about one quantizable leaf."""

    name: str                 # canonical: "<block_prefix>.<spec.name>"
    group: str                # WeightSpec.group ("attn" / "mlp")
    r: int                    # out_features (GPTVQ row dim)
    c: int                    # in_features
    numel: int                # total weights (experts included)
    default_action: RuleAction | None = None  # adapter-declared fallback


@dataclasses.dataclass(frozen=True)
class Resolved:
    """One target's resolved treatment plus its provenance."""

    action: RuleAction
    rule: str                 # "rule[i]:<pattern>" | "default" | "adapter:<reason>"

    @property
    def needs_hessian(self) -> bool:
        return self.action.needs_hessian


class RecipeError(ValueError):
    pass


@dataclasses.dataclass(frozen=True)
class QuantRecipe:
    """Ordered first-match-wins rules + default, over canonical names."""

    rules: tuple[Rule, ...] = ()
    default: RuleAction | None = Quantize()
    strict: bool = False
    name: str = ""

    def __post_init__(self):
        # mirror the from_json guard: a strict recipe must pass
        # default=None — a silently-ignored default is a config footgun
        if self.strict and self.default is not None:
            raise RecipeError("strict recipe cannot carry a default action")

    def resolve(self, targets: list[TargetInfo]) -> dict[str, Resolved]:
        """Map every target to its action. Strict mode refuses targets
        that no rule matches (adapter-declared defaults still apply:
        they are explicit, visible exclusions, not silent misses)."""
        plan: dict[str, Resolved] = {}
        unmatched: list[str] = []
        for t in targets:
            if t.name in plan:
                raise RecipeError(f"duplicate canonical target {t.name!r}")
            hit = None
            for i, rule in enumerate(self.rules):
                if not rule.matches(t.name, t.group):
                    continue
                if t.default_action is not None and not rule.explicit:
                    continue  # adapter exclusions need a by-name rule
                hit = Resolved(rule.action, f"rule[{i}]:{rule.pattern}")
                break
            if hit is None and t.default_action is not None:
                reason = getattr(t.default_action, "reason", "")
                hit = Resolved(t.default_action, f"adapter:{reason}")
            if hit is None:
                if self.strict or self.default is None:
                    unmatched.append(t.name)
                    continue
                hit = Resolved(self.default, "default")
            plan[t.name] = hit
        if unmatched:
            why = "strict recipe" if self.strict else "recipe has no default"
            raise RecipeError(
                f"{why}: no rule matches target(s) "
                + ", ".join(repr(n) for n in unmatched[:8])
                + ("..." if len(unmatched) > 8 else ""))
        return plan

    def with_quantize_overrides(self, **kw) -> "QuantRecipe":
        """A copy with VQConfig fields overridden on every Quantize action
        (rules and default) — launchers use it to apply global speed knobs
        like em_iters without touching the rule structure."""
        def fix(action):
            if not isinstance(action, Quantize):
                return action
            return dataclasses.replace(
                action, cfg=dataclasses.replace(action.cfg, **kw))

        return dataclasses.replace(
            self,
            rules=tuple(dataclasses.replace(r, action=fix(r.action))
                        for r in self.rules),
            default=None if self.default is None else fix(self.default))

    def with_solver(self, solver: str) -> "QuantRecipe":
        """A copy with ``solver`` set on every Quantize action — the
        launcher's ``--solver`` flag applies it globally."""
        from repro.core.solvers import VALID_SOLVERS

        if solver not in VALID_SOLVERS:
            raise RecipeError(f"unknown solver {solver!r}; expected one "
                              f"of {VALID_SOLVERS}")

        def fix(action):
            if not isinstance(action, Quantize):
                return action
            return dataclasses.replace(action, solver=solver)

        return dataclasses.replace(
            self,
            rules=tuple(dataclasses.replace(r, action=fix(r.action))
                        for r in self.rules),
            default=None if self.default is None else fix(self.default))

    # -- construction helpers ----------------------------------------------

    @staticmethod
    def uniform(cfg: VQConfig, method: str = "gptvq",
                name: str = "") -> "QuantRecipe":
        return QuantRecipe(rules=(), default=Quantize(cfg, method), name=name)

    @staticmethod
    def from_legacy(method: str, cfg, *, quantize_attn: bool = True,
                    quantize_mlp: bool = True) -> "QuantRecipe":
        """Compile the old ``quantize_model(method, cfg, quantize_attn=,
        quantize_mlp=)`` surface into an equivalent recipe. The pipeline
        guarantees bitwise-identical packed params for this recipe vs the
        legacy kwargs (same per-target ops, same RNG key consumption)."""
        if method in ("rtn", "gptq"):
            cfg = cfg if cfg is not None else {"bits": 4, "group_size": 128}
            action: RuleAction = IntQuant(cfg["bits"], cfg["group_size"],
                                          method=method)
        elif method in ("gptvq", "kmeans", "kmeans_data"):
            action = Quantize(cfg if cfg is not None else VQConfig(), method)
        else:
            raise RecipeError(f"unknown method {method!r}")
        rules = []
        if not quantize_attn:
            rules.append(Rule("group:attn", KeepDense("quantize_attn=False")))
        if not quantize_mlp:
            rules.append(Rule("group:mlp", KeepDense("quantize_mlp=False")))
        return QuantRecipe(rules=tuple(rules), default=action,
                           name=f"legacy:{method}")

    # -- (de)serialization --------------------------------------------------

    def to_json(self) -> dict:
        out: dict[str, Any] = {"name": self.name, "strict": self.strict,
                               "rules": [
                                   {"pattern": r.pattern,
                                    **_action_to_json(r.action)}
                                   for r in self.rules]}
        if self.default is not None:
            out["default"] = _action_to_json(self.default)
        return out

    @staticmethod
    def from_json(obj: dict) -> "QuantRecipe":
        rules = tuple(
            Rule(r["pattern"], _action_from_json(r))
            for r in obj.get("rules", ()))
        default = (_action_from_json(obj["default"])
                   if "default" in obj else None)
        strict = bool(obj.get("strict", False))
        if strict and "default" in obj:
            raise RecipeError("strict recipe cannot carry a default action")
        # no implicit default: a JSON recipe that omits "default" covers
        # only what its rules (and adapter defaults) match — unmatched
        # targets are a clear error, never silently quantized
        return QuantRecipe(rules=rules, default=default, strict=strict,
                           name=obj.get("name", ""))

    @staticmethod
    def from_file(path: str) -> "QuantRecipe":
        with open(path) as f:
            return QuantRecipe.from_json(json.load(f))


def _vq_cfg_from_json(spec: dict) -> VQConfig:
    base = PAPER_SETTINGS[spec["setting"]] if "setting" in spec else VQConfig()
    overrides = spec.get("overrides", {})
    unknown = set(overrides) - {f.name for f in dataclasses.fields(VQConfig)}
    if unknown:
        raise RecipeError(f"unknown VQConfig override(s): {sorted(unknown)}")
    return dataclasses.replace(base, **overrides)


def _action_from_json(spec: dict) -> RuleAction:
    kind = spec.get("action", "quantize")
    if kind == "quantize":
        return Quantize(_vq_cfg_from_json(spec),
                        method=spec.get("method", "gptvq"),
                        solver=spec.get("solver", "gptq"))
    if kind == "int_quant":
        return IntQuant(int(spec.get("bits", 4)),
                        int(spec.get("group_size", 128)),
                        method=spec.get("method", "gptq"))
    if kind == "keep_dense":
        return KeepDense(spec.get("reason", ""))
    raise RecipeError(f"unknown action {kind!r}")


def _action_to_json(action: RuleAction) -> dict:
    if isinstance(action, Quantize):
        out: dict[str, Any] = {"action": "quantize"}
        if action.method != "gptvq":
            out["method"] = action.method
        if action.solver != "gptq":
            out["solver"] = action.solver
        # emit the matching paper setting when one exists, else raw fields
        for name, cfg in PAPER_SETTINGS.items():
            if action.cfg == cfg:
                out["setting"] = name
                return out
        out["overrides"] = {
            f.name: getattr(action.cfg, f.name)
            for f in dataclasses.fields(VQConfig)
            if getattr(action.cfg, f.name) != f.default}
        return out
    if isinstance(action, IntQuant):
        out = {"action": "int_quant", "bits": action.bits,
               "group_size": action.group_size}
        if action.method != "gptq":
            out["method"] = action.method
        return out
    assert isinstance(action, KeepDense)
    return {"action": "keep_dense", "reason": action.reason}


# ---------------------------------------------------------------------------
# named presets: every PAPER_SETTINGS point as a single-rule (uniform)
# recipe, plus the mixed demo CI exercises on dense and hybrid
# ---------------------------------------------------------------------------

PRESET_RECIPES: dict[str, QuantRecipe] = {
    name: QuantRecipe.uniform(cfg, name=name)
    for name, cfg in PAPER_SETTINGS.items()
}
PRESET_RECIPES["mixed_demo"] = QuantRecipe(
    rules=(
        Rule("group:attn", Quantize(PAPER_SETTINGS["2.25bpv_2d"])),
        Rule("group:mlp", Quantize(PAPER_SETTINGS["4.125bpv_1d"])),
    ),
    default=Quantize(PAPER_SETTINGS["2.25bpv_2d"]),
    name="mixed_demo",
)


def get_recipe(spec: str) -> QuantRecipe:
    """Resolve a CLI recipe argument: a preset name or a JSON file path."""
    if spec in PRESET_RECIPES:
        return PRESET_RECIPES[spec]
    if spec.endswith(".json"):
        return QuantRecipe.from_file(spec)
    raise RecipeError(
        f"unknown recipe {spec!r}: not a preset "
        f"({sorted(PRESET_RECIPES)}) and not a .json path")


# ---------------------------------------------------------------------------
# Hessian-budgeted mixed-precision allocation
# ---------------------------------------------------------------------------

# candidate settings the allocator may assign, cheapest-first by nominal
# bpv; targets whose column count is not divisible by a setting's d skip
# that setting
BUDGET_CANDIDATES = tuple(sorted(
    PAPER_SETTINGS, key=lambda n: PAPER_SETTINGS[n].bits_per_value))


@dataclasses.dataclass
class BudgetEntry:
    """One Quantize-resolved target entering the allocation."""

    name: str
    W: jax.Array              # (r, c) float32, GPTVQ orientation
    diag_h: jax.Array | None  # (c,) diagonal Hessian (None -> identity)
    base_cfg: VQConfig        # non-(d,bits,gs,cb) fields carry over
    numel: int                # weights this choice prices (experts incl.)
    replicas: int = 1         # matrices sharing this choice (E for expert
                              # stacks): the proxy error scales by this so
                              # err and bit-cost cover the same weights


def _proxy_error(W: jax.Array, diag_h, cfg: VQConfig,
                 max_rows: int = 32) -> float:
    """Refit proxy for the reconstruction error of ``cfg`` on W: a short
    diagonal-Hessian-weighted EM fit (no GPTQ error feedback) on a row
    subsample, scaled back to the full matrix. Kept as the validation
    oracle for :func:`closed_form_proxy_error` (``scorer="refit"``) —
    it runs a real (trimmed) sweep per (target, candidate) pair, which
    is what made the budget pre-pass the throughput bottleneck."""
    from repro.core.gptvq import gptvq_quantize_matrix, layer_error

    r, c = W.shape
    step = max(1, r // max_rows)
    Ws = W[::step][:max_rows]
    if diag_h is None:
        diag_h = jnp.ones((c,), jnp.float32)
    d = jnp.maximum(diag_h.astype(jnp.float32), 1e-10)
    Ud = jnp.diag(1.0 / jnp.sqrt(d))  # diagonal H -> Hinv = U^T U
    cfg = dataclasses.replace(cfg, em_iters=min(cfg.em_iters, 6),
                              codebook_update_iters=0, exact_span_solve=False)
    res = gptvq_quantize_matrix(Ws, Ud, cfg, jax.random.PRNGKey(0))
    err = float(layer_error(Ws, res.arrays.Q, jnp.diag(d)))
    return err * (r / Ws.shape[0])


# Gersho's conjectured normalized second moments of the optimal lattice
# quantizer per dimension (d=1 interval, d=2 hexagonal, d=3 BCC, d=4 D4)
_GERSHO_G = {1: 1.0 / 12.0, 2: 5.0 / (36.0 * 3.0 ** 0.5),
             3: 0.0785, 4: 0.0766}


@functools.partial(jax.jit, static_argnames=("n_bands", "rg", "n_cg",
                                             "spans_pg", "d"))
def _cf_weighted_variance(W, h, *, n_bands, rg, n_cg, spans_pg, d):
    """Hessian-weighted total variance per (band, column group), summed.
    Jitted with the group plan static: the allocator evaluates it for
    every (target, candidate) pair, so per-call dispatch overhead is
    what would dominate the pre-pass."""
    X = W.astype(jnp.float32).reshape(n_bands, rg, n_cg, spans_pg, d)
    Hw = h.reshape(n_cg, spans_pg, d)
    # weighted mean per (band, group, coordinate) over the n_vec vectors
    wsum = rg * jnp.sum(Hw, axis=1)  # (n_cg, d)
    mu = (jnp.einsum("bigjp,gjp->bgp", X, Hw)
          / jnp.maximum(wsum[None], 1e-20))
    diff = X - mu[:, None, :, None, :]
    return jnp.einsum("bigjp,gjp->", diff * diff, Hw)


def closed_form_proxy_error(W: jax.Array, diag_h, cfg: VQConfig) -> float:
    """Rate-distortion closed form for the reconstruction error of
    ``cfg`` on W — no EM refit, no sweep: O(r*c) per candidate.

    High-rate VQ theory prices a k-centroid codebook on n d-vectors at

        D  ≈  G_d * k^(-2/d) * V  =  G_d * 2^(-2*bits_per_dim) * V

    where G_d is the Gersho lattice constant and V the (here
    Hessian-weighted) total variance of the vectors around their
    weighted mean. We apply it per (row band, column group) — each has
    its own codebook under the group plan — and multiply by the finite-k
    coverage factor ``max(1 - k/n_vec, 0)``: when the codebook has at
    least as many centroids as vectors every vector is its own centroid
    and the distortion collapses to ~0 (exactly what the refit proxy
    reports on small smoke tensors).

    Weighted variance uses ``diag_h`` per column as the coordinate
    importances, matching the refit proxy's diagonal-Hessian metric.
    Blockwise normalization is ignored (every PAPER_SETTINGS candidate
    has ``scale_block=0``); scales would only rescale V per block and
    cancel in the allocator's per-target comparisons.
    """
    from repro.core.gptvq import plan_groups

    r, c = W.shape
    cg, rg = plan_groups(r, c, cfg)
    d, k = cfg.d, cfg.k
    n_cg, n_bands, spans_pg = c // cg, r // rg, cg // d
    n_vec = rg * spans_pg
    coverage = max(1.0 - k / n_vec, 0.0)
    if coverage == 0.0:
        return 0.0
    if diag_h is None:
        h = jnp.ones((c,), jnp.float32)
    else:
        h = jnp.maximum(diag_h.astype(jnp.float32), 1e-10)
    V = _cf_weighted_variance(W, h, n_bands=n_bands, rg=rg, n_cg=n_cg,
                              spans_pg=spans_pg, d=d)
    g_d = _GERSHO_G.get(d, _GERSHO_G[4])
    return float(g_d * 2.0 ** (-2.0 * cfg.bits_per_dim) * coverage * V)


PROXY_SCORERS = {
    "closed_form": lambda W, diag_h, cfg: closed_form_proxy_error(
        W, diag_h, cfg),
    "refit": lambda W, diag_h, cfg: _proxy_error(W, diag_h, cfg),
}


def allocate_budget(
    entries: list[BudgetEntry],
    budget_bpv: float,
    *,
    fixed_bits: float = 0.0,      # Σ numel*bpv of non-Quantize targets
    fixed_numel: int = 0,
    candidates: tuple[str, ...] = BUDGET_CANDIDATES,
    scorer: str = "closed_form",
    progress=None,
) -> dict[str, tuple[str, VQConfig]]:
    """Greedy discrete allocation: start every target at its cheapest
    feasible setting, then repeatedly apply the upgrade with the best
    proxy-error reduction per extra bit while the model-wide weighted
    bpv (including ``fixed_*`` contributions from int/dense targets)
    stays <= ``budget_bpv``. Returns {target name: (setting, VQConfig)}.

    ``scorer`` picks the per-(target, candidate) error proxy:
    "closed_form" (default) is the O(r*c) rate-distortion model, "refit"
    the original trimmed-EM fit kept as the validation oracle.
    """
    if not entries:
        return {}
    try:
        score = PROXY_SCORERS[scorer]
    except KeyError:
        raise RecipeError(f"unknown budget scorer {scorer!r}; expected "
                          f"one of {sorted(PROXY_SCORERS)}")
    table: dict[str, list[tuple[str, VQConfig, float, float]]] = {}
    for e in entries:
        r, c = e.W.shape
        rows = []
        for setting in candidates:
            base = PAPER_SETTINGS[setting]
            if c % base.d != 0:
                continue
            cfg = dataclasses.replace(
                e.base_cfg, d=base.d, bits_per_dim=base.bits_per_dim,
                group_size=base.group_size, codebook_bits=base.codebook_bits)
            bpv = effective_bpv(cfg, r, c)
            err = score(e.W, e.diag_h, cfg) * e.replicas
            rows.append((setting, cfg, bpv, err))
        if not rows:
            raise RecipeError(
                f"no candidate setting fits target {e.name!r} "
                f"(c={c} not divisible by any candidate d)")
        table[e.name] = rows
        if progress:
            progress(f"budget proxy: {e.name} ({len(rows)} candidates)")

    numel = {e.name: e.numel for e in entries}
    total_numel = fixed_numel + sum(numel.values())
    # start at the cheapest effective bpv (ties: lower proxy error)
    choice: dict[str, int] = {}
    for nm, rows in table.items():
        choice[nm] = min(range(len(rows)), key=lambda i: (rows[i][2],
                                                          rows[i][3]))
    bits = fixed_bits + sum(
        numel[nm] * table[nm][choice[nm]][2] for nm in table)
    if bits / total_numel > budget_bpv + 1e-9:
        raise RecipeError(
            f"budget {budget_bpv} bpv infeasible: cheapest allocation "
            f"already needs {bits / total_numel:.3f} bpv")

    while True:
        best = None  # (efficiency, name, cand_index, delta_bits)
        for nm, rows in table.items():
            cur = rows[choice[nm]]
            for i, cand in enumerate(rows):
                dbits = (cand[2] - cur[2]) * numel[nm]
                derr = cur[3] - cand[3]
                if dbits <= 0 or derr <= 0:
                    continue
                if (bits + dbits) / total_numel > budget_bpv + 1e-9:
                    continue
                eff = derr / dbits
                if best is None or eff > best[0]:
                    best = (eff, nm, i, dbits)
        if best is None:
            break
        _, nm, i, dbits = best
        choice[nm] = i
        bits += dbits

    return {nm: (table[nm][choice[nm]][0], table[nm][choice[nm]][1])
            for nm in table}
