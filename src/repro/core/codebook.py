"""Codebook initialization and Hessian-weighted EM (GPTVQ §3.2).

Vectors are rows of ``X`` with shape (n, d); each vector carries a diagonal
weight vector ``Hw`` of shape (n, d) (the per-coordinate Hessian importances,
see :func:`repro.core.hessian.cholesky_diag_weights`). With ``Hw == 1`` the
EM reduces exactly to k-Means, which is the paper's identity-Hessian remark.

All functions are jit-compatible with static ``k``/iteration counts and are
vmapped over groups by the GPTVQ sweep.
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp


def weighted_distances(X: jax.Array, Hw: jax.Array, C: jax.Array) -> jax.Array:
    """(n, k) matrix of sum_p Hw[i,p] * (X[i,p] - C[m,p])^2.

    Expanded as  sum(Hw*X^2) - 2*(Hw*X)@C^T + Hw@ (C^2)^T  so the inner loops
    are MXU matmuls rather than a materialized (n, k, d) tensor.
    """
    x2 = jnp.sum(Hw * X * X, axis=-1, keepdims=True)  # (n, 1)
    cross = (Hw * X) @ C.T  # (n, k)
    c2 = Hw @ (C * C).T  # (n, k)
    return x2 - 2.0 * cross + c2


def assign(X: jax.Array, Hw: jax.Array, C: jax.Array) -> jax.Array:
    """E-step / Eq. 4: Hessian-weighted nearest-centroid assignment."""
    return jnp.argmin(weighted_distances(X, Hw, C), axis=-1)


def m_step(X: jax.Array, Hw: jax.Array, idx: jax.Array, C_prev: jax.Array) -> jax.Array:
    """Closed-form weighted centroid update (diagonal-Hessian case).

    c_m = (sum_{i in I_m} Hw_i)^+ (sum_{i in I_m} Hw_i * x_i), elementwise.
    Empty clusters keep their previous centroid.
    """
    k = C_prev.shape[0]
    onehot = jax.nn.one_hot(idx, k, dtype=X.dtype)  # (n, k)
    num = onehot.T @ (Hw * X)  # (k, d)
    den = onehot.T @ Hw  # (k, d)
    new = num / jnp.maximum(den, 1e-12)
    empty = (den <= 1e-12)
    return jnp.where(empty, C_prev, new)


def em_objective(X: jax.Array, Hw: jax.Array, C: jax.Array) -> jax.Array:
    return jnp.sum(jnp.min(weighted_distances(X, Hw, C), axis=-1))


@functools.partial(jax.jit, static_argnames=("iters",))
def em(X: jax.Array, Hw: jax.Array, C0: jax.Array, iters: int = 100) -> jax.Array:
    """Run ``iters`` E/M steps from seed centroids ``C0``; returns codebook."""

    def body(_, C):
        idx = assign(X, Hw, C)
        return m_step(X, Hw, idx, C)

    return jax.lax.fori_loop(0, iters, body, C0)


# ---------------------------------------------------------------------------
# Seeding methods (paper §4.3, Table 6)
# ---------------------------------------------------------------------------


def mahalanobis_init(X: jax.Array, k: int) -> jax.Array:
    """Paper's 'Mahalanobis' seeding: sort points by Mahalanobis distance to
    the mean and take k equally spaced points from the sorted list."""
    n, d = X.shape
    mu = jnp.mean(X, axis=0)
    Xc = X - mu
    cov = (Xc.T @ Xc) / n + 1e-6 * jnp.eye(d, dtype=X.dtype)
    prec = jnp.linalg.inv(cov)
    a = jnp.einsum("nd,de,ne->n", Xc, prec, Xc)
    order = jnp.argsort(a)
    pick = jnp.clip(jnp.round(jnp.linspace(0, n - 1, k)).astype(jnp.int32), 0, n - 1)
    return X[order[pick]]


def kmeanspp_init(X: jax.Array, Hw: jax.Array, k: int, key: jax.Array) -> jax.Array:
    """k-means++ seeding with the Hessian-weighted distance (for Table 6)."""
    n, d = X.shape

    def body(carry, key_i):
        C, i = carry
        dist = weighted_distances(X, Hw, C)
        # distance to nearest *already chosen* centroid (mask the unfilled)
        valid = jnp.arange(C.shape[0]) < i
        dmin = jnp.min(jnp.where(valid[None, :], dist, jnp.inf), axis=-1)
        dmin = jnp.maximum(dmin, 0.0)
        p = dmin / jnp.maximum(jnp.sum(dmin), 1e-12)
        choice = jax.random.choice(key_i, n, p=p)
        C = C.at[i].set(X[choice])
        return (C, i + 1), None

    key0, key = jax.random.split(key)
    first = jax.random.randint(key0, (), 0, n)
    C = jnp.zeros((k, d), X.dtype).at[0].set(X[first])
    (C, _), _ = jax.lax.scan(body, (C, 1), jax.random.split(key, k - 1))
    return C


@functools.partial(jax.jit, static_argnames=("k", "iters", "method"))
def init_codebook(
    X: jax.Array,
    Hw: jax.Array,
    *,
    k: int,
    iters: int = 100,
    method: str = "mahalanobis",
    key: jax.Array | None = None,
) -> jax.Array:
    """Seed + EM refine a codebook for one weight group (Algorithm 1 l.11)."""
    if method == "mahalanobis":
        C0 = mahalanobis_init(X, k)
    elif method == "kmeans++":
        assert key is not None
        C0 = kmeanspp_init(X, Hw, k, key)
    else:
        raise ValueError(f"unknown init method {method!r}")
    return em(X, Hw, C0, iters=iters)
