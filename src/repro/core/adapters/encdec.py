"""Adapter for the "audio" family — Whisper-style encoder-decoder.

Block sequence: encoder blocks in order (calibrated on the frame-embedding
stream), one transition pseudo-block (applies the final encoder norm to
form the cross-attention memory and embeds the decoder tokens), then
decoder blocks. Decoder anatomy adds the cross-attention Hessians: the
query projection reads the normed decoder stream ("cross_q_in"), while
wk/wv read the *encoder memory* ("memory" tap) — so the decoder-side
cross projections are calibrated against the actual acoustic statistics,
quantized-encoder error included. Biases (whisper uses qkv_bias) and
positional embeddings stay dense.

The conv/mel frontend is a stub upstream (models/encdec.py): calibration
frames are synthesized deterministically per chunk at the same scale the
smoke tests use. The calibration state is {"enc": x} on the encoder side
and {"dec": x, "memory": m} after the transition.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import vq_linear as vql_mod
from repro.core.adapters import base
from repro.core.adapters.base import WeightSpec
from repro.models import attention, common as cm, encdec, mlp

_FRAMES_SEED = 20  # deterministic stub-frontend calibration frames


def synth_frames(cfg, batch: int, chunk_index: int = 0):
    """Deterministic placeholder frame embeddings (conv frontend stub)."""
    key = jax.random.fold_in(jax.random.PRNGKey(_FRAMES_SEED), chunk_index)
    return jax.random.normal(
        key, (batch, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1


def _ffn_specs(cfg, prefix=""):
    names = ["w_in", "w_out"] + (
        ["w_gate"] if cm.is_gated(cfg.activation) else [])
    tap = {"w_in": "ffn_in", "w_gate": "ffn_in", "w_out": "ffn_out_in"}
    return [WeightSpec(f"ffn.{w}", ("ffn", w), tap[w], "mlp") for w in names]


class _EncBlock(base.BlockAdapter):
    def __init__(self, adapter, index: int):
        self.adapter = adapter
        self.cfg = adapter.cfg
        self.index = index
        self.name = f"enc{index}"
        self.prefix = f"enc.{index}"
        self._p = adapter.enc_layer(index)
        self._new = None

    def params(self):
        return self._p

    def targets(self):
        return tuple(
            [WeightSpec(f"attn.{w}", ("attn", w), "attn_in", "attn")
             for w in ("wq", "wk", "wv")]
            + [WeightSpec("attn.wo", ("attn", "wo"), "attn_out_in", "attn")]
            + _ffn_specs(self.cfg))

    def capture(self, state, taps, groups):
        cfg, lp = self.cfg, self._p
        x = state["enc"]
        x1 = cm.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        if "attn" in groups:
            taps = base.acc_tap(taps, "attn_in", x1)
            o = attention.pre_out(lp["attn"], cfg, x1, causal=False,
                                  use_rope=False)
            taps = base.acc_tap(taps, "attn_out_in", o)
            a = (o @ lp["attn"]["wo"]).astype(x.dtype)
        else:
            a, _ = attention.apply(lp["attn"], cfg, x1, causal=False,
                                   use_rope=False)
        h = x + a
        if "mlp" in groups:
            x2 = cm.rmsnorm(h, lp["norm2"], cfg.norm_eps)
            taps = base.acc_tap(taps, "ffn_in", x2)
            taps = base.acc_tap(taps, "ffn_out_in",
                                mlp.pre_out(lp["ffn"], cfg, x2))
        return taps

    def install(self, new_params):
        self._new = new_params
        self.adapter.new_enc[self.index] = new_params

    def advance(self, state):
        lp = vql_mod.dequant_tree(self._new, jnp.float32)
        return dict(state, enc=encdec.enc_block_apply(lp, self.cfg,
                                                      state["enc"]))


class _Transition(base.BlockAdapter):
    """Encoder→decoder hand-off: final encoder norm forms the memory, the
    decoder token stream is embedded. No quantizable weights."""

    def __init__(self, adapter):
        self.adapter = adapter
        self.cfg = adapter.cfg
        self.name = "enc→dec"
        self.prefix = "enc_dec"

    def params(self):
        return {}

    def targets(self):
        return ()

    def capture(self, state, taps, groups):
        return taps

    def install(self, new_params):
        pass

    def advance(self, state):
        cfg, params = self.cfg, self.adapter.params
        memory = cm.rmsnorm(state["enc"], params["enc_norm"], cfg.norm_eps)
        tokens = state["tokens"]
        x = params["embed"][tokens]
        pos_ids = jnp.arange(tokens.shape[1])
        x = x + params["pos_dec"][pos_ids][None].astype(x.dtype)
        return {"dec": x, "memory": memory}


class _DecBlock(base.BlockAdapter):
    def __init__(self, adapter, index: int):
        self.adapter = adapter
        self.cfg = adapter.cfg
        self.index = index
        self.name = f"dec{index}"
        self.prefix = f"dec.{index}"
        self._p = adapter.dec_layer(index)
        self._new = None

    def params(self):
        return self._p

    def targets(self):
        return tuple(
            [WeightSpec(f"self_attn.{w}", ("self_attn", w), "self_in",
                        "attn") for w in ("wq", "wk", "wv")]
            + [WeightSpec("self_attn.wo", ("self_attn", "wo"),
                          "self_out_in", "attn")]
            + [WeightSpec("cross_attn.wq", ("cross_attn", "wq"),
                          "cross_q_in", "attn")]
            + [WeightSpec(f"cross_attn.{w}", ("cross_attn", w), "memory",
                          "attn") for w in ("wk", "wv")]
            + [WeightSpec("cross_attn.wo", ("cross_attn", "wo"),
                          "cross_out_in", "attn")]
            + _ffn_specs(self.cfg))

    def capture(self, state, taps, groups):
        cfg, lp = self.cfg, self._p
        h, memory = state["dec"], state["memory"]
        x1 = cm.rmsnorm(h, lp["norm1"], cfg.norm_eps)
        if "attn" in groups:
            taps = base.acc_tap(taps, "self_in", x1)
            o = attention.pre_out(lp["self_attn"], cfg, x1, use_rope=False)
            taps = base.acc_tap(taps, "self_out_in", o)
            a = (o @ lp["self_attn"]["wo"]).astype(h.dtype)
        else:
            a, _ = attention.apply(lp["self_attn"], cfg, x1, use_rope=False)
        h = h + a
        xq = cm.rmsnorm(h, lp["norm_x"], cfg.norm_eps)
        if "attn" in groups:
            taps = base.acc_tap(taps, "cross_q_in", xq)
            taps = base.acc_tap(taps, "memory", memory)
            oc = attention.cross_pre_out(lp["cross_attn"], cfg, xq, memory)
            taps = base.acc_tap(taps, "cross_out_in", oc)
            c = (oc @ lp["cross_attn"]["wo"]).astype(h.dtype)
        else:
            c = attention.cross_apply(lp["cross_attn"], cfg, xq, memory)
        h = h + c
        if "mlp" in groups:
            x2 = cm.rmsnorm(h, lp["norm2"], cfg.norm_eps)
            taps = base.acc_tap(taps, "ffn_in", x2)
            taps = base.acc_tap(taps, "ffn_out_in",
                                mlp.pre_out(lp["ffn"], cfg, x2))
        return taps

    def install(self, new_params):
        self._new = new_params
        self.adapter.new_dec[self.index] = new_params

    def advance(self, state):
        lp = vql_mod.dequant_tree(self._new, jnp.float32)
        h = encdec.dec_block_apply(lp, self.cfg, state["dec"],
                                   state["memory"])
        return dict(state, dec=h)


class EncDecAdapter(base.ModelAdapter):
    """Family "audio": params["enc_layers"] + params["dec_layers"], both
    layer-stacked; cross K/V read the encoder memory."""

    def __init__(self, model, params):
        super().__init__(model, params)
        self.new_enc: dict[int, dict] = {}
        self.new_dec: dict[int, dict] = {}

    def enc_layer(self, i: int):
        return jax.tree.map(lambda a: a[i], self.params["enc_layers"])

    def dec_layer(self, i: int):
        return jax.tree.map(lambda a: a[i], self.params["dec_layers"])

    def calib_state(self, tokens, chunk_index: int = 0):
        frames = synth_frames(self.cfg, tokens.shape[0], chunk_index)
        x = encdec.embed_frames(self.params, self.cfg,
                                frames.astype(jnp.float32))
        return {"enc": x, "tokens": tokens}

    def blocks(self):
        cfg = self.cfg
        out: list[base.BlockAdapter] = [
            _EncBlock(self, i) for i in range(cfg.n_encoder_layers)]
        out.append(_Transition(self))
        out += [_DecBlock(self, i) for i in range(cfg.n_layers)]
        return out

    def finalize(self):
        cfg = self.cfg
        enc = base.maybe_stack_blocks(
            [self.new_enc[i] for i in range(cfg.n_encoder_layers)])
        dec = base.maybe_stack_blocks(
            [self.new_dec[i] for i in range(cfg.n_layers)])
        return dict(self.params, enc_layers=enc, dec_layers=dec)
