"""Adapter for the "ssm" family — the xLSTM stack (mLSTM + sLSTM blocks).

Quantizable anatomy (DESIGN.md §5; models/xlstm.py):

  mLSTM block: up / up_gate read the normed block input ("in" tap); the
  q/k/v/o head projections read the up-projected stream u ("u" tap); down
  reads the gated core output ("down_in" tap). The tiny fp32 gate
  projections w_i/w_f ((d_inner, n_heads)) stay dense — they are
  numerically sensitive exponential-gate inputs and a negligible fraction
  of the payload.

  sLSTM block: the four input projections w_z/w_i/w_f/w_o read the normed
  block input; the block-diagonal per-head recurrent matrices r_* are
  emitted as explicit ``keep_dense`` targets (their inputs are the lagged
  hidden states inside the scan — no static tap exists without unrolling
  the recurrence), so the recipe layer surfaces the exclusion in
  ``QuantizeReport.per_target`` instead of skipping it silently. The
  post-core gated FFN quantizes like any dense MLP.

All mixer projections carry group "attn" (they are the sequence-mixing
path); the sLSTM FFN carries group "mlp".
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import vq_linear as vql_mod
from repro.core.adapters import base
from repro.core.adapters.base import WeightSpec
from repro.models import common as cm, transformer, xlstm


_GATE_DENSE_REASON = (
    "fp32 exponential-gate inputs: numerically sensitive and a "
    "negligible fraction of the payload")
_R_DENSE_REASON = (
    "recurrent r_* inputs are lagged hidden states inside the scan — "
    "no static Hessian tap exists without unrolling the recurrence")


class _MLSTMBlock(base.BlockAdapter):
    TARGETS = tuple(
        [WeightSpec(f"core.{w}", ("core", w), "in", "attn")
         for w in ("up", "up_gate")]
        + [WeightSpec(f"core.{w}", ("core", w), "u", "attn")
           for w in ("wq", "wk", "wv", "w_o")]
        + [WeightSpec("core.down", ("core", "down"), "down_in", "attn")]
        + [WeightSpec(f"core.{w}", ("core", w), None, "attn",
                      keep_dense=_GATE_DENSE_REASON)
           for w in ("w_i", "w_f")]
    )

    def __init__(self, adapter, index: int):
        self.adapter = adapter
        self.cfg = adapter.cfg
        self.index = index
        self.name = f"layer{index}[mlstm]"
        self.prefix = f"layers.{index}"
        self._p = adapter.layer(index)
        self._new = None

    def params(self):
        return self._p

    def targets(self):
        return self.TARGETS

    def capture(self, x, taps, groups):
        if "attn" not in groups:
            return taps
        cfg, lp = self.cfg, self._p
        x1 = cm.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        taps = base.acc_tap(taps, "in", x1)
        u, h, _ = xlstm.mlstm_pre_down(lp["core"], cfg, x1)
        taps = base.acc_tap(taps, "u", u)
        taps = base.acc_tap(taps, "down_in", h)
        return taps

    def install(self, new_params):
        self._new = new_params
        self.adapter.installed[self.index] = new_params

    def advance(self, x):
        dense_lp = vql_mod.dequant_tree(self._new, jnp.float32)
        return transformer._block_apply(
            dense_lp, self.cfg, "mlstm", x, pos=0, cache=None)[0]


class _SLSTMBlock(base.BlockAdapter):
    def __init__(self, adapter, index: int):
        self.adapter = adapter
        self.cfg = adapter.cfg
        self.index = index
        self.name = f"layer{index}[slstm]"
        self.prefix = f"layers.{index}"
        self._p = adapter.layer(index)
        self._new = None

    def targets(self):
        return tuple(
            [WeightSpec(f"core.{w}", ("core", w), "in", "attn")
             for w in ("w_z", "w_i", "w_f", "w_o")]
            # block-diagonal per-head recurrent matrices: declared (not
            # silently skipped) so the recipe layer reports them dense
            + [WeightSpec(f"core.{w}", ("core", w), None, "attn",
                          keep_dense=_R_DENSE_REASON)
               for w in ("r_z", "r_i", "r_f", "r_o")]
            + [WeightSpec(f"core.ffn.{w}", ("core", "ffn", w), "ffn_in",
                          "mlp") for w in ("w_in", "w_gate")]
            + [WeightSpec("core.ffn.w_out", ("core", "ffn", "w_out"),
                          "ffn_out_in", "mlp")]
        )

    def params(self):
        return self._p

    def capture(self, x, taps, groups):
        cfg, lp = self.cfg, self._p
        x1 = cm.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        if "attn" in groups:
            taps = base.acc_tap(taps, "in", x1)
        if "mlp" in groups:
            h, _ = xlstm.slstm_apply(lp["core"], cfg, x1, None)
            xa = x + h
            x2 = cm.rmsnorm(xa, lp["core"]["ffn_norm"], cfg.norm_eps)
            taps = base.acc_tap(taps, "ffn_in", x2)
            taps = base.acc_tap(
                taps, "ffn_out_in",
                xlstm.slstm_ffn_pre_out(lp["core"], cfg, x2))
        return taps

    def install(self, new_params):
        self._new = new_params
        self.adapter.installed[self.index] = new_params

    def advance(self, x):
        dense_lp = vql_mod.dequant_tree(self._new, jnp.float32)
        return transformer._block_apply(
            dense_lp, self.cfg, "slstm", x, pos=0, cache=None)[0]


class XLSTMAdapter(base.ModelAdapter):
    """Family "ssm": heterogeneous mLSTM/sLSTM list under params["layers"]."""

    def __init__(self, model, params):
        super().__init__(model, params)
        self._layers = params["layers"]
        self.installed: dict[int, dict] = {}

    def layer(self, i: int):
        return dict(self._layers[i])

    def calib_state(self, tokens, chunk_index: int = 0):
        return transformer.embed_tokens(self.params, self.cfg, tokens)

    def blocks(self):
        out = []
        for i in range(self.cfg.n_layers):
            kind = transformer.block_kind(self.cfg, i)
            cls = _MLSTMBlock if kind == "mlstm" else _SLSTMBlock
            out.append(cls(self, i))
        return out

    def finalize(self):
        new_layers = [self.installed[i] for i in range(self.cfg.n_layers)]
        return dict(self.params, layers=new_layers)
