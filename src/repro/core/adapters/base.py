"""Adapter contracts for the family-agnostic GPTVQ pipeline.

The sequential error-compensated sweep (core/pipeline.quantize_model) is
written once against two small interfaces:

  * ``ModelAdapter`` — one per model family. Owns the parameter tree during
    quantization, turns calibration token chunks into activation *states*
    (opaque to the driver: a plain array for decoder-only stacks, richer
    tuples for models that carry auxiliary streams such as the hybrid's
    initial embedding or the enc-dec's encoder memory), yields the ordered
    list of ``BlockAdapter``s, and reassembles the quantized tree.

  * ``BlockAdapter`` — one per quantizable block. Names the block's weight
    leaves as ``WeightSpec`` (name, path, hessian tap) triples, accumulates
    input Hessians for each tap by running the block's sub-forward
    (``capture``), receives the quantized block (``install``), and pushes a
    calibration state through the quantized block (``advance``) so
    downstream Hessians see upstream quantization error.

Everything a family knows about its block anatomy (which matrices exist,
what feeds them, what stays dense) lives in its adapter module; the driver
only ever sees specs, taps, and states.
"""
from __future__ import annotations

import contextlib
import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core import hessian as hes
from repro.core import vq_linear as vql_mod


@dataclasses.dataclass(frozen=True)
class WeightSpec:
    """One quantizable weight leaf inside a block.

    path       — key path into the block's parameter tree, e.g.
                 ("attn", "wq"). The leaf is an (in, out) kernel, or an
                 (E, in, out) expert stack when ``per_expert`` is set.
    tap        — name of the Hessian tap (``capture`` output) whose
                 statistics quantize this leaf. Plain taps accumulate a
                 ``hessian.HessianState``; per-expert taps accumulate an
                 (E, c, c) stack with per-expert token counts. ``None``
                 means no static tap exists (e.g. recurrent matrices fed
                 by lagged hidden states): data-aware actions fall back
                 to an identity Hessian if a recipe forces quantization.
    group      — "attn" (mixer / attention) or "mlp" (feed-forward); rule
                 patterns can address it as ``group:attn`` / ``group:mlp``.
    keep_dense — when set, the adapter declares this target dense by
                 default (the string is the reason, surfaced in
                 ``QuantizeReport.per_target``); an explicit recipe rule
                 still overrides it.

    The canonical recipe-visible name of a target is
    ``f"{block.prefix}.{spec.name}"`` (see BlockAdapter.prefix).
    """

    name: str
    path: tuple
    tap: str | None
    group: str = "attn"
    per_expert: bool = False
    keep_dense: str | None = None


class BlockAdapter:
    """Base class: one sequential block of the model."""

    name: str = "block"      # display name (progress lines, report rows)
    prefix: str = "block"    # canonical-name prefix for recipe patterns:
                             # stable across runs, e.g. "layers.3", "shared",
                             # "mamba.0.1", "enc.2", "dec.0"

    def params(self) -> Any:
        """Current (not yet quantized) block parameter tree."""
        raise NotImplementedError

    def targets(self) -> tuple[WeightSpec, ...]:
        raise NotImplementedError

    def capture(self, state, taps: dict, groups: frozenset) -> dict:
        """Accumulate this block's Hessian taps from one calibration state."""
        raise NotImplementedError

    def install(self, new_params) -> None:
        """Store the quantized block params (adapter-owned placement)."""
        raise NotImplementedError

    def advance(self, state):
        """Push one calibration state through the (quantized) block."""
        raise NotImplementedError


class ModelAdapter:
    """Base class: a model family's view of the quantization sweep."""

    def __init__(self, model, params):
        self.model = model
        self.cfg: ModelConfig = model.cfg
        self.params = params

    def calib_state(self, tokens: jax.Array, chunk_index: int = 0):
        """Embed one (B, S) calibration token chunk into the family's
        activation-state representation."""
        raise NotImplementedError

    def blocks(self) -> list[BlockAdapter]:
        raise NotImplementedError

    def finalize(self):
        """Reassemble the full parameter tree with quantized blocks."""
        raise NotImplementedError


# ---------------------------------------------------------------------------
# tap accumulation helpers
# ---------------------------------------------------------------------------
#
# Every family adapter funnels Hessian capture through acc_tap /
# acc_expert_tap, so the two pipeline-wide capture modes are dispatched
# here rather than in the six adapter modules:
#
#   * diag_capture(): taps accumulate O(c) DiagHessianState instead of
#     the (c, c) HessianState — the budget pre-pass only reads diag(H),
#     so it never materializes a full Hessian. Adapters with per-expert
#     taps consult diag_capture_active() to build (E, c) diag stacks.
#   * hessian_mesh(mesh, axis): plain taps accumulate data-parallel over
#     the mesh axis (hessian.accumulate_sharded — one psum per call).

_capture_mode = {"diag_only": False, "mesh": None, "axis": "data"}


@contextlib.contextmanager
def diag_capture():
    """Within this context, acc_tap accumulates O(c) diagonals only."""
    prev = _capture_mode["diag_only"]
    _capture_mode["diag_only"] = True
    try:
        yield
    finally:
        _capture_mode["diag_only"] = prev


def diag_capture_active() -> bool:
    return _capture_mode["diag_only"]


@contextlib.contextmanager
def hessian_mesh(mesh, axis: str = "data"):
    """Within this context, acc_tap shards calibration rows over the
    mesh axis and psums the per-device partial Hessians."""
    prev = (_capture_mode["mesh"], _capture_mode["axis"])
    _capture_mode["mesh"], _capture_mode["axis"] = mesh, axis
    try:
        yield
    finally:
        _capture_mode["mesh"], _capture_mode["axis"] = prev


def acc_tap(taps: dict, name: str, x) -> dict:
    """Accumulate activations ``x`` (..., c) into the named Hessian tap."""
    state = taps.get(name)
    if state is None:
        c = x.shape[-1]
        state = (hes.init_diag_hessian(c) if _capture_mode["diag_only"]
                 else hes.init_hessian(c))
    taps = dict(taps)
    mesh = _capture_mode["mesh"]
    if mesh is not None:
        taps[name] = hes.accumulate_sharded(state, x, mesh,
                                            _capture_mode["axis"])
    elif isinstance(state, hes.DiagHessianState):
        taps[name] = hes.accumulate_diag(state, x)
    else:
        taps[name] = hes.accumulate(state, x)
    return taps


def acc_expert_tap(taps: dict, name: str, new: tuple) -> dict:
    """Accumulate a per-expert (Hessian stack, (E,) count) pair — the
    stack is (E, c, c), or (E, c) diagonals under diag_capture()."""
    taps = dict(taps)
    acc = taps.get(name)
    taps[name] = new if acc is None else (acc[0] + new[0], acc[1] + new[1])
    return taps


# ---------------------------------------------------------------------------
# tree path / stacking utilities
# ---------------------------------------------------------------------------

def tree_get(tree, path: tuple):
    for k in path:
        tree = tree[k]
    return tree


def tree_set(tree, path: tuple, value):
    """Copy-on-write set: shallow-copies dicts along ``path`` only."""
    if not path:
        return value
    out = dict(tree)
    out[path[0]] = tree_set(tree[path[0]], path[1:], value)
    return out


def stack_blocks(block_list: list):
    """Stack per-block trees along a new leading axis; VQLinear leaves keep
    their static metadata and stack arraywise (serving format for scanned
    layer stacks)."""
    def is_leaf(x):
        return isinstance(x, vql_mod.VQLinear) or not isinstance(
            x, (dict, list, tuple))

    def stack(*ls):
        if isinstance(ls[0], vql_mod.VQLinear):
            return jax.tree.map(lambda *a: jnp.stack(a), *ls)
        return jnp.stack(ls)

    return jax.tree.map(stack, *block_list, is_leaf=is_leaf)


def blocks_stackable(block_list: list) -> bool:
    """True when every block tree has an identical structure (VQLinear
    static metadata included — it lives in the treedef), i.e. the stack is
    scannable. Mixed recipes break this: per-layer settings diverge in
    (k, d, band) metadata or leave some layers dense, so the model
    assemblies fall back to a per-layer python loop over a list."""
    s0 = jax.tree.structure(block_list[0])
    return all(jax.tree.structure(b) == s0 for b in block_list[1:])


def unify_rules(block_list: list) -> list:
    """When per-layer VQLinear leaves differ *only* in their ``rule``
    provenance string (e.g. layer 0 matched a by-name rule whose action
    equals the default), collapse the divergent rules to "mixed" so the
    stack stays scannable — per-target provenance is still exact in
    QuantizeReport.per_target / checkpoint metadata."""
    is_l = lambda x: isinstance(x, vql_mod.VQLinear)
    flats = [jax.tree.flatten(b, is_leaf=is_l) for b in block_list]
    if any(f[1] != flats[0][1] for f in flats[1:]):
        return block_list  # shapes of the trees themselves differ
    cols = list(zip(*[f[0] for f in flats]))
    out_cols = []
    for col in cols:
        if all(is_l(x) for x in col) and len({x.rule for x in col}) > 1:
            col = tuple(dataclasses.replace(x, rule="mixed") for x in col)
        out_cols.append(col)
    return [jax.tree.unflatten(flats[0][1], [c[i] for c in out_cols])
            for i in range(len(block_list))]


def maybe_stack_blocks(block_list: list):
    """stack_blocks when the blocks are homogeneous, else the plain list
    (heterogeneous serving format for mixed recipes). Rule-provenance
    strings that are the only divergence are unified first so they never
    force the slow list path on an otherwise uniform stack."""
    if blocks_stackable(block_list):
        return stack_blocks(block_list)
    unified = unify_rules(block_list)
    if blocks_stackable(unified):
        return stack_blocks(unified)
    # genuinely heterogeneous: keep the ORIGINAL blocks so each leaf's
    # exact rule provenance survives in the list-path serving format
    return list(block_list)
