"""Adapters for the transformer family: dense GQA stacks, MoE stacks, and
the VLM text backbone (the vision tower is a stub; GPTVQ quantizes the text
stack, calibrated on text tokens — patches enter only at serving time).

Block anatomy (pre-norm residual):

  x ─ norm1 ─ attn(wq wk wv │ wo) ─+─ norm2 ─ ffn(w_in w_gate │ w_out) ─+

Taps: "attn_in" feeds the fused q/k/v projections, "attn_out_in" (the
pre-``wo`` attention output) feeds the output projection, "ffn_in" feeds
the up/gate projections and "ffn_out_in" (the activated hidden state) the
down projection. MoE blocks replace the dense FFN taps with per-expert
Hessian stacks accumulated from each expert's *routed* tokens
(models/moe.expert_hessians).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import vq_linear as vql_mod
from repro.core.adapters import base
from repro.core.adapters.base import WeightSpec
from repro.models import attention, common as cm, mlp, moe, transformer


def _gated(cfg) -> bool:
    return cm.is_gated(cfg.activation)


class _DenseBlock(base.BlockAdapter):
    def __init__(self, adapter: "TransformerAdapter", index: int):
        self.adapter = adapter
        self.cfg = adapter.cfg
        self.index = index
        self.name = f"layer{index}"
        self.prefix = f"layers.{index}"
        self.kind = transformer.block_kind(self.cfg, index)
        self._p = adapter.layer(index)
        self._new = None

    def params(self):
        return self._p

    def targets(self):
        specs = [
            WeightSpec(f"attn.{w}", ("attn", w), "attn_in", "attn")
            for w in ("wq", "wk", "wv")
        ]
        specs.append(WeightSpec("attn.wo", ("attn", "wo"), "attn_out_in",
                                "attn"))
        if self.kind == "dense":
            names = ["w_in", "w_out"] + (["w_gate"] if _gated(self.cfg)
                                         else [])
            tap = {"w_in": "ffn_in", "w_gate": "ffn_in",
                   "w_out": "ffn_out_in"}
            specs += [WeightSpec(f"ffn.{w}", ("ffn", w), tap[w], "mlp")
                      for w in names]
        else:  # moe: expert stacks with routed-token Hessians
            names = ["w_in", "w_out"] + (["w_gate"] if _gated(self.cfg)
                                         else [])
            tap = {"w_in": "experts_in", "w_gate": "experts_in",
                   "w_out": "experts_out"}
            specs += [WeightSpec(f"ffn.{w}", ("ffn", w), tap[w], "mlp",
                                 per_expert=True) for w in names]
        return tuple(specs)

    def capture(self, x, taps, groups):
        cfg, lp = self.cfg, self.params()
        x1 = cm.rmsnorm(x, lp["norm1"], cfg.norm_eps)
        if "attn" in groups:
            taps = base.acc_tap(taps, "attn_in", x1)
            o = attention.pre_out(lp["attn"], cfg, x1, pos=0)
            taps = base.acc_tap(taps, "attn_out_in", o)
            a = (o @ lp["attn"]["wo"]).astype(x.dtype)
        else:
            a, _ = attention.apply(lp["attn"], cfg, x1, pos=0)
        xa = x + a
        x2 = cm.rmsnorm(xa, lp["norm2"], cfg.norm_eps)
        if "mlp" in groups:
            if self.kind == "dense":
                taps = base.acc_tap(taps, "ffn_in", x2)
                taps = base.acc_tap(
                    taps, "ffn_out_in", mlp.pre_out(lp["ffn"], cfg, x2))
            else:
                eh_in, eh_out = moe.expert_hessians(
                    lp["ffn"], cfg, x2,
                    diag_only=base.diag_capture_active())
                taps = base.acc_expert_tap(taps, "experts_in", eh_in)
                taps = base.acc_expert_tap(taps, "experts_out", eh_out)
        return taps

    def install(self, new_params):
        self._new = new_params
        self.adapter.installed[self.index] = new_params

    def advance(self, x):
        dense_lp = vql_mod.dequant_tree(self._new, jnp.float32)
        return transformer._block_apply(
            dense_lp, self.cfg, self.kind, x, pos=0, cache=None)[0]


class TransformerAdapter(base.ModelAdapter):
    """Families "dense", "moe", "vlm": a stacked (or listed) block stack
    under params["layers"] with transformer.embed_tokens in front."""

    def __init__(self, model, params):
        super().__init__(model, params)
        layers = params["layers"]
        self._stacked = not isinstance(layers, list)
        self._layers = layers
        self.installed: dict[int, dict] = {}

    def layer(self, i: int):
        if self._stacked:
            return jax.tree.map(lambda a: a[i], self._layers)
        return dict(self._layers[i])

    def calib_state(self, tokens, chunk_index: int = 0):
        return transformer.embed_tokens(self.params, self.cfg, tokens)

    def blocks(self):
        return [_DenseBlock(self, i) for i in range(self.cfg.n_layers)]

    def finalize(self):
        new_blocks = [self.installed[i] for i in range(self.cfg.n_layers)]
        if not self._stacked:
            out_layers = new_blocks
        else:
            # mixed recipes produce per-layer packed metadata that cannot
            # stack into one scan; the forward falls back to a layer loop
            out_layers = base.maybe_stack_blocks(new_blocks)
        return dict(self.params, layers=out_layers)
