"""Model-adapter registry: the GPTVQ pipeline's only entry to block anatomy.

``get_adapter(model, params)`` resolves a ``ModelAdapter`` by
``ModelConfig.family``; each adapter yields per-block ``BlockAdapter``s
exposing quantizable weights, Hessian-tap capture and quantized-activation
advance (see base.py). To support a new family, implement the two classes
in a new module and ``register("<family>")`` it here — the driver in
core/pipeline.py needs no change.
"""
from __future__ import annotations

from repro.core.adapters.base import (  # noqa: F401 (public API)
    BlockAdapter,
    ModelAdapter,
    WeightSpec,
    acc_expert_tap,
    acc_tap,
    blocks_stackable,
    diag_capture,
    diag_capture_active,
    hessian_mesh,
    maybe_stack_blocks,
    stack_blocks,
    tree_get,
    tree_set,
)

_REGISTRY: dict[str, type] = {}


def register(family: str):
    def deco(cls):
        _REGISTRY[family] = cls
        return cls
    return deco


def families() -> tuple[str, ...]:
    _ensure_builtins()
    return tuple(sorted(_REGISTRY))


def get_adapter(model, params) -> ModelAdapter:
    _ensure_builtins()
    family = model.cfg.family
    cls = _REGISTRY.get(family)
    if cls is None:
        raise KeyError(
            f"no ModelAdapter registered for family {family!r} "
            f"(known: {sorted(_REGISTRY)}); add one under "
            "repro/core/adapters/ and register() it")
    return cls(model, params)


def _ensure_builtins():
    if _REGISTRY:
        return
    from repro.core.adapters.encdec import EncDecAdapter
    from repro.core.adapters.hybrid import HybridAdapter
    from repro.core.adapters.recurrent import XLSTMAdapter
    from repro.core.adapters.transformer import TransformerAdapter

    _REGISTRY.update({
        "dense": TransformerAdapter,
        "moe": TransformerAdapter,
        "vlm": TransformerAdapter,
        "ssm": XLSTMAdapter,
        "hybrid": HybridAdapter,
        "audio": EncDecAdapter,
    })


def calib_extras(cfg, tokens, chunk_index: int = 0) -> dict:
    """Stub-frontend batch extras (frames/patches) for families whose
    forward needs more than tokens — used by eval helpers around the
    quantization launcher."""
    if cfg.family == "audio":
        from repro.core.adapters.encdec import synth_frames
        return {"frames": synth_frames(cfg, tokens.shape[0], chunk_index)}
    return {}
