"""Adapter for the "hybrid" family — Zamba2-style Mamba2 trunk with one
*shared* attention block invoked every ``shared_attn_every`` layers (plus
per-invocation LoRA deltas on q/k/v).

Block sequence: the shared attention block first (it is one set of weights
used at every group boundary), then the mamba layers in trunk order. The
shared block's Hessians are accumulated over *all* of its invocations by
replaying the unquantized trunk — its q/k/v/o statistics come from every
group's concat(hidden, initial-embedding) stream, not just the first.
Because it is quantized before any mamba layer, every subsequent capture
and advance already sees the quantized shared weights at group entries —
preserving the GPTQ-style "downstream sees upstream error" invariant.

Mamba mixers quantize in_proj (tap: normed block input) and out_proj (tap:
the gated scan output from models/ssm.pre_out). Conv/scan parameters
(conv_w, A_log, dt_bias, D_skip, norm_scale) and the LoRA A/B factors stay
dense. The calibration state is a dict {"x": hidden, "emb0": embedding}
because every shared invocation re-reads the initial embedding.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import vq_linear as vql_mod
from repro.core.adapters import base
from repro.core.adapters.base import WeightSpec
from repro.models import attention, common as cm, hybrid, ssm


def _lora_group(params, g: int):
    return jax.tree.map(lambda a: a[g], params["lora"])


def _shared_pre_out(shared_p, lora_g, cfg, h, emb0):
    """One shared-block invocation up to wo; returns (xin, o, y)."""
    xin = hybrid.shared_attn_input(shared_p, cfg, h, emb0)
    attn_p = hybrid.lora_attn_params(shared_p, lora_g, cfg)
    o = attention.pre_out(attn_p, cfg, xin, pos=0)
    y = (o @ attn_p["wo"]).astype(h.dtype)
    return xin, o, y


class _SharedAttnBlock(base.BlockAdapter):
    TARGETS = tuple(
        [WeightSpec(f"attn.{w}", ("attn", w), "xin", "attn")
         for w in ("wq", "wk", "wv")]
        + [WeightSpec("attn.wo", ("attn", "wo"), "attn_out_in", "attn")]
    )

    def __init__(self, adapter: "HybridAdapter"):
        self.adapter = adapter
        self.cfg = adapter.cfg
        self.name = "shared_attn"
        self.prefix = "shared"

    def params(self):
        return dict(self.adapter.params["shared"])

    def targets(self):
        return self.TARGETS

    def capture(self, state, taps, groups):
        """Replay the unquantized trunk, accumulating the shared block's
        input / pre-out Hessians at every invocation."""
        if "attn" not in groups:
            return taps
        cfg = self.cfg
        params = self.adapter.params
        shared = params["shared"]
        h, emb0 = state["x"], state["emb0"]
        for g in range(self.adapter.n_groups):
            lora_g = _lora_group(params, g)
            xin, o, y = _shared_pre_out(shared, lora_g, cfg, h, emb0)
            taps = base.acc_tap(taps, "xin", xin)
            taps = base.acc_tap(taps, "attn_out_in", o)
            h = h + y
            for j in range(self.adapter.per):
                lp = self.adapter.mamba_layer(g, j)
                y_m, _ = ssm.apply(
                    lp["mixer"], cfg,
                    cm.rmsnorm(h, lp["norm"], cfg.norm_eps))
                h = h + y_m
        return taps

    def install(self, new_params):
        self.adapter.new_shared = new_params
        self.adapter._shared_dense = None  # invalidate dequant cache

    def advance(self, state):
        return state  # stream is still at the embedding


class _MambaBlock(base.BlockAdapter):
    def __init__(self, adapter: "HybridAdapter", g: int, j: int):
        self.adapter = adapter
        self.cfg = adapter.cfg
        self.g, self.j = g, j
        self.name = f"mamba{g}.{j}" + (" (+shared entry)" if j == 0 else "")
        self.prefix = f"mamba.{g}.{j}"
        self._p = adapter.mamba_layer(g, j)
        self._new = None
        # group-entry hidden streams computed in capture(), reused by
        # advance() on the same state objects (the driver holds the state
        # list across both loops) — halves the shared-block forwards
        self._entered: dict[int, jax.Array] = {}

    def params(self):
        return self._p

    def targets(self):
        return (
            WeightSpec("mixer.in_proj", ("mixer", "in_proj"), "in", "attn"),
            WeightSpec("mixer.out_proj", ("mixer", "out_proj"), "out_in",
                       "attn"),
        )

    def _enter(self, state):
        """Hidden stream at this layer's input (applies the — already
        quantized — shared block at group entry)."""
        h, emb0 = state["x"], state["emb0"]
        if self.j == 0:
            shared = self.adapter.shared_dense()
            lora_g = _lora_group(self.adapter.params, self.g)
            _, _, y = _shared_pre_out(shared, lora_g, self.cfg, h, emb0)
            h = h + y
        return h

    def capture(self, state, taps, groups):
        if "attn" not in groups:
            return taps
        cfg = self.cfg
        h = self._enter(state)
        self._entered[id(state)] = h
        x1 = cm.rmsnorm(h, self._p["norm"], cfg.norm_eps)
        taps = base.acc_tap(taps, "in", x1)
        y_pre, _ = ssm.pre_out(self._p["mixer"], cfg, x1)
        taps = base.acc_tap(taps, "out_in", y_pre)
        return taps

    def install(self, new_params):
        self._new = new_params
        self.adapter.new_mamba[(self.g, self.j)] = new_params

    def advance(self, state):
        cfg = self.cfg
        h = self._entered.pop(id(state), None)
        if h is None:  # capture skipped (group disabled)
            h = self._enter(state)
        lp = vql_mod.dequant_tree(self._new, jnp.float32)
        y, _ = ssm.apply(lp["mixer"], cfg,
                         cm.rmsnorm(h, lp["norm"], cfg.norm_eps))
        return {"x": h + y, "emb0": state["emb0"]}


class HybridAdapter(base.ModelAdapter):
    """Family "hybrid": shared attention block + (n_groups, per) mamba
    trunk. The shared block quantizes first (Hessians over all
    invocations), then the trunk in order."""

    def __init__(self, model, params):
        super().__init__(model, params)
        self.n_groups = self.cfg.n_layers // self.cfg.shared_attn_every
        self.per = self.cfg.shared_attn_every
        self.new_shared = None
        self.new_mamba: dict[tuple, dict] = {}
        self._shared_dense = None

    def mamba_layer(self, g: int, j: int):
        return jax.tree.map(lambda a: a[g][j], self.params["mamba"])

    def current_shared(self):
        return self.new_shared if self.new_shared is not None \
            else self.params["shared"]

    def shared_dense(self):
        """Dequantized shared block, cached — it is immutable once the
        shared adapter has installed its quantized params, and every
        group-entry capture/advance reuses it."""
        if self._shared_dense is None:
            self._shared_dense = vql_mod.dequant_tree(
                self.current_shared(), jnp.float32)
        return self._shared_dense

    def calib_state(self, tokens, chunk_index: int = 0):
        x = self.params["embed"][tokens]
        return {"x": x, "emb0": x}

    def blocks(self):
        out: list[base.BlockAdapter] = [_SharedAttnBlock(self)]
        for g in range(self.n_groups):
            for j in range(self.per):
                out.append(_MambaBlock(self, g, j))
        return out

    def finalize(self):
        flat = [self.new_mamba[(g, j)] for g in range(self.n_groups)
                for j in range(self.per)]
        if not base.blocks_stackable(flat):
            # provenance-only rule divergence must not cost the scan path
            flat = base.unify_rules(flat)
        if base.blocks_stackable(flat):
            groups = [flat[g * self.per:(g + 1) * self.per]
                      for g in range(self.n_groups)]
            mamba = base.stack_blocks(
                [base.stack_blocks(grp) for grp in groups])
        else:
            # heterogeneous trunk (mixed recipe): list-of-lists with the
            # original per-leaf rules, consumed by the python-loop path
            # in models/hybrid.forward
            mamba = [[self.new_mamba[(g, j)] for j in range(self.per)]
                     for g in range(self.n_groups)]
        return dict(self.params, shared=self.new_shared
                    if self.new_shared is not None
                    else self.params["shared"], mamba=mamba)
