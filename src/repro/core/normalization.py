"""Blockwise data normalization (GPTVQ §3.2).

Before codebook initialization, each sub-row block of ``Ns`` weights is
divided by its absmax scale. Scales are quantized to ``scale_bits`` (default
4) integers *in log2 domain*, with a per-column-group floating point offset
``z`` so that unit scaling is exactly representable:

    s_int = round((log2(s) - z) / a) ,  clipped to the integer grid
    s_hat = 2^(a * s_int + z)

The quantized-scale grid step ``a`` is shared over the weight group.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class BlockScales(NamedTuple):
    s_int: jax.Array   # (r, n_blocks) int32 codes
    a: jax.Array       # scalar grid step (per group)
    z: jax.Array       # scalar log2 offset (per group)
    block: int
    bits: int

    def dequant(self) -> jax.Array:
        """Per-block scales, shape (r, n_blocks)."""
        return jnp.exp2(self.a * self.s_int.astype(jnp.float32) + self.z)

    def expand(self, c: int) -> jax.Array:
        """Per-element scales, shape (r, c)."""
        s = self.dequant()
        return jnp.repeat(s, self.block, axis=1)[:, :c]


def compute_block_scales(W: jax.Array, block: int = 32, bits: int = 4) -> BlockScales:
    # NOTE: deliberately not jitted — callers jit around this, and the int
    # fields of BlockScales must stay concrete (static) under tracing.
    """Compute quantized log-domain absmax scales for sub-row blocks of W."""
    r, c = W.shape
    assert c % block == 0, f"{c} % {block} != 0"
    wb = W.reshape(r, c // block, block)
    s = jnp.max(jnp.abs(wb), axis=-1)
    s = jnp.where(s == 0, 1.0, s)
    logs = jnp.log2(s)
    # offset z: make the *median* scale exactly representable and center the
    # 4-bit grid on the observed range of log-scales.
    lo = jnp.min(logs)
    hi = jnp.max(logs)
    z = lo
    nlevels = 2**bits - 1
    a = jnp.maximum((hi - lo) / jnp.maximum(nlevels, 1), 1e-8)
    s_int = jnp.clip(jnp.round((logs - z) / a), 0, nlevels).astype(jnp.int32)
    return BlockScales(s_int, a, z, block, bits)


def normalize(W: jax.Array, scales: BlockScales) -> jax.Array:
    """W ./ expanded scales (applied before codebook init / assignment)."""
    return W / scales.expand(W.shape[1])


def denormalize(Wn: jax.Array, scales: BlockScales) -> jax.Array:
    return Wn * scales.expand(Wn.shape[1])


def identity_scales(W: jax.Array, block: int = 32) -> BlockScales:
    """Unit scales (normalization disabled) with the same static structure."""
    r, c = W.shape
    nb = c // block
    return BlockScales(
        jnp.zeros((r, nb), jnp.int32),
        jnp.zeros(()),
        jnp.zeros(()),
        block,
        4,
    )
