"""Layer-Hessian utilities for GPTQ/GPTVQ.

The per-layer objective Hessian of ``||W X - Ŵ X||_F^2`` w.r.t. a row of W is
``H = X X^T`` (shape (c, c), c = in_features), shared across rows.

In the distributed quantization pipeline each data-parallel worker
accumulates a partial Hessian over its calibration shard; partials are summed
with a single ``psum`` (see core/pipeline.py). Everything downstream of the
accumulated H is per-layer-local.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp


class HessianState(NamedTuple):
    H: jax.Array  # (c, c) running sum of X X^T
    n: jax.Array  # scalar: number of accumulated tokens


def init_hessian(c: int, dtype=jnp.float32) -> HessianState:
    return HessianState(jnp.zeros((c, c), dtype), jnp.zeros((), jnp.int32))


@jax.jit
def accumulate(state: HessianState, x: jax.Array) -> HessianState:
    """Accumulate inputs ``x`` of shape (..., c) into the Hessian."""
    c = state.H.shape[0]
    xf = x.reshape(-1, c).astype(state.H.dtype)
    return HessianState(state.H + xf.T @ xf, state.n + xf.shape[0])


def finalize(state: HessianState) -> jax.Array:
    """Mean Hessian (scale-invariant for the argmin, but keeps damping sane)."""
    n = jnp.maximum(state.n, 1).astype(state.H.dtype)
    return state.H / n


@functools.partial(jax.jit, static_argnames=("percdamp",))
def inv_hessian_cholesky(H: jax.Array, percdamp: float = 0.01) -> jax.Array:
    """Return upper-triangular U with ``H^{-1} = U^T U`` (GPTQ formulation).

    Dead columns (zero diagonal — inputs never active, e.g. unrouted MoE
    expert dims) are given unit diagonal so they quantize round-to-nearest
    with no error feedback, matching the GPTQ reference treatment.
    """
    c = H.shape[0]
    diag = jnp.diagonal(H)
    dead = diag == 0
    H = H + jnp.diag(jnp.where(dead, 1.0, 0.0))
    damp = percdamp * jnp.mean(jnp.where(dead, 0.0, diag))
    damp = jnp.where(damp <= 0, 1e-8, damp)
    H = H + damp * jnp.eye(c, dtype=H.dtype)
    # H^{-1} via Cholesky solves (stable), then Cholesky of the inverse.
    L = jnp.linalg.cholesky(H)
    eye = jnp.eye(c, dtype=H.dtype)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    Hinv = Linv.T @ Linv
    # unique lower factor of Hinv, transposed -> upper U with Hinv = U^T U
    U = jnp.linalg.cholesky(Hinv).T
    return U


def cholesky_diag_weights(U: jax.Array) -> jax.Array:
    """Per-column error importance ``1 / U[q,q]^2``.

    ``U[q,q]^2`` is the q-th diagonal of the *conditioned* inverse Hessian
    (the Schur complement given all previous columns are already fixed), so
    ``1/U[q,q]^2`` is exactly the weight GPTQ's Eq. (2) assigns to the
    quantization error of column q. Used as the diagonal H-weights of the
    VQ assignment / EM distance (DESIGN.md §6.1).
    """
    d = jnp.diagonal(U)
    return 1.0 / jnp.maximum(d * d, 1e-20)
