"""Layer-Hessian utilities for GPTQ/GPTVQ.

The per-layer objective Hessian of ``||W X - Ŵ X||_F^2`` w.r.t. a row of W is
``H = X X^T`` (shape (c, c), c = in_features), shared across rows.

Accumulation comes in three flavours:

  * ``HessianState`` + ``accumulate``: the full (c, c) running sum used by
    the main quantization pass (GPTQ/GPTVQ need the whole matrix for the
    Cholesky error feedback).
  * ``DiagHessianState`` + ``accumulate_diag``: an O(c) running sum of
    ``sum_i x_i^2`` per column. The budget pre-pass only ever reads
    ``diag(H)``, so it uses this state and never materializes (c, c).
  * ``accumulate_sharded``: data-parallel accumulation over a
    ``jax.sharding`` mesh — calibration rows are sharded across the mesh's
    data axis, each device computes a partial ``X_s^T X_s`` (or the diag
    partial), and a single ``psum`` merges the partials. Numerically this
    matches single-device accumulation up to summation order.

Everything downstream of the accumulated H is per-layer-local.
"""
from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P


class HessianState(NamedTuple):
    H: jax.Array  # (c, c) running sum of X X^T
    n: jax.Array  # scalar: number of accumulated tokens


class DiagHessianState(NamedTuple):
    """O(c) accumulator: only the diagonal ``sum_i x_i[q]^2`` per column."""

    diag: jax.Array  # (c,) running sum of x^2 per column
    n: jax.Array     # scalar: number of accumulated tokens


def init_hessian(c: int, dtype=jnp.float32) -> HessianState:
    return HessianState(jnp.zeros((c, c), dtype), jnp.zeros((), jnp.int32))


def init_diag_hessian(c: int, dtype=jnp.float32) -> DiagHessianState:
    return DiagHessianState(jnp.zeros((c,), dtype), jnp.zeros((), jnp.int32))


@jax.jit
def accumulate(state: HessianState, x: jax.Array) -> HessianState:
    """Accumulate inputs ``x`` of shape (..., c) into the Hessian."""
    c = state.H.shape[0]
    xf = x.reshape(-1, c).astype(state.H.dtype)
    return HessianState(state.H + xf.T @ xf, state.n + xf.shape[0])


@jax.jit
def accumulate_diag(state: DiagHessianState, x: jax.Array) -> DiagHessianState:
    """Accumulate ``diag(X^T X)`` without ever forming (c, c)."""
    c = state.diag.shape[0]
    xf = x.reshape(-1, c).astype(state.diag.dtype)
    return DiagHessianState(state.diag + jnp.sum(xf * xf, axis=0),
                            state.n + xf.shape[0])


# -- mesh-parallel accumulation ----------------------------------------------

@functools.lru_cache(maxsize=None)
def _sharded_partial_fns(mesh, axis: str):
    """Build (full, diag) shard_map partial-Hessian fns for a mesh axis.

    Each device receives its row-shard of the flattened activations,
    computes the local ``X_s^T X_s`` (or its diagonal), and a single
    ``psum`` over ``axis`` merges the partials — one collective per
    accumulate call. Cached per (mesh, axis): ``jax.sharding.Mesh`` is
    hashable, so repeated calls reuse the compiled fns.
    """

    def _full(xf):
        part = xf.T @ xf
        return jax.lax.psum(part, axis)

    def _diag(xf):
        part = jnp.sum(xf * xf, axis=0)
        return jax.lax.psum(part, axis)

    full = jax.jit(shard_map(_full, mesh=mesh, in_specs=P(axis, None),
                             out_specs=P(), check_rep=False))
    diag = jax.jit(shard_map(_diag, mesh=mesh, in_specs=P(axis, None),
                             out_specs=P(), check_rep=False))
    return full, diag


def _shard_rows(x: jax.Array, c: int, n_dev: int):
    """Flatten to (rows, c) and zero-pad rows to a multiple of n_dev.

    Zero rows contribute nothing to ``X^T X``; the true row count is
    returned separately so ``n`` stays exact.
    """
    xf = x.reshape(-1, c).astype(jnp.float32)
    rows = xf.shape[0]
    pad = (-rows) % n_dev
    if pad:
        xf = jnp.concatenate([xf, jnp.zeros((pad, c), xf.dtype)], axis=0)
    return xf, rows


def accumulate_sharded(state, x: jax.Array, mesh, axis: str = "data"):
    """Data-parallel ``accumulate``/``accumulate_diag`` over a mesh axis.

    Rows of the flattened calibration activations are sharded across the
    mesh's ``axis`` devices; each computes a partial and one psum merges
    them. Accepts either a ``HessianState`` or a ``DiagHessianState`` and
    returns the same kind. Matches the single-device path numerically
    (floating-point summation order differs, so comparisons should be
    allclose rather than bitwise).
    """
    n_dev = mesh.shape[axis]
    full_fn, diag_fn = _sharded_partial_fns(mesh, axis)
    if isinstance(state, DiagHessianState):
        c = state.diag.shape[0]
        xf, rows = _shard_rows(x, c, n_dev)
        return DiagHessianState(state.diag + diag_fn(xf), state.n + rows)
    c = state.H.shape[0]
    xf, rows = _shard_rows(x, c, n_dev)
    return HessianState(state.H + full_fn(xf), state.n + rows)


def finalize(state: HessianState) -> jax.Array:
    """Mean Hessian (scale-invariant for the argmin, but keeps damping sane)."""
    n = jnp.maximum(state.n, 1).astype(state.H.dtype)
    return state.H / n


def finalize_diag(state: DiagHessianState) -> jax.Array:
    """Mean Hessian diagonal, (c,)."""
    n = jnp.maximum(state.n, 1).astype(state.diag.dtype)
    return state.diag / n


@functools.partial(jax.jit, static_argnames=("percdamp",))
def inv_hessian_cholesky(H: jax.Array, percdamp: float = 0.01) -> jax.Array:
    """Return upper-triangular U with ``H^{-1} = U^T U`` (GPTQ formulation).

    Dead columns (zero diagonal — inputs never active, e.g. unrouted MoE
    expert dims) are given unit diagonal so they quantize round-to-nearest
    with no error feedback, matching the GPTQ reference treatment. The
    damping level is ``percdamp`` times the mean *live* diagonal: dividing
    by the live-column count rather than c keeps layers with many dead
    columns from being systematically under-damped.
    """
    c = H.shape[0]
    diag = jnp.diagonal(H)
    dead = diag == 0
    H = H + jnp.diag(jnp.where(dead, 1.0, 0.0))
    live = jnp.maximum(jnp.sum(~dead), 1).astype(H.dtype)
    damp = percdamp * jnp.sum(jnp.where(dead, 0.0, diag)) / live
    damp = jnp.where(damp <= 0, 1e-8, damp)
    H = H + damp * jnp.eye(c, dtype=H.dtype)
    # H^{-1} via Cholesky solves (stable), then Cholesky of the inverse.
    L = jnp.linalg.cholesky(H)
    eye = jnp.eye(c, dtype=H.dtype)
    Linv = jax.scipy.linalg.solve_triangular(L, eye, lower=True)
    Hinv = Linv.T @ Linv
    # unique lower factor of Hinv, transposed -> upper U with Hinv = U^T U
    U = jnp.linalg.cholesky(Hinv).T
    return U


def cholesky_diag_weights(U: jax.Array) -> jax.Array:
    """Per-column error importance ``1 / U[q,q]^2``.

    ``U[q,q]^2`` is the q-th diagonal of the *conditioned* inverse Hessian
    (the Schur complement given all previous columns are already fixed), so
    ``1/U[q,q]^2`` is exactly the weight GPTQ's Eq. (2) assigns to the
    quantization error of column q. Used as the diagonal H-weights of the
    VQ assignment / EM distance (DESIGN.md §6.1).
    """
    d = jnp.diagonal(U)
    return 1.0 / jnp.maximum(d * d, 1e-20)
