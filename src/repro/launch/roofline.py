"""Roofline-term extraction from compiled dry-run artifacts.

Three terms per (arch x shape x mesh) cell, in seconds (EXPERIMENTS §Roofline):

  compute    = HLO_FLOPs / (chips * peak_FLOP/s)
  memory     = HLO_bytes / (chips * HBM_bw)
  collective = collective_bytes / (chips * ICI_bw)

HLO_FLOPs / HLO_bytes come from ``compiled.cost_analysis()`` (per-partition
under SPMD -> multiplied back to whole-job by chips where needed; we report
per-chip directly). collective_bytes is parsed from the post-SPMD HLO text:
the sum of output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op (output-size is an upper
bound within 2x of true link traffic for ring implementations; methodology
note in EXPERIMENTS.md).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass

from repro.launch.mesh import HARDWARE

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z]+[0-9]+|pred)\[([0-9,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum output bytes per collective kind from (post-SPMD) HLO text."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+\s*=\s*(\(?[^=]*?\)?)\s*([a-z\-]+)\(", line)
        if not m:
            continue
        op = m.group(2)
        if op in _COLLECTIVES:
            out[op] += _shape_bytes(m.group(1))
            counts[op] += 1
        elif op == "while":
            pass  # loop bodies appear as separate computations; their
            # collectives are counted when their lines appear below
    out["_counts"] = counts
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


def while_trip_counts(hlo_text: str) -> list[int]:
    """Trip counts of while loops (scan over layers / microbatches / attn
    chunks). Evidence that cost_analysis counts each loop body ONCE — which
    is why the roofline table is driven by the analytic model below, with
    cost_analysis reported raw as a cross-check (EXPERIMENTS §Roofline)."""
    trips = []
    for m in re.finditer(r'known_trip_count"?\s*[:=]\s*\{"?n"?[:=]+"?(\d+)"?\}',
                         hlo_text):
        trips.append(int(m.group(1)))
    return trips


@dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    model_flops: float
    dominant: str
    useful_flops_ratio: float

    def as_dict(self):
        return self.__dict__.copy()


def analyze(cost: dict, coll: dict, *, chips: int, model_flops: float,
            loop_scale: float = 1.0) -> Roofline:
    """cost: compiled.cost_analysis() dict (per-partition on SPMD)."""
    flops = float(cost.get("flops", 0.0)) * loop_scale
    raw_bytes = float(cost.get("bytes accessed", 0.0)) * loop_scale
    cbytes = float(coll.get("total", 0)) * loop_scale
    compute_s = flops / HARDWARE["peak_flops_bf16"]
    memory_s = raw_bytes / HARDWARE["hbm_bw"]
    # per-chip collective bytes over ~3 usable ICI links on a v5e torus
    collective_s = cbytes / (3 * HARDWARE["ici_bw"])
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    per_chip_model_flops = model_flops / chips
    ratio = per_chip_model_flops / flops if flops else 0.0
    return Roofline(compute_s, memory_s, collective_s, flops, raw_bytes,
                    cbytes, per_chip_model_flops, dominant, ratio)


def model_flops_train(n_params_active: float, tokens: float) -> float:
    return 6.0 * n_params_active * tokens


def model_flops_decode(n_params_active: float, batch: float) -> float:
    return 2.0 * n_params_active * batch


# ---------------------------------------------------------------------------
# Analytic cost model — exact from config shapes; drives the §Roofline table
# ---------------------------------------------------------------------------

def analytic_cell(cfg, shape, *, chips: int, dp: int, tp: int,
                  n_total: int, n_active: int, microbatches: int = 1,
                  vq_bytes_per_param: float | None = None,
                  weight_payload_bytes: float | None = None,
                  kv_bytes: float = 2.0) -> dict:
    """Per-chip per-step FLOPs / HBM bytes / collective bytes.

    Derivation notes inline; all terms are per chip. ``vq_bytes_per_param``
    replaces the dense bf16 weight payload for VQ serving cells.
    """
    B, S, kind = shape.global_batch, shape.seq_len, shape.kind
    L = cfg.n_layers + cfg.n_encoder_layers
    D = cfg.d_model
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    Bdp = max(1, B // dp)  # rows per data shard
    w_bytes = (vq_bytes_per_param if vq_bytes_per_param is not None else 2.0)
    P_bytes = (weight_payload_bytes if weight_payload_bytes is not None
               else n_total * w_bytes)
    attn_free = cfg.attention_free

    if kind == "train":
        T = B * S
        flops = 6.0 * n_active * T * 1.33 / chips           # +remat refwd
        if not attn_free:
            # fwd 4*B*S^2*H*hd per layer (QK^T + PV), causal /2; bwd 2x; +remat
            flops += 16.0 * B * S * S * H * hd * L / 2 / chips
        # params: streamed per microbatch (FSDP all-gather) + grad/opt traffic
        bytes_ = (microbatches * n_total * 2 + 36.0 * n_total) / chips
        # activations: ~12 R/W of (B,S,D) bf16 per layer incl. recompute
        bytes_ += 12.0 * Bdp * S * D * L * 2 / tp  # act work split over TP
        # collectives: grad ring all-reduce (f32) + 2 TP all-reduces/layer.
        # MoE uses the shard_map EP schedule (models/moe.py): dispatch is
        # local (tokens already TP-replicated), combine is ONE psum of the
        # (Bdp, S, D) output per layer — same cost as the dense TP
        # all-reduce, so no extra term (before §Perf it.3 this was a
        # token all-to-all of K copies: +4*Bdp*S*K*D*2*L).
        coll = 8.0 * n_total * 4 / chips
        coll += 4.0 * L * Bdp * S * D * 2 * microbatches / microbatches
    elif kind == "prefill":
        T = B * S
        flops = 2.0 * n_active * T / chips
        if not attn_free:
            flops += 4.0 * B * S * S * H * hd * L / 2 / chips
        bytes_ = P_bytes / chips
        bytes_ += 10.0 * Bdp * S * D * L * 2 / tp
        bytes_ += 2.0 * Bdp * S * KV * hd * 2 * L / tp  # KV cache write
        coll = 4.0 * L * Bdp * S * D * 2  # MoE combine folded in (see above)
    else:  # decode: one token for every sequence in the batch
        flops = 2.0 * n_active * B / chips
        if not attn_free:
            flops += 4.0 * B * H * hd * S * L / chips       # attend to cache
        bytes_ = P_bytes / chips                            # weights, once
        if not attn_free:
            bytes_ += 2.0 * B * S * KV * hd * kv_bytes * L / chips  # KV read
        if cfg.family in ("ssm", "hybrid"):
            d_inner = cfg.ssm_expand * D
            bytes_ += 2.0 * B * d_inner * cfg.ssm_state * 4 * L / chips
        coll = 4.0 * L * Bdp * 1 * D * 2                    # TP all-reduces
        coll += 2.0 * Bdp * cfg.padded_vocab * 4 / tp       # logits reduce

    compute_s = flops / HARDWARE["peak_flops_bf16"]
    memory_s = bytes_ / HARDWARE["hbm_bw"]
    collective_s = coll / (3 * HARDWARE["ici_bw"])
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    useful_s = (model_flops_train(n_active, B * S) / chips
                if kind == "train" else
                (2.0 * n_active * (B * S if kind == "prefill" else B) / chips)
                ) / HARDWARE["peak_flops_bf16"]
    bound = max(terms.values())
    return {
        "flops": flops, "hbm_bytes": bytes_, "coll_bytes": coll,
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": collective_s, "dominant": dominant,
        "step_lower_bound_s": bound,
        "useful_compute_s": useful_s,
        "roofline_fraction": useful_s / bound if bound > 0 else 0.0,
    }
