"""Render the EXPERIMENTS.md §Dry-run and §Roofline tables from
artifacts/dryrun/*.json.

Run: PYTHONPATH=src python -m repro.launch.report > artifacts/roofline_tables.md
"""
from __future__ import annotations

import glob
import json
import os

ART = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")


def load():
    cells = {}
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        r = json.load(open(f))
        cells[r["cell"]] = r
    return cells


def fmt_s(x):
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.2f}ms"
    return f"{x*1e6:.0f}us"


def gb(x):
    return f"{(x or 0)/2**30:.2f}"


def dryrun_table(cells):
    lines = [
        "| cell | status | chips | fits 16GiB | args GiB | temp GiB | "
        "compile s | collective bytes/chip |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for cid, r in sorted(cells.items()):
        if r["status"] == "skipped":
            lines.append(f"| {cid} | skipped ({r['reason'][:40]}...) "
                         "| | | | | | |")
            continue
        if r["status"] != "ok":
            lines.append(f"| {cid} | **{r['status']}** | | | | | | |")
            continue
        m = r["memory"]
        lines.append(
            f"| {cid} | ok | {r['chips']} | "
            f"{'Y' if r['fits_16GB'] else 'N'} | {gb(m['argument_bytes'])} | "
            f"{gb(m['temp_bytes'])} | {r['compile_s']} | "
            f"{r['collectives']['total']:,} |")
    return "\n".join(lines)


def roofline_table(cells, pod: str = "pod1"):
    lines = [
        "| arch | shape | variant | compute | memory | collective | "
        "dominant | bound (s) | MFU@bound |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for cid, r in sorted(cells.items()):
        if r["status"] != "ok" or f"__{pod}" not in cid:
            continue
        parts = cid.split("__")
        arch, shape = parts[0], parts[1]
        variant = "vq" if cid.endswith("__vq") else "bf16"
        ro = r["roofline"]
        mfu = ro["useful_compute_s"] / ro["step_lower_bound_s"] \
            if ro["step_lower_bound_s"] else 0
        lines.append(
            f"| {arch} | {shape} | {variant} | {fmt_s(ro['compute_s'])} | "
            f"{fmt_s(ro['memory_s'])} | {fmt_s(ro['collective_s'])} | "
            f"**{ro['dominant']}** | {fmt_s(ro['step_lower_bound_s'])} | "
            f"{mfu:.3f} |")
    return "\n".join(lines)


def vq_comparison(cells):
    """Per-arch decode: bf16 vs VQ memory term (the paper's claim)."""
    lines = [
        "| arch | shape | bf16 bound | VQ bound | speedup | "
        "bf16 weight+cache GB/chip | VQ GB/chip |",
        "|---|---|---|---|---|---|---|",
    ]
    for cid, r in sorted(cells.items()):
        if not cid.endswith("__vq") or r["status"] != "ok":
            continue
        base_id = cid[: -len("__vq")]
        b = cells.get(base_id)
        if not b or b["status"] != "ok":
            continue
        arch, shape = cid.split("__")[0], cid.split("__")[1]
        rb, rv = b["roofline"], r["roofline"]
        sp = rb["step_lower_bound_s"] / rv["step_lower_bound_s"]
        lines.append(
            f"| {arch} | {shape} | {fmt_s(rb['step_lower_bound_s'])} | "
            f"{fmt_s(rv['step_lower_bound_s'])} | **{sp:.2f}x** | "
            f"{rb['hbm_bytes']/1e9:.2f} | {rv['hbm_bytes']/1e9:.2f} |")
    return "\n".join(lines)


def summary(cells):
    ok = [r for r in cells.values() if r["status"] == "ok"]
    fits = [r for r in ok if r.get("fits_16GB")]
    skipped = [r for r in cells.values() if r["status"] == "skipped"]
    failed = [r for r in cells.values()
              if r["status"] not in ("ok", "skipped")]
    doms = {}
    for r in ok:
        doms[r["roofline"]["dominant"]] = doms.get(
            r["roofline"]["dominant"], 0) + 1
    return (f"{len(cells)} cells: {len(ok)} compiled ok "
            f"({len(fits)} fit 16GiB-reserve), {len(skipped)} skipped "
            f"by design, {len(failed)} failed. "
            f"Dominant terms: {doms}.")


def main():
    cells = load()
    print("## Summary\n")
    print(summary(cells))
    print("\n## Dry-run table\n")
    print(dryrun_table(cells))
    print("\n## Roofline (single-pod, 256 chips)\n")
    print(roofline_table(cells, "pod1"))
    print("\n## Roofline (multi-pod, 512 chips)\n")
    print(roofline_table(cells, "pod2"))
    print("\n## VQ vs bf16 serving (paper's deployment claim)\n")
    print(vq_comparison(cells))


if __name__ == "__main__":
    main()
