"""Production mesh construction.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before any jax
device initialization.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh():
    """Degenerate 1x1 mesh for CPU smoke usage of mesh-aware code paths."""
    return jax.make_mesh((1, 1), ("data", "model"))


HARDWARE = {
    # TPU v5e per-chip constants used by the roofline (EXPERIMENTS §Roofline)
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16 * 2**30,     # 16 GiB
    "hbm_reserve": 0.5 * 2**30,  # runtime/system reserve
}
