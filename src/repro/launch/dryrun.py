import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape) cell against the production meshes and record
memory / cost / collective statistics for the roofline analysis.

Run:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multipod-only]

Outputs one JSON per cell under artifacts/dryrun/.
"""

import argparse
import dataclasses
import json
import math
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, shape_applicable
from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import packing
from repro.core.bpv import VQConfig
from repro.core.vq_linear import VQLinear
from repro.core.gptvq import plan_groups
from repro.launch import roofline as rl
from repro.launch.mesh import HARDWARE, make_production_mesh
from repro.models import common as cm, model_zoo, transformer
from repro.serve.serve_step import make_decode, make_prefill
from repro.train import optimizer as opt
from repro.train.train_step import TrainState, init_state, make_train_step

ART_DIR = os.path.join(os.path.dirname(__file__), "../../../artifacts/dryrun")

# serving VQ setting used for the quantized-serving dry-run variants:
# paper's 2.25 bpv (W2@g64-equivalent) 2D configuration (Table 2)
SERVE_VQ = VQConfig(d=2, bits_per_dim=2, group_size=1024, codebook_bits=8)


def dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def widen_fsdp(specs, mesh: Mesh):
    """On multi-pod meshes, FSDP shards over ('pod','data') — the pod axis
    would otherwise be pure replication for parameters/optimizer state."""
    if "pod" not in mesh.axis_names:
        return specs

    def fix(s):
        if not isinstance(s, P):
            return s
        return P(*[("pod", "data") if ax == "data" else ax for ax in s])

    return jax.tree.map(fix, specs, is_leaf=lambda x: isinstance(x, P))


def ns_tree(mesh: Mesh, shapes, specs):
    specs = cm.sanitize_specs(shapes, widen_fsdp(specs, mesh), mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def batch_sharding(mesh: Mesh, batch_shapes):
    dp = dp_axes(mesh)
    dpn = math.prod(mesh.devices.shape[: len(dp)])

    def spec(x):
        b = x.shape[0]
        lead = dp if (b % dpn == 0) else None
        return NamedSharding(mesh, P(lead, *([None] * (len(x.shape) - 1))))

    return jax.tree.map(spec, batch_shapes)


# ---------------------------------------------------------------------------
# abstract inputs per cell
# ---------------------------------------------------------------------------

def abstract_batch(cfg: ModelConfig, shape: ShapeConfig, kind: str):
    B = shape.global_batch
    S = shape.seq_len
    tok = jnp.int32
    if kind == "decode":
        batch = {"tokens": jax.ShapeDtypeStruct((B, 1), tok)}
        return batch
    n_img = cfg.n_image_tokens if cfg.family == "vlm" else 0
    batch = {"tokens": jax.ShapeDtypeStruct((B, S - n_img), tok)}
    if cfg.family == "vlm":
        batch["patches"] = jax.ShapeDtypeStruct(
            (B, n_img, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


def abstract_cache(model, B: int, max_len: int, kv_dtype=jnp.bfloat16):
    return jax.eval_shape(lambda: model.init_cache(B, max_len, dtype=kv_dtype))


def cache_shardings(model, mesh, cache_shapes, *, seq_shard=False):
    """Attention-cache sharding policy (EXPERIMENTS §Dry-run):

    stacked KV caches are (L, B, S, KV, hd). Batch shards over the DP axes.
    When KV divides the TP axis, heads shard over 'model'; otherwise (GQA
    with kv < 16) the *sequence* shards over 'model' — flash-decode style:
    each chip attends over its cache slice, XLA inserts the small
    max/sum/PV collectives. For batch=1 long-context cells the sequence
    additionally takes the 'data' axis.
    """
    cfg = model.cfg
    tp = mesh.devices.shape[-1]
    if cfg.family == "hybrid":
        specs = model.cache_specs(seq_shard=seq_shard)
        return ns_tree(mesh, cache_shapes, specs)
    dp = dp_axes(mesh)

    dpn = math.prod(mesh.devices.shape[: len(dp)])

    def kv_policy(leaf_shape):
        dims = leaf_shape.shape
        if len(dims) != 5:  # recurrent state (xlstm): batch-first leaves
            lead = dp if dims and dims[0] % dpn == 0 else None
            return P(lead, *([None] * (len(dims) - 1)))
        L, B, S, KV, hd = dims
        batch_ax = dp if B % dpn == 0 else None
        if B == 1:
            # single-sequence long context: seq over data AND model
            return P(None, None, ("data", "model"), None, None)
        if KV % tp == 0:
            return P(None, batch_ax, None, "model", None)
        return P(None, batch_ax, "model", None, None)

    specs = jax.tree.map(kv_policy, cache_shapes)
    return ns_tree(mesh, cache_shapes, specs)


# ---------------------------------------------------------------------------
# VQ-compressed abstract parameters (quantized-serving variants)
# ---------------------------------------------------------------------------

_VQ_TARGET_KEYS = ("wq", "wk", "wv", "wo", "w_in", "w_gate", "w_out", "up",
                   "up_gate", "down", "in_proj", "out_proj", "w_z", "w_i",
                   "w_f", "w_o")


def vq_abstract_params(model, vq_cfg: VQConfig):
    """Replace weight leaves with abstract VQLinear pytrees (+ specs)."""
    shapes = model_zoo.abstract_params(model)
    specs = model.param_specs()

    def convert(path, leaf, spec):
        keys = [getattr(k, "key", getattr(k, "name", "")) for k in path]
        name = keys[-1] if keys else ""
        inside_layers = any(k in ("layers", "mamba", "enc_layers",
                                  "dec_layers", "shared") for k in keys)
        if (name not in _VQ_TARGET_KEYS or not inside_layers
                or leaf.ndim < 2 or leaf.shape[-1] < 64
                or leaf.shape[-2] < 64):
            if leaf.dtype == jnp.float32:
                leaf = jax.ShapeDtypeStruct(leaf.shape, jnp.bfloat16)
            return leaf, spec
        lead = leaf.shape[:-2]
        d_in, d_out = leaf.shape[-2], leaf.shape[-1]
        r, c = d_out, d_in  # VQ layout is (out, in)
        cg, rg = plan_groups(r, c, vq_cfg)
        n_cg, n_bands = c // cg, r // rg
        code_bits = max(1, (vq_cfg.k - 1).bit_length())
        lanes = 32 // packing.container_bits(code_bits)
        words = (c // vq_cfg.d) // lanes
        sds = jax.ShapeDtypeStruct
        vql = VQLinear(
            words=sds((*lead, r, words), jnp.uint32),
            codebooks=sds((*lead, n_cg, n_bands, vq_cfg.k, vq_cfg.d), jnp.int8),
            cb_scale=sds((*lead, n_cg, n_bands), jnp.float32),
            scale_sint=sds((*lead, n_cg, r, 1), jnp.int8),
            scale_a=sds((*lead, n_cg), jnp.float32),
            scale_z=sds((*lead, n_cg), jnp.float32),
            r=r, c=c, d=vq_cfg.d, k=vq_cfg.k, group_cols=cg,
            rows_per_band=rg, scale_block=0,
        )
        # shardings: rows (out) follow the original out axis, column groups
        # follow the original in axis
        nlead = len(lead)
        in_ax = spec[-2] if len(spec) >= 2 else None
        out_ax = spec[-1] if len(spec) >= 1 else None
        lead_sp = list(spec[:nlead]) if len(spec) >= nlead + 2 else [None] * nlead
        vspec = VQLinear(
            words=P(*lead_sp, out_ax, in_ax),
            codebooks=P(*lead_sp, in_ax, out_ax, None, None),
            cb_scale=P(*lead_sp, in_ax, out_ax),
            scale_sint=P(*lead_sp, in_ax, out_ax, None),
            scale_a=P(*lead_sp, in_ax),
            scale_z=P(*lead_sp, in_ax),
            r=r, c=c, d=vq_cfg.d, k=vq_cfg.k, group_cols=cg,
            rows_per_band=rg, scale_block=0,
        )
        return vql, vspec

    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    spec_leaves = treedef.flatten_up_to(specs)
    out_shapes, out_specs = [], []
    for (path, leaf), spec in zip(flat, spec_leaves):
        s, sp = convert(path, leaf, spec)
        out_shapes.append(s)
        out_specs.append(sp)
    new_shapes = jax.tree.unflatten(treedef, out_shapes)
    new_specs = jax.tree.unflatten(treedef, out_specs)
    return new_shapes, new_specs


def vq_param_bytes(shapes) -> int:
    total = 0
    for leaf in jax.tree.leaves(
            shapes, is_leaf=lambda x: isinstance(x, VQLinear)):
        if isinstance(leaf, VQLinear):
            total += sum(
                math.prod(a.shape) * a.dtype.itemsize
                for a in jax.tree.leaves(leaf))
        else:
            total += math.prod(leaf.shape) * leaf.dtype.itemsize
    return total


# ---------------------------------------------------------------------------
# per-cell builders
# ---------------------------------------------------------------------------

def active_param_counts(model) -> tuple[int, int]:
    """(total_non_embed, active_non_embed) for MODEL_FLOPS."""
    cfg = model.cfg
    shapes = model_zoo.abstract_params(model)
    flat, _ = jax.tree_util.tree_flatten_with_path(shapes)
    total = active = 0
    for path, leaf in flat:
        keys = [str(getattr(k, "key", getattr(k, "name", ""))) for k in path]
        n = math.prod(leaf.shape)
        if "embed" in keys or "pos_enc" in keys or "pos_dec" in keys:
            continue
        total += n
        if cfg.family == "moe" and "ffn" in keys and leaf.ndim == 4:
            active += n * cfg.n_experts_active // cfg.n_experts
        else:
            active += n
    return total, active


def plan_microbatches(cfg: ModelConfig, shape: ShapeConfig, mesh) -> int:
    dp = dp_axes(mesh)
    dpn = math.prod(mesh.devices.shape[: len(dp)])
    per_dev = max(1, shape.global_batch // dpn)
    # target one sequence per device per microbatch for >=7B models
    big = cfg.d_model >= 3000 or cfg.n_layers >= 40
    return per_dev if big else max(1, per_dev // 4)


def build_cell(arch: str, shape_name: str, *, multi_pod: bool,
               vq: bool = False, kv8: bool = False):
    """Returns (jitted_fn, example_args, meta) ready to lower."""
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    model = model_zoo.build(cfg)
    pshapes = model_zoo.abstract_params(model)
    pspecs = model.param_specs()

    total_p, active_p = active_param_counts(model)
    meta = {
        "arch": arch, "shape": shape_name, "kind": shape.kind,
        "multi_pod": multi_pod, "vq": vq,
        "chips": int(math.prod(mesh.devices.shape)),
        "params_total": total_p, "params_active": active_p,
    }

    if shape.kind == "train":
        assert not vq
        # >=30B models store Adam moments in bf16 (§Perf iteration 6)
        big = cfg.d_model * cfg.n_layers >= 8192 * 24 or cfg.family == "moe"
        ocfg = opt.OptConfig(
            moment_dtype="bfloat16" if big else "float32",
            grad_accum_dtype="bfloat16" if big else "float32")
        mb = plan_microbatches(cfg, shape, mesh)
        meta["moment_dtype"] = ocfg.moment_dtype
        meta["microbatches"] = mb
        state_shapes = jax.eval_shape(
            lambda k: init_state(model, k, ocfg), jax.random.PRNGKey(0))
        state_sh = TrainState(
            params=ns_tree(mesh, state_shapes.params, pspecs),
            opt=opt.AdamWState(
                step=NamedSharding(mesh, P()),
                m=ns_tree(mesh, state_shapes.opt.m, pspecs),
                v=ns_tree(mesh, state_shapes.opt.v, pspecs),
                master=ns_tree(mesh, state_shapes.opt.master, pspecs),
            ))
        batch_shapes = abstract_batch(cfg, shape, "train")
        batch_sh = batch_sharding(mesh, batch_shapes)
        fn = make_train_step(model, ocfg, microbatches=mb)
        # donate the train state: params/opt buffers update in place
        jfn = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                      donate_argnums=(0,))
        args = (state_shapes, batch_shapes)
        model_flops = rl.model_flops_train(
            active_p, shape.global_batch * shape.seq_len) * 1.33  # + remat
        meta["model_flops_note"] = "6*N_active*tokens * 1.33 remat"
    else:
        if vq:
            pshapes, pspecs = vq_abstract_params(model, SERVE_VQ)
            meta["vq_param_bytes"] = vq_param_bytes(pshapes)
        else:
            pshapes = jax.tree.map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.bfloat16)
                if s.dtype == jnp.float32 and s.ndim >= 1 else s, pshapes)
        params_sh = ns_tree(mesh, pshapes, pspecs)
        max_len = shape.seq_len
        kv_dtype = jnp.float8_e4m3fn if kv8 else jnp.bfloat16
        meta["kv_dtype"] = "fp8" if kv8 else "bf16"
        cache_shapes = abstract_cache(model, shape.global_batch, max_len,
                                      kv_dtype)
        seq_shard = shape.name == "long_500k" or (
            shape.kind == "decode" and shape.global_batch <
            math.prod(mesh.devices.shape[: len(dp_axes(mesh))]))
        cache_sh = cache_shardings(model, mesh, cache_shapes,
                                   seq_shard=seq_shard)
        meta["seq_sharded_cache"] = bool(seq_shard)
        if shape.kind == "prefill":
            batch_shapes = abstract_batch(cfg, shape, "prefill")
            batch_sh = batch_sharding(mesh, batch_shapes)
            fn = make_prefill(model, last_only=True)
            # donate the cache: prefill fills it in place
            jfn = jax.jit(fn, in_shardings=(params_sh, batch_sh, cache_sh),
                          donate_argnums=(2,))
            args = (pshapes, batch_shapes, cache_shapes)
            model_flops = rl.model_flops_train(
                active_p, shape.global_batch * shape.seq_len) / 3.0
            meta["model_flops_note"] = "2*N_active*tokens (fwd only)"
        else:  # decode
            batch_shapes = abstract_batch(cfg, shape, "decode")
            tok_sh = batch_sharding(mesh, batch_shapes)["tokens"]
            fn = make_decode(model)
            jfn = jax.jit(
                fn,
                in_shardings=(params_sh, tok_sh, cache_sh,
                              NamedSharding(mesh, P())),
                donate_argnums=(2,),  # cache updates in place
            )
            args = (pshapes, batch_shapes["tokens"], cache_shapes,
                    jax.ShapeDtypeStruct((), jnp.int32))
            model_flops = rl.model_flops_decode(active_p, shape.global_batch)
            meta["model_flops_note"] = "2*N_active*batch (per token)"
    meta["model_flops"] = float(model_flops)
    return jfn, args, mesh, meta


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, vq: bool = False,
             kv8: bool = False, save: bool = True,
             hlo_dump: bool = False) -> dict:
    cfg = ARCHS[arch]
    shape = SHAPES[shape_name]
    ok, reason = shape_applicable(cfg, shape)
    cell_id = f"{arch}__{shape_name}__{'pod2' if multi_pod else 'pod1'}" + (
        "__vq" if vq else "") + ("__kv8" if kv8 else "")
    if not ok:
        result = {"cell": cell_id, "status": "skipped", "reason": reason}
        if save:
            _save(cell_id, result)
        return result
    if vq and shape.kind == "train":
        return {"cell": cell_id, "status": "skipped", "reason": "vq is serve-only"}

    t0 = time.time()
    try:
        jfn, args, mesh, meta = build_cell(arch, shape_name,
                                           multi_pod=multi_pod, vq=vq,
                                           kv8=kv8)
    except Exception as e:
        result = {"cell": cell_id, "status": "FAILED",
                  "error": repr(e)[:2000]}
        if save:
            _save(cell_id, result)
        return result
    try:
        with mesh:
            lowered = jfn.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
    except Exception as e:  # a failed cell is a bug — surface it loudly
        result = {"cell": cell_id, "status": "FAILED", "error": repr(e)[:2000],
                  **meta}
        if save:
            _save(cell_id, result)
        return result

    coll = rl.collective_bytes(hlo)
    trips = rl.while_trip_counts(hlo)
    roof = rl.analyze(cost, coll, chips=meta["chips"],
                      model_flops=meta["model_flops"])
    dp = math.prod(mesh.devices.shape[: len(dp_axes(mesh))])
    tp = mesh.devices.shape[-1]
    # embedding params (bf16 even under VQ) included in the weight payload
    emb = ARCHS[arch].padded_vocab * ARCHS[arch].d_model * (
        1 if ARCHS[arch].tie_embeddings else 2)
    payload = (meta["vq_param_bytes"] if vq and "vq_param_bytes" in meta
               else (meta["params_total"] + emb) * 2)
    analytic = rl.analytic_cell(
        ARCHS[arch], shape, chips=meta["chips"], dp=dp, tp=tp,
        n_total=meta["params_total"], n_active=meta["params_active"],
        microbatches=meta.get("microbatches", 1),
        weight_payload_bytes=payload,
        kv_bytes=1.0 if kv8 else 2.0)
    mem_d = {
        "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "alias_bytes": getattr(mem, "alias_size_in_bytes", None),
    }
    args_b = mem_d["argument_bytes"] or 0
    tmp_b = mem_d["temp_bytes"] or 0
    fits = (args_b + tmp_b) <= (HARDWARE["hbm_bytes"]
                                - HARDWARE["hbm_reserve"])
    result = {
        "cell": cell_id, "status": "ok", **meta,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "memory": mem_d, "fits_16GB": bool(fits),
        "cost": {k: float(v) for k, v in cost.items()
                 if isinstance(v, (int, float))},
        "collectives": coll,
        "while_trip_counts": trips[:32],
        "roofline_hlo_raw": roof.as_dict(),  # cost_analysis counts loop
        # bodies once (see while_trip_counts) — cross-check only
        "roofline": analytic,
    }
    if hlo_dump:
        os.makedirs(ART_DIR, exist_ok=True)
        with open(os.path.join(ART_DIR, cell_id + ".hlo"), "w") as f:
            f.write(hlo)
    if save:
        _save(cell_id, result)
    return result


def _save(cell_id: str, result: dict):
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, cell_id + ".json"), "w") as f:
        json.dump(result, f, indent=1, default=str)


def all_cells(vq_variants: bool = True):
    cells = []
    for arch in ARCHS:
        if arch == "llama2-7b":
            continue
        for shape in SHAPES:
            for mp in (False, True):
                cells.append((arch, shape, mp, False))
            if vq_variants and SHAPES[shape].kind == "decode":
                cells.append((arch, shape, False, True))
    return cells


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--vq", action="store_true")
    ap.add_argument("--kv8", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--hlo-dump", action="store_true")
    args = ap.parse_args()

    if args.all:
        for arch, shape, mp, vq in all_cells():
            cid = f"{arch}/{shape}/{'pod2' if mp else 'pod1'}{'/vq' if vq else ''}"
            t0 = time.time()
            r = run_cell(arch, shape, multi_pod=mp, vq=vq)
            print(f"[{time.strftime('%H:%M:%S')}] {cid}: {r['status']} "
                  f"({time.time()-t0:.0f}s) "
                  + (r.get("reason", "") if r["status"] != "ok" else
                     f"dom={r['roofline']['dominant']}"), flush=True)
        return
    r = run_cell(args.arch, args.shape, multi_pod=args.multipod, vq=args.vq,
                 kv8=args.kv8, hlo_dump=args.hlo_dump)
    print(json.dumps(r, indent=2, default=str))


if __name__ == "__main__":
    main()
