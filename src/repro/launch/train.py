"""Training launcher: mesh setup, sharded state, supervised fault-tolerant
loop with checkpointing and straggler monitoring.

On the CPU container this runs tiny smoke configs end-to-end; on a real
TPU/TRN deployment the same entrypoint runs per-host under the cluster
scheduler (jax.distributed.initialize is called when COORDINATOR_ADDRESS is
set) with the production mesh from launch/mesh.py.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --smoke \
      --steps 50 --global-batch 8 --seq-len 64 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCHS, SMOKE
from repro.data.synthetic import SyntheticStream
from repro.models import common as cm, model_zoo
from repro.runtime import fault_tolerance as ft
from repro.runtime.elastic import build_mesh, plan_mesh
from repro.runtime.straggler import StragglerMonitor
from repro.train import optimizer as opt
from repro.train.train_step import TrainState, init_state, make_train_step


def maybe_init_distributed():
    if os.environ.get("COORDINATOR_ADDRESS"):
        jax.distributed.initialize()


def shardings_for(mesh, model, state_shapes):
    pspecs = model.param_specs()

    def ns(shapes, specs):
        specs = cm.sanitize_specs(shapes, specs, mesh)
        return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)

    return TrainState(
        params=ns(state_shapes.params, pspecs),
        opt=opt.AdamWState(
            step=NamedSharding(mesh, P()),
            m=ns(state_shapes.opt.m, pspecs),
            v=ns(state_shapes.opt.v, pspecs),
            master=ns(state_shapes.opt.master, pspecs),
        ))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced smoke config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--checkpoint-every", type=int, default=25)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    maybe_init_distributed()
    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    if args.smoke:
        cfg = cfg.scaled(dtype="float32")
    model = model_zoo.build(cfg)
    print(f"arch={cfg.name} params={model_zoo.count_params(model)/1e6:.1f}M "
          f"devices={len(jax.devices())}")

    plan = plan_mesh(len(jax.devices()), model_parallel=args.model_parallel)
    mesh = build_mesh(plan)
    ocfg = opt.OptConfig(lr=args.lr, warmup_steps=min(20, args.steps // 5),
                         total_steps=args.steps)
    state_shapes = jax.eval_shape(
        lambda k: init_state(model, k, ocfg), jax.random.PRNGKey(0))
    state_sh = shardings_for(mesh, model, state_shapes)

    with mesh:
        state = jax.jit(
            lambda k: init_state(model, k, ocfg),
            out_shardings=state_sh)(jax.random.PRNGKey(0))
        step_fn_jit = jax.jit(
            make_train_step(model, ocfg, microbatches=args.microbatches),
            donate_argnums=(0,))

        stream = SyntheticStream(cfg.vocab_size, seq_len=args.seq_len,
                                 global_batch=args.global_batch)
        ckpt = Checkpointer(args.ckpt_dir, keep=3, async_save=True)
        monitor = StragglerMonitor()

        def one_step(state, i):
            t0 = time.perf_counter()
            batch = {"tokens": stream.next()}
            state, metrics = step_fn_jit(state, batch)
            jax.block_until_ready(metrics["loss"])
            rep = monitor.record(i, time.perf_counter() - t0)
            if i % 10 == 0:
                print(f"step {i}: loss={float(metrics['loss']):.4f} "
                      f"gnorm={float(metrics['grad_norm']):.3f} "
                      f"{'STRAGGLER' if rep.is_straggler else ''}", flush=True)
            return state

        res = ft.supervise(
            state=state, step_fn=one_step, ckpt=ckpt,
            total_steps=args.steps, checkpoint_every=args.checkpoint_every,
            heartbeat_path=os.path.join(args.ckpt_dir, "heartbeat.json"))
        print(f"done: {res.steps_done} steps, {res.restarts} restarts, "
              f"{res.straggler_flags} straggler flags")


if __name__ == "__main__":
    main()
