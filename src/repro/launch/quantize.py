"""Quantization launcher: run the GPTVQ pipeline over a model and save the
packed checkpoint.

Any architecture in the zoo quantizes through the same command — the
pipeline resolves the family's ModelAdapter (core/adapters/) from the
config, so `--arch whisper-small` or `--arch zamba2-7b` works exactly like
`--arch llama2-7b`.

Distribution note (DESIGN.md §3): calibration Hessian accumulation is
data-parallel (each worker processes a shard of the calibration set; a psum
merges per-layer Hessians), and layers are embarrassingly parallel across
workers afterwards. On the single-process container worker_count=1 runs the
identical code path.

  PYTHONPATH=src python -m repro.launch.quantize --arch llama2-7b --smoke \
      --setting 2.25bpv_2d --out /tmp/vq_ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCHS, SMOKE
from repro.core import adapters
from repro.core.bpv import PAPER_SETTINGS, VQConfig
from repro.core.pipeline import quantize_model
from repro.data.calibration import calibration_tokens, shard_for_worker
from repro.models import model_zoo
from repro.train.loss import perplexity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--setting", default="2.25bpv_2d",
                    choices=sorted(PAPER_SETTINGS))
    ap.add_argument("--sequences", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--em-iters", type=int, default=25)
    ap.add_argument("--update-iters", type=int, default=10)
    ap.add_argument("--out", default="/tmp/repro_vq_ckpt")
    ap.add_argument("--worker", type=int, default=0)
    ap.add_argument("--n-workers", type=int, default=1)
    args = ap.parse_args()

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    if args.smoke:
        cfg = cfg.scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    calib = calibration_tokens(cfg.vocab_size, n_sequences=args.sequences,
                               seq_len=args.seq_len)
    calib = shard_for_worker(calib, args.worker, args.n_workers)
    heldout = calibration_tokens(cfg.vocab_size, n_sequences=8,
                                 seq_len=args.seq_len, seed=777)

    base = PAPER_SETTINGS[args.setting]
    vq_cfg = VQConfig(**{**base.__dict__, "em_iters": args.em_iters,
                         "codebook_update_iters": args.update_iters})
    print(f"arch={cfg.name} setting={args.setting} "
          f"({vq_cfg.bits_per_value:.3f} bpv) calib={calib.shape}")

    # stub-frontend extras (audio frames) for families whose forward needs
    # more than tokens; {} for everyone else
    extras = adapters.calib_extras(cfg, heldout)
    ppl_fp = perplexity(model, params, heldout, batch_extra=extras)
    t0 = time.time()
    qparams, rep = quantize_model(
        model, params, calib, "gptvq", vq_cfg, pack=True,
        progress=lambda msg: print(f"  {msg}", flush=True))
    dt = time.time() - t0
    ppl_vq = perplexity(model, qparams, heldout, batch_extra=extras)
    print(f"quantized in {dt:.1f}s | ppl fp={ppl_fp:.3f} vq={ppl_vq:.3f} "
          f"| recon err={rep.total_error():.4f}")

    ck = Checkpointer(args.out, keep=1)
    ck.save(0, qparams, metadata={
        "arch": cfg.name, "setting": args.setting,
        "bits_per_value": rep.bits_per_value, "ppl_fp": float(ppl_fp),
        "ppl_vq": float(ppl_vq), "seconds": dt,
    })
    print(f"packed checkpoint written to {args.out}")


if __name__ == "__main__":
    main()
