"""Quantization launcher: run the GPTVQ pipeline over a model and save the
packed checkpoint.

Any architecture in the zoo quantizes through the same command — the
pipeline resolves the family's ModelAdapter (core/adapters/) from the
config, so `--arch whisper-small` or `--arch zamba2-7b` works exactly like
`--arch llama2-7b`.

Configuration is recipe-first: `--recipe` takes a preset name (any
PAPER_SETTINGS key as a uniform recipe, or `mixed_demo`) or a JSON file of
declarative per-target rules (schema: core/recipe.py / ROADMAP.md
"Recipes"); `--setting` remains as the uniform shorthand. `--budget-bpv`
enables Hessian-budgeted mixed precision on top of whichever recipe is
active: a cheap diagonal-Hessian pre-pass scores every target at each
candidate setting and a greedy allocator spends the budget where it buys
the most reconstruction error. The checkpoint metadata records the
resolved recipe and the full per-target bpv/rule/error map (not just one
global number), so serve/report can reconstruct the mix.

Distribution note (DESIGN.md §3): calibration Hessian accumulation is
data-parallel (each worker processes a shard of the calibration set; a psum
merges per-layer Hessians), and layers are embarrassingly parallel across
workers afterwards. On the single-process container worker_count=1 runs the
identical code path.

  PYTHONPATH=src python -m repro.launch.quantize --arch llama2-7b --smoke \
      --setting 2.25bpv_2d --out /tmp/vq_ckpt
  PYTHONPATH=src python -m repro.launch.quantize --arch zamba2-7b --smoke \
      --recipe mixed_demo --out /tmp/vq_ckpt
  PYTHONPATH=src python -m repro.launch.quantize --arch llama2-7b --smoke \
      --budget-bpv 2.5 --out /tmp/vq_ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import ARCHS, SMOKE
from repro.core import adapters
from repro.core.bpv import PAPER_SETTINGS
from repro.core.pipeline import quantize_model
from repro.core.recipe import PRESET_RECIPES, QuantRecipe, get_recipe
from repro.data.calibration import calibration_tokens, shard_for_worker
from repro.models import model_zoo
from repro.obs import Telemetry
from repro.train.loss import perplexity


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--setting", default="2.25bpv_2d",
                    choices=sorted(PAPER_SETTINGS))
    ap.add_argument("--recipe", default=None,
                    help="preset name (%s) or recipe JSON path; overrides "
                         "--setting" % ", ".join(sorted(PRESET_RECIPES)))
    ap.add_argument("--budget-bpv", type=float, default=None,
                    help="model-wide bits-per-value budget: per-target "
                         "settings are allocated by Hessian sensitivity")
    ap.add_argument("--budget-scorer", default="closed_form",
                    choices=("closed_form", "refit"),
                    help="budget pre-pass error proxy: the O(r*c) "
                         "rate-distortion closed form (default) or the "
                         "original trimmed-EM refit (validation oracle)")
    ap.add_argument("--solver", default=None,
                    choices=("gptq", "babai", "cd"),
                    help="inner sweep solver on every quantize action: "
                         "gptq (paper default), babai (full conditional "
                         "span metric), cd (+coordinate-descent "
                         "refinement)")
    ap.add_argument("--hessian-mesh", type=int, default=0,
                    help="shard Hessian accumulation data-parallel over "
                         "this many local devices (0 = single-device)")
    ap.add_argument("--sequences", type=int, default=16)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--em-iters", type=int, default=None,
                    help="override em_iters on every quantize action "
                         "(default: 25 for --setting; recipe values for "
                         "--recipe)")
    ap.add_argument("--update-iters", type=int, default=None,
                    help="override codebook_update_iters likewise "
                         "(default: 10 for --setting)")
    ap.add_argument("--out", default="/tmp/repro_vq_ckpt")
    ap.add_argument("--worker", type=int, default=0)
    ap.add_argument("--n-workers", type=int, default=1)
    ap.add_argument("--events-out", default=None,
                    help="write per-stage/per-target quant_* telemetry "
                         "events as JSONL here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the span.quant/* metrics snapshot as JSON "
                         "here")
    args = ap.parse_args()

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    if args.smoke:
        cfg = cfg.scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))

    calib = calibration_tokens(cfg.vocab_size, n_sequences=args.sequences,
                               seq_len=args.seq_len)
    calib = shard_for_worker(calib, args.worker, args.n_workers)
    heldout = calibration_tokens(cfg.vocab_size, n_sequences=8,
                                 seq_len=args.seq_len, seed=777)

    em, up = args.em_iters, args.update_iters
    if args.recipe is not None:
        recipe = get_recipe(args.recipe)
    else:
        recipe = QuantRecipe.uniform(PAPER_SETTINGS[args.setting],
                                     name=args.setting)
        em = 25 if em is None else em
        up = 10 if up is None else up
    # only explicitly-requested speed knobs touch the recipe: a JSON
    # recipe's per-rule em_iters/update_iters stay authoritative otherwise
    overrides = {k: v for k, v in (("em_iters", em),
                                   ("codebook_update_iters", up))
                 if v is not None}
    if overrides:
        recipe = recipe.with_quantize_overrides(**overrides)
    if args.solver is not None:
        recipe = recipe.with_solver(args.solver)
    mesh = None
    if args.hessian_mesh > 1:
        mesh = jax.make_mesh((args.hessian_mesh,), ("data",))
    budget = f" budget={args.budget_bpv}bpv" if args.budget_bpv else ""
    solver = f" solver={args.solver}" if args.solver else ""
    print(f"arch={cfg.name} recipe={recipe.name or 'custom'}{budget}"
          f"{solver} calib={calib.shape}")

    # stub-frontend extras (audio frames) for families whose forward needs
    # more than tokens; {} for everyone else
    extras = adapters.calib_extras(cfg, heldout)
    ppl_fp = perplexity(model, params, heldout, batch_extra=extras)
    telemetry = Telemetry(events_out=args.events_out)
    t0 = time.time()
    qparams, rep = quantize_model(
        model, params, calib, recipe=recipe, budget_bpv=args.budget_bpv,
        budget_scorer=args.budget_scorer, hessian_mesh=mesh,
        pack=True, progress=lambda msg: print(f"  {msg}", flush=True),
        telemetry=telemetry)
    dt = time.time() - t0
    ppl_vq = perplexity(model, qparams, heldout, batch_extra=extras)
    print(f"quantized in {dt:.1f}s | ppl fp={ppl_fp:.3f} vq={ppl_vq:.3f} "
          f"| recon err={rep.total_error():.4f} "
          f"| achieved {rep.achieved_bpv:.3f} bpv")
    if rep.stage_seconds:
        total = sum(rep.stage_seconds.values())
        parts = "  ".join(
            f"{k}={v:.1f}s ({100*v/max(total, 1e-9):.0f}%)"
            for k, v in sorted(rep.stage_seconds.items(),
                               key=lambda kv: -kv[1]))
        print(f"  stages: {parts}")
    for w in rep.warnings:
        print(f"  WARNING: {w}")
    if args.metrics_out:
        telemetry.write_metrics(args.metrics_out)
        print(f"  metrics snapshot -> {args.metrics_out}")
    telemetry.close()
    dense = [k for k, v in rep.per_target.items()
             if v["action"] == "keep_dense"]
    if dense:
        print(f"  kept dense ({len(dense)}): {', '.join(dense[:6])}"
              + (" ..." if len(dense) > 6 else ""))

    ck = Checkpointer(args.out, keep=1)
    ck.save(0, qparams, metadata={
        "arch": cfg.name, "recipe": rep.recipe,
        "achieved_bpv": rep.achieved_bpv, "per_target": rep.per_target,
        "budget_bpv": args.budget_bpv, "ppl_fp": float(ppl_fp),
        "ppl_vq": float(ppl_vq), "seconds": dt,
        "stage_seconds": rep.stage_seconds, "warnings": rep.warnings,
    })
    print(f"packed checkpoint written to {args.out}")


if __name__ == "__main__":
    main()
