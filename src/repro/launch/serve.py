"""Serving launcher: load (or synthesize) weights, optionally GPTVQ-quantize
them, and serve batched synthetic requests through the engine.

  PYTHONPATH=src python -m repro.launch.serve --arch llama2-7b --smoke \
      --vq --requests 8 --max-new 16
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCHS, SMOKE
from repro.core.pipeline import quantize_model
from repro.core.recipe import get_recipe
from repro.data.calibration import calibration_tokens
from repro.models import model_zoo
from repro.obs import Telemetry
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-7b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--vq", action="store_true",
                    help="GPTVQ-quantize before serving")
    ap.add_argument("--recipe", default="2.25bpv_2d",
                    help="recipe preset name or JSON path (with --vq)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--paged-attn-impl", default="gather",
                    choices=["gather", "fused", "xla", "pallas"],
                    help="decode attention over the paged KV pool: the "
                         "XLA logical-view gather (default), or the fused "
                         "in-kernel page gather ('fused' = Pallas kernel "
                         "on TPU, its XLA oracle elsewhere)")
    ap.add_argument("--vq-matmul-impl", default="gather",
                    choices=["gather", "fused", "xla", "pallas"],
                    help="execution path for VQ-packed weight leaves: "
                         "per-layer dense dequantization (default), or the "
                         "fused VQ-dequant matmul over engine-prepped "
                         "FusedVQLinear leaves ('fused' = Pallas kernel on "
                         "TPU, its XLA oracle elsewhere); with --vq this "
                         "skips the per-tick dense-weight materialization")
    ap.add_argument("--kv-cache-bits", default=16,
                    type=lambda s: s if s == "vq2" else int(s),
                    choices=[16, 8, 4, "vq2"],
                    help="paged KV-cache storage: 16 = passthrough dtype, "
                         "8/4 = int8/packed-int4 pages with per-row "
                         "per-kv-head scales, dequantized on the fly by "
                         "every read path (2-4x more pages per byte); "
                         "vq2 = vector-quantized pages (4-bit codebook "
                         "indices over d=2 head-dim vectors, ~10x pages "
                         "per byte; codebooks EM-calibrated at engine "
                         "load, then frozen)")
    ap.add_argument("--prefix-cache", default="off", choices=["on", "off"],
                    help="radix prefix cache + refcounted copy-on-write "
                         "page tables: admitted prompts whose prefix was "
                         "already prefilled share those KV pages and skip "
                         "their prefill chunks (attention families; inert "
                         "for recurrent-state families)")
    ap.add_argument("--parallel-n", type=int, default=1,
                    help="parallel samples per request: each request forks "
                         "n-1 children sharing the prompt's KV blocks "
                         "(best with --prefix-cache on; temperature 0 "
                         "makes them identical — use --temperature)")
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--events-out", default=None,
                    help="write the request-lifecycle JSONL event stream "
                         "(enqueue/admit/first_token/preempt/finish) here")
    ap.add_argument("--metrics-out", default=None,
                    help="write the final metrics-registry snapshot "
                         "(gauges/counters/histograms + dispatch counts) "
                         "as JSON here")
    ap.add_argument("--trace-dir", default=None,
                    help="capture a jax profiler trace of the serve loop; "
                         "host spans become StepTraceAnnotations")
    args = ap.parse_args()

    cfg = (SMOKE if args.smoke else ARCHS)[args.arch]
    if args.smoke:
        cfg = cfg.scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    print(f"arch={cfg.name} params={model_zoo.count_params(model)/1e6:.1f}M")

    if args.vq:
        t0 = time.time()
        calib = calibration_tokens(cfg.vocab_size, n_sequences=8, seq_len=64)
        recipe = get_recipe(args.recipe)
        if not args.recipe.endswith(".json"):
            # presets get the serving-demo speed knobs; a user-authored
            # JSON recipe's own em/update iteration counts stay as written
            recipe = recipe.with_quantize_overrides(
                em_iters=15, codebook_update_iters=5)
        params, rep = quantize_model(model, params, calib, recipe=recipe,
                                     pack=True)
        print(f"GPTVQ[{recipe.name}]: {rep.achieved_bpv:.3f} bpv "
              f"in {time.time()-t0:.1f}s")

    rng = np.random.RandomState(0)
    prefix_on = args.prefix_cache == "on"
    if prefix_on:
        # shared-prefix traffic (the system-prompt pattern the cache is
        # for): every request opens with the same 2 pages of tokens and
        # diverges in a short private tail
        header = rng.randint(0, cfg.vocab_size, size=32)
        prompts = [np.concatenate([
            header, rng.randint(0, cfg.vocab_size, size=6 + i % 5)])
            for i in range(args.requests)]
    else:
        prompts = [rng.randint(0, cfg.vocab_size, size=6 + i % 5)
                   for i in range(args.requests)]
    reqs = [Request(rid=i, prompt=p, max_new_tokens=args.max_new,
                    temperature=args.temperature, n=args.parallel_n)
            for i, p in enumerate(prompts)]
    telemetry = Telemetry(events_out=args.events_out,
                          trace_dir=args.trace_dir)
    eng = Engine(model, params, max_batch=args.max_batch,
                 max_len=args.max_len,
                 paged_attn_impl=args.paged_attn_impl,
                 kv_cache_bits=args.kv_cache_bits,
                 vq_matmul_impl=args.vq_matmul_impl,
                 prefix_cache=prefix_on,
                 telemetry=telemetry)
    if args.kv_cache_bits != 16:
        import dataclasses as _dc

        import jax.numpy as jnp

        from repro.models.attention import KVQuantSpec
        from repro.serve.paged_cache import pool_bytes_of
        fp_layout = _dc.replace(eng.layout, kv=KVQuantSpec())
        print(f"kv_cache_bits={args.kv_cache_bits}: per-layer pool "
              f"{pool_bytes_of(model.cfg, eng.layout, jnp.float32)} B vs "
              f"{pool_bytes_of(model.cfg, fp_layout, jnp.float32)} B fp32 "
              f"at the same page count")
    eng.run(reqs)
    tok_s = eng.stats["tokens"] / max(eng.stats["wall_s"], 1e-9)
    print(f"served {len(reqs)} requests, {eng.stats['tokens']} tokens in "
          f"{eng.stats['wall_s']:.2f}s ({tok_s:.1f} tok/s host-CPU)")

    records = eng.drain_request_records()
    ttfts = sorted(r.ttft_s for r in records if r.ttft_s is not None)
    itls = sorted(r.itl_mean_s for r in records if r.itl_mean_s is not None)
    if ttfts:
        mid = ttfts[len(ttfts) // 2]
        print(f"TTFT: median {mid*1e3:.1f}ms  worst {ttfts[-1]*1e3:.1f}ms "
              f"(enqueue -> first sampled token; first TTFT pays jit "
              f"compilation on this synthetic run)")
    if itls:
        mid = itls[len(itls) // 2]
        print(f"ITL:  median {mid*1e3:.1f}ms/token  worst "
              f"{itls[-1]*1e3:.1f}ms/token")
    preempted = sum(r.preemptions for r in records)
    if preempted:
        print(f"preemptions: {preempted} (recompute-style; preempted "
              f"tokens were discarded and regenerated)")
    if eng.prefix_cache is not None:
        s = eng.stats
        print(f"prefix cache: {s['prefix_hits']} hits / "
              f"{s['prefix_misses']} misses, "
              f"{s['prefix_hit_tokens']} prompt tokens served from shared "
              f"pages, {s['prefix_cached_blocks']} blocks cached, "
              f"{s['prefix_evictions']} evicted")
    if args.parallel_n > 1:
        kids = sum(len(r.forks) for r in reqs)
        print(f"parallel sampling: {kids} forked sequences "
              f"(n={args.parallel_n}) shared their prompts' KV pages")

    if args.metrics_out:
        telemetry.write_metrics(args.metrics_out)
        print(f"metrics snapshot -> {args.metrics_out}")
    if args.events_out:
        print(f"event stream -> {args.events_out}")
    if args.trace_dir:
        print(f"profiler trace -> {args.trace_dir}")
    eng.close()
    telemetry.close()
    for r in reqs[:2]:
        print(f"  req {r.rid}: {list(r.prompt)[:4]}... -> {r.out_tokens[:8]}")


if __name__ == "__main__":
    main()
