"""launch."""
