"""GPTVQ reproduction: vector-quantized LLM PTQ + serving on jax/pallas."""
