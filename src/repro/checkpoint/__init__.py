"""checkpoint."""
