"""Sharded, atomic, async-capable checkpointing (orbax is unavailable).

Layout: <dir>/step_<N>/  with one .npy per leaf (path-encoded filename) and
a manifest.json holding the treedef, dtypes and user metadata. Writes go to
a ``.tmp-`` staging dir that is atomically renamed on completion — a crashed
writer can never corrupt the latest checkpoint, which is what the restart
path (runtime/fault_tolerance.py) relies on.

On multi-host deployments each host writes only the leaves it owns
(addressable shards) and rank 0 writes the manifest; the single-process
container exercises the same code path with world size 1.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _sanitize(path: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.-]", "_", path)


def _flatten_with_names(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for kp, _ in flat:
        names.append(_sanitize(jax.tree_util.keystr(kp)))
    return [(n, v) for n, (kp, v) in zip(names, flat)], treedef


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3, async_save: bool = False):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- save ---------------------------------------------------------------
    def save(self, step: int, tree: Any, metadata: dict | None = None):
        if self.async_save:
            host_tree = jax.tree.map(np.asarray, tree)  # snapshot now
            self.wait()
            self._thread = threading.Thread(
                target=self._save_sync, args=(step, host_tree, metadata))
            self._thread.start()
        else:
            self._save_sync(step, tree, metadata)

    def _save_sync(self, step: int, tree: Any, metadata: dict | None):
        final = os.path.join(self.dir, f"step_{step:010d}")
        tmp = os.path.join(self.dir, f".tmp-step_{step:010d}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        named, treedef = _flatten_with_names(tree)
        manifest = {
            "step": step,
            "treedef": str(treedef),
            "leaves": [],
            "metadata": metadata or {},
        }
        for name, val in named:
            arr = np.asarray(val)
            np.save(os.path.join(tmp, name + ".npy"), arr)
            manifest["leaves"].append(
                {"name": name, "dtype": str(arr.dtype), "shape": list(arr.shape)})
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic publish
        self._gc()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # -- restore --------------------------------------------------------------
    def all_steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", d)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, like: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into the structure of ``like``; returns (tree, metadata)."""
        step = self.latest_step() if step is None else step
        assert step is not None, "no checkpoint found"
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        named, treedef = _flatten_with_names(like)
        vals = []
        for (name, ref) in named:
            arr = np.load(os.path.join(d, name + ".npy"))
            vals.append(arr)
        leaves = [jnp.asarray(v) for v in vals]
        if shardings is not None:
            sh_named, _ = _flatten_with_names(shardings)
            leaves = [jax.device_put(v, s) for v, (_, s) in zip(leaves, sh_named)]
        tree = jax.tree.unflatten(jax.tree.structure(like), leaves)
        return tree, manifest["metadata"]
