"""yi-34b [dense]: 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000
— llama-arch GQA. [arXiv:2403.04652; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-34b", family="dense",
    n_layers=60, d_model=7168, n_heads=56, n_kv_heads=8, head_dim=128,
    d_ff=20480, vocab_size=64000,
    activation="swiglu", rope_theta=5e6,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=128, vocab_size=512, max_seq_len=128,
)
