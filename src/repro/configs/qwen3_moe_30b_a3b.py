"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) d_ff=768/expert
vocab=151936, MoE 128 experts top-8. [hf:Qwen/Qwen3-30B-A3B; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, head_dim=128,
    d_ff=768, vocab_size=151936,
    n_experts=128, n_experts_active=8,
    activation="swiglu", qk_norm=True, rope_theta=1e6,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=64, vocab_size=512, n_experts=8, n_experts_active=2, max_seq_len=128,
)
