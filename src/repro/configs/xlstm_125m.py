"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — sLSTM + mLSTM
blocks (xLSTM[10:2]; sLSTM at layers 3 and 9). [arXiv:2405.04517; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m", family="ssm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4,
    d_ff=0, vocab_size=50304,
    slstm_layers=(3, 9), tie_embeddings=True,
)

SMOKE = CONFIG.scaled(
    n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, vocab_size=512,
    slstm_layers=(1,), max_seq_len=128,
)
