"""Model / shape configuration dataclasses shared by the whole framework."""
from __future__ import annotations

import dataclasses
from typing import Literal, Sequence


def pad_to(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture. Field defaults follow the llama lineage."""

    name: str = "model"
    family: Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"] = "dense"

    n_layers: int = 4
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0          # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 32000
    vocab_pad_multiple: int = 256   # pad embedding rows for clean TP sharding

    activation: Literal["swiglu", "geglu", "relu2", "gelu"] = "swiglu"
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    rope_theta: float = 1e6
    norm_eps: float = 1e-6
    max_seq_len: int = 131072

    # --- MoE ---
    n_experts: int = 0
    n_experts_active: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_loss_coef: float = 0.01

    # --- SSM / recurrent families ---
    ssm_state: int = 64
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv_width: int = 4
    ssm_chunk: int = 256
    # xLSTM: indices of sLSTM blocks (rest are mLSTM)
    slstm_layers: Sequence[int] = ()

    # --- hybrid (zamba2): shared attention block applied every k-th layer ---
    shared_attn_every: int = 0   # 0 = no shared block
    shared_attn_lora_rank: int = 0

    # --- enc-dec (whisper) ---
    n_encoder_layers: int = 0
    encoder_seq: int = 1500      # precomputed frame embeddings (conv stub)

    # --- vlm (phi-3-vision) ---
    n_image_tokens: int = 0      # precomputed patch embeddings (CLIP stub)

    # numerics
    dtype: str = "bfloat16"

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def padded_vocab(self) -> int:
        return pad_to(self.vocab_size, self.vocab_pad_multiple)

    @property
    def is_encdec(self) -> bool:
        return self.n_encoder_layers > 0

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm" and self.shared_attn_every == 0

    def scaled(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned per architecture)."""

    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(runnable, reason). long_500k needs sub-quadratic context handling."""
    if shape.name == "long_500k":
        if cfg.family in ("ssm", "hybrid"):
            return True, "recurrent/hybrid: O(1)-state decode"
        return False, "pure full-attention arch: long_500k skipped (DESIGN §5)"
    return True, "ok"
