"""Architecture registry: one module per assigned architecture."""
from repro.configs.base import ModelConfig, ShapeConfig, SHAPES, shape_applicable

from repro.configs import (
    qwen3_1p7b, qwen2_72b, minitron_4b, yi_34b, xlstm_125m, dbrx_132b,
    qwen3_moe_30b_a3b, phi3_vision_4p2b, whisper_small, zamba2_7b, llama2_7b,
)

ARCHS: dict[str, ModelConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        qwen3_1p7b, qwen2_72b, minitron_4b, yi_34b, xlstm_125m, dbrx_132b,
        qwen3_moe_30b_a3b, phi3_vision_4p2b, whisper_small, zamba2_7b,
        llama2_7b,
    )
}

SMOKE: dict[str, ModelConfig] = {
    m.CONFIG.name: m.SMOKE for m in (
        qwen3_1p7b, qwen2_72b, minitron_4b, yi_34b, xlstm_125m, dbrx_132b,
        qwen3_moe_30b_a3b, phi3_vision_4p2b, whisper_small, zamba2_7b,
        llama2_7b,
    )
}

ASSIGNED = [n for n in ARCHS if n != "llama2-7b"]

# canonical representative arch per model family (smoke-testable via
# SMOKE[...]); the adapter-registry tests and examples iterate this
FAMILY_REPRESENTATIVE: dict[str, str] = {
    "dense": "llama2-7b",
    "moe": "qwen3-moe-30b-a3b",
    "vlm": "phi-3-vision-4.2b",
    "ssm": "xlstm-125m",
    "hybrid": "zamba2-7b",
    "audio": "whisper-small",
}


def get(name: str) -> ModelConfig:
    return ARCHS[name]
