"""llama2-7b: the paper's primary evaluation model (GPTVQ Tables 1/2/6-11)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama2-7b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=32, head_dim=128,
    d_ff=11008, vocab_size=32000,
    activation="swiglu", rope_theta=1e4,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, max_seq_len=128,
)
