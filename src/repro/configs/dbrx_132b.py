"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752
vocab=100352, MoE 16 experts top-4 fine-grained. [hf:databricks/dbrx-base]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=10752, vocab_size=100352,
    n_experts=16, n_experts_active=4,
    activation="swiglu", rope_theta=5e5,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
    d_ff=96, vocab_size=512, n_experts=4, n_experts_active=2, max_seq_len=128,
)
