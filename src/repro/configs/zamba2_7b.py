"""zamba2-7b [hybrid]: 81L d_model=3584 32H (kv=32) d_ff=14336 ssm_state=64
— Mamba2 trunk + shared attention block. [arXiv:2411.15242; unverified]

n_layers rounded 81 -> 78 so the trunk scans uniformly as 13 groups of 6
mamba layers, each preceded by the shared-attn invocation (DESIGN.md §5).
d_ff is unused by mamba blocks (kept for the record)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid",
    n_layers=78, d_model=3584, n_heads=32, n_kv_heads=32, head_dim=112,
    d_ff=14336, vocab_size=32000,
    ssm_state=64, ssm_head_dim=64, ssm_expand=2,
    shared_attn_every=6, shared_attn_lora_rank=64,
    rope_theta=1e4,
)

SMOKE = CONFIG.scaled(
    n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=32,
    d_ff=128, vocab_size=512, ssm_state=16, ssm_head_dim=16,
    shared_attn_every=2, shared_attn_lora_rank=8, max_seq_len=128,
)
