"""whisper-small [audio]: 12L enc + 12L dec, d_model=768 12H (kv=12)
d_ff=3072 vocab=51865 — enc-dec, conv frontend stubbed (precomputed frame
embeddings). [arXiv:2212.04356; unverified]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small", family="audio",
    n_layers=12, n_encoder_layers=12, d_model=768, n_heads=12, n_kv_heads=12,
    d_ff=3072, vocab_size=51865, encoder_seq=1500,
    activation="gelu", qkv_bias=True, tie_embeddings=True,
    max_seq_len=32768,
)

SMOKE = CONFIG.scaled(
    n_layers=2, n_encoder_layers=2, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=512, encoder_seq=32, max_seq_len=128,
)
