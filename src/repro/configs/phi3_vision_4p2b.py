"""phi-3-vision-4.2b [vlm]: 32L d_model=3072 32H (MHA kv=32) d_ff=8192
vocab=32064 — phi3-mini backbone + CLIP stub (precomputed patch embeds).
[hf:microsoft/Phi-3-vision-128k-instruct; hf]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", family="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    n_image_tokens=1024,
    activation="swiglu", rope_theta=1e4,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
    d_ff=128, vocab_size=512, n_image_tokens=16, max_seq_len=128,
)
