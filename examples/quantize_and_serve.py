"""End-to-end driver (deliverable b): train a small LM, GPTVQ-quantize it
post-training, and serve batched requests with the SAME engine for bf16 and
VQ-compressed weights — the paper's deployment story in one script.

The quantizer is family-agnostic (core/adapters/): pass --family to also
run the identical `quantize_model` call on a non-transformer architecture
(ssm/xlstm, hybrid mamba+attention, audio enc-dec, moe, vlm) and report
its packed-vs-fp perplexity.

The VQ serving passes run twice: once on the portable gather path
(densify per layer inside the forward) and once with
``--vq-matmul-impl fused`` — the fused VQ-dequant matmul serving path
(Pallas kernel on TPU, prep-folded XLA oracle elsewhere), token-identical
greedy outputs.

Telemetry rides along for free (PR 7): every Engine carries an obs/
Telemetry bundle, so each serving pass below also reports **TTFT**
(time to first token, measured from *enqueue* — queue wait counts, and
the first request of a cold engine pays jit compile) and **ITL**
(inter-token latency: mean gap between consecutive decoded tokens,
undefined for single-token requests), drained per request via
``eng.drain_request_records()``. The decode host/device split comes
from the ``span.decode_tick/host_prep`` and ``span.decode_tick/device``
histograms (the device span closes at the tick's token download — jax
dispatch is async, so "device" reads as dispatch + device wait). The
quantization calls report per-stage wall seconds
(``report.stage_seconds``: hessian_capture / em_init / column_sweep /
codebook_update / advance — EM codebook init is timed separately from
the sweep). The same
data streams to files on the launchers: ``--events-out`` (JSONL
lifecycle events), ``--metrics-out`` (snapshot), ``--trace-dir``
(jax.profiler traces) on ``repro.launch.serve`` /
``repro.launch.quantize``.

Run: PYTHONPATH=src python examples/quantize_and_serve.py [--steps 200]
     [--family ssm] [--vq-matmul-impl fused]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FAMILY_REPRESENTATIVE as FAMILY_ARCH, SMOKE
from repro.configs.base import ModelConfig
from repro.core import adapters
from repro.core.bpv import PAPER_SETTINGS, VQConfig
from repro.core.pipeline import quantize_model
from repro.core.recipe import KeepDense, QuantRecipe, Quantize, Rule
from repro.data.synthetic import SyntheticStream, sample_batch
from repro.models import model_zoo
from repro.serve.engine import Engine, Request
from repro.train import optimizer as opt
from repro.train.loss import perplexity
from repro.train.train_step import init_state, make_train_step

def quantize_other_family(family: str):
    """Same quantize_model call, different architecture family."""
    cfg = SMOKE[FAMILY_ARCH[family]].scaled(dtype="float32")
    print(f"== GPTVQ on the {family} family ({cfg.name} smoke config) ==")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 32, 8)
    vq_cfg = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=10,
                      codebook_update_iters=5)
    t0 = time.time()
    qparams, rep = quantize_model(model, params, calib, "gptvq", vq_cfg,
                                  pack=True)
    heldout = sample_batch(jax.random.PRNGKey(4), cfg.vocab_size, 32, 4)
    extras = adapters.calib_extras(cfg, heldout)
    ppl_fp = perplexity(model, params, heldout, batch_extra=extras)
    ppl_vq = perplexity(model, qparams, heldout, batch_extra=extras)
    print(f"  {len(rep.per_layer)} blocks in {time.time()-t0:.1f}s at "
          f"{rep.bits_per_value:.3f} bpv | recon err {rep.total_error():.3f}"
          f" | ppl fp={ppl_fp:.2f} vq={ppl_vq:.2f}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--family", default=None, choices=sorted(FAMILY_ARCH),
                    help="also quantize a smoke config from this family "
                         "through the same adapter-registry pipeline")
    ap.add_argument("--kv-cache-bits", default=8,
                    type=lambda s: s if s == "vq2" else int(s),
                    choices=[16, 8, 4, "vq2"],
                    help="page storage for the quantized-KV serving pass: "
                         "int8/int4 pages dequantized on the fly, or vq2 "
                         "(packed 4-bit codebook indices over d=2 head-dim "
                         "vectors — the paper's dimensionality thesis "
                         "applied to the cache; codebooks EM-calibrated "
                         "at engine load, then frozen)")
    ap.add_argument("--vq-matmul-impl", default="fused",
                    choices=["gather", "fused", "xla", "pallas"],
                    help="VQ weight execution for the fused serving pass: "
                         "gather = densify per layer inside the forward; "
                         "fused = the fused dequant-matmul path (Pallas "
                         "kernel on TPU, prep-folded XLA oracle elsewhere)")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="serve-demo", family="dense", n_layers=4, d_model=args.d_model,
        n_heads=4, n_kv_heads=2, head_dim=args.d_model // 4, d_ff=args.d_model * 3,
        vocab_size=2048, max_seq_len=256, dtype="float32",
        vocab_pad_multiple=64)
    model = model_zoo.build(cfg)

    print(f"== training {model_zoo.count_params(model)/1e6:.1f}M param LM "
          f"for {args.steps} steps ==")
    ocfg = opt.OptConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
    state = init_state(model, jax.random.PRNGKey(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg))
    stream = SyntheticStream(cfg.vocab_size, seq_len=64, global_batch=16)
    for i in range(args.steps):
        state, metrics = step(state, {"tokens": stream.next()})
        if (i + 1) % 50 == 0:
            print(f"  step {i+1}: loss={float(metrics['loss']):.3f}")

    heldout = sample_batch(jax.random.PRNGKey(7), cfg.vocab_size, 64, 8)
    ppl_fp = perplexity(model, state.params, heldout)
    print(f"  fp32 perplexity: {ppl_fp:.2f}")

    print("== GPTVQ post-training quantization (2D, 2.25 bpv) ==")
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 64, 16)
    vq_cfg = VQConfig(d=2, bits_per_dim=2, group_size=1024, em_iters=30,
                      codebook_update_iters=15)
    t0 = time.time()
    qparams, report = quantize_model(model, state.params, calib, "gptvq",
                                     vq_cfg, pack=True)
    print(f"  quantized in {time.time()-t0:.1f}s at "
          f"{report.bits_per_value:.3f} bits/value")
    stages = sorted(report.stage_seconds.items(), key=lambda kv: -kv[1])
    print("  stage breakdown: " + " ".join(f"{k}={v:.1f}s"
                                           for k, v in stages))
    ppl_vq = perplexity(model, qparams, heldout)
    print(f"  VQ perplexity: {ppl_vq:.2f} (fp32 {ppl_fp:.2f})")

    print("== mixed QuantRecipe: attn 2D@2b, mlp 1D@4b, layer-0 wq dense ==")
    recipe = QuantRecipe(
        rules=(
            Rule("layers.0.attn.wq", KeepDense("demo: named target")),
            Rule("group:attn", Quantize(PAPER_SETTINGS["2.25bpv_2d"])),
            Rule("group:mlp", Quantize(PAPER_SETTINGS["4.125bpv_1d"])),
        ),
        default=Quantize(PAPER_SETTINGS["2.25bpv_2d"]), name="mixed-demo",
    ).with_quantize_overrides(em_iters=30, codebook_update_iters=15)
    qparams_mix, rep_mix = quantize_model(model, state.params, calib,
                                          recipe=recipe, pack=True)
    ppl_mix = perplexity(model, qparams_mix, heldout)
    mix = sorted({(e.get("d"), e.get("bits_per_dim"))
                  for e in rep_mix.per_target.values()
                  if e["action"] == "quantize"})
    print(f"  {rep_mix.achieved_bpv:.3f} bpv achieved | settings (d,b): "
          f"{mix} | ppl {ppl_mix:.2f} | dense: "
          f"{[k for k, e in rep_mix.per_target.items() if e['action'] == 'keep_dense']}")

    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=8 + i % 5) for i in range(6)]
    # the third pass serves the SAME packed checkpoint through the fused
    # VQ-dequant matmul path (Engine preps VQLinear -> FusedVQLinear once
    # at load; greedy outputs are token-identical to the gather pass)
    passes = (("bf16/fp32", state.params, "gather"),
              ("gptvq-packed", qparams, "gather"),
              (f"gptvq-{args.vq_matmul_impl}", qparams,
               args.vq_matmul_impl))
    for tag, params, vq_impl in passes:
        print(f"== serving 6 batched requests [{tag}] ==")
        eng = Engine(model, params, max_batch=4, max_len=128,
                     vq_matmul_impl=vq_impl)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=16)
                for i, p in enumerate(prompts)]
        eng.run(reqs)
        print(f"  {eng.stats['tokens']} tokens in {eng.stats['wall_s']:.2f}s "
              f"({eng.stats['decode_ticks']} ticks); "
              f"sample: {reqs[0].out_tokens[:8]}")
        # per-request telemetry: TTFT counts queue wait (and, on the
        # first pass of a cold engine, jit compile); ITL is the mean
        # inter-token gap once decoding starts
        recs = eng.drain_request_records()
        ttfts = sorted(r.ttft_s for r in recs if r.ttft_s is not None)
        itls = [r.itl_mean_s for r in recs if r.itl_mean_s is not None]
        snap = eng.telemetry.registry.snapshot()
        host = snap.get("span.decode_tick/host_prep", {}).get("sum", 0.0)
        dev = snap.get("span.decode_tick/device", {}).get("sum", 0.0)
        frac = dev / (host + dev) if host + dev else 0.0
        print(f"  TTFT med={1e3*ttfts[len(ttfts)//2]:.0f}ms "
              f"worst={1e3*ttfts[-1]:.0f}ms | "
              f"ITL mean={1e3*np.mean(itls):.1f}ms/tok | "
              f"decode device frac {frac:.2f} "
              f"(device span = dispatch + device wait)")

    # low-bit KV pages: the SAME engine + VQ-packed weights, but the paged
    # KV pool stores int8 (or packed-int4) code pages with per-row scales
    # that every read path dequantizes on the fly — at a fixed pool byte
    # budget the allocator exposes the extra pages directly
    from repro.models.attention import PagedLayout
    from repro.serve.paged_cache import pool_bytes_of
    bits = args.kv_cache_bits
    print(f"== serving with --kv-cache-bits {bits} "
          f"[gptvq-packed weights + quantized KV pages] ==")
    # the budget an fp32-cache engine's default pool would cost (pure
    # layout arithmetic — no engine/pool allocation needed for sizing)
    mb, max_len, page_size = 4, 128, 16
    fp_blocks = mb * (-(-max_len // page_size)) + 1
    budget = pool_bytes_of(cfg, PagedLayout(fp_blocks, page_size),
                           jnp.float32)
    eng = Engine(model, qparams, max_batch=mb, max_len=max_len,
                 page_size=page_size, kv_cache_bits=bits,
                 pool_bytes=budget)
    reqs = [Request(rid=100 + i, prompt=p, max_new_tokens=16)
            for i, p in enumerate(prompts)]
    eng.run(reqs)
    fp_pages = fp_blocks - 1
    headroom = eng.scheduler.allocator.capacity / fp_pages
    tag = bits if bits == "vq2" else f"kv{bits}"
    print(f"  {eng.stats['tokens']} tokens in {eng.stats['wall_s']:.2f}s; "
          f"sample: {reqs[0].out_tokens[:8]}")
    print(f"  fixed {budget} B/layer pool: {fp_pages} fp32 pages -> "
          f"{eng.scheduler.allocator.capacity} {tag} pages "
          f"({headroom:.1f}x{'; codebook bytes charged off the top' if bits == 'vq2' else ''})")
    # prefix sharing + forked parallel sampling (PR 8): requests that open
    # with the same system-prompt header share its KV pages through the
    # radix prefix cache (refcounted copy-on-write page tables) — warm
    # admissions skip every fully-shared page's prefill — and Request(n=)
    # forks n parallel samples off one prompt's blocks. The launchers
    # expose both as --prefix-cache on and --parallel-n N.
    print("== prefix sharing: 6 requests behind one 48-token header ==")
    header = rng.randint(0, cfg.vocab_size, size=48)
    shared = [np.concatenate([header,
                              rng.randint(0, cfg.vocab_size, size=4 + i)])
              for i in range(6)]
    eng = Engine(model, qparams, max_batch=4, max_len=128, page_size=16,
                 prefix_cache=True)
    reqs = [Request(rid=200 + i, prompt=p, max_new_tokens=16)
            for i, p in enumerate(shared)]
    eng.run(reqs)
    s = eng.stats
    print(f"  {s['tokens']} tokens in {s['wall_s']:.2f}s | "
          f"{s['prefix_hits']} prefix hits / {s['prefix_misses']} misses: "
          f"{s['prefix_hit_tokens']} prompt tokens served from shared "
          f"pages instead of re-prefilling "
          f"({s['prefix_cached_blocks']} blocks cached)")
    par = Request(rid=300, prompt=shared[0], max_new_tokens=16, n=3)
    eng.run([par])
    assert all(c.out_tokens == par.out_tokens for c in par.forks)
    print(f"  Request(n=3): parent + {len(par.forks)} forks off the same "
          f"prompt blocks, greedy-identical: {par.out_tokens[:6]}...")
    print("done — same engine, 7x smaller weight payload with VQ, "
          f"{headroom:.1f}x KV pages per byte with quantized pages, and "
          "shared-prefix prompts admitted without re-prefill.")
    if args.family:
        quantize_other_family(args.family)


if __name__ == "__main__":
    main()
