"""Fault-tolerant training example: supervised loop with checkpointing,
straggler monitoring, and simulated failure + restart (runtime/ layer).

Run: PYTHONPATH=src python examples/train_tiny.py
"""
import os
import tempfile

import jax

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs.base import ModelConfig
from repro.data.synthetic import SyntheticStream
from repro.models import model_zoo
from repro.runtime import fault_tolerance as ft
from repro.train import optimizer as opt
from repro.train.train_step import init_state, make_train_step


def main():
    cfg = ModelConfig(name="tiny", family="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, head_dim=16, d_ff=192,
                      vocab_size=512, dtype="float32", vocab_pad_multiple=64)
    model = model_zoo.build(cfg)
    ocfg = opt.OptConfig(lr=3e-3, warmup_steps=5, total_steps=60)
    state = init_state(model, jax.random.PRNGKey(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg, microbatches=2))
    stream = SyntheticStream(cfg.vocab_size, seq_len=32, global_batch=8)

    crashed = {"done": False}

    def step_fn(state, i):
        if i == 25 and not crashed["done"]:
            crashed["done"] = True
            raise RuntimeError("simulated node failure at step 25")
        batch = {"tokens": stream.next()}
        state, metrics = step(state, batch)
        if i % 10 == 0:
            print(f"  step {i}: loss={float(metrics['loss']):.3f}")
        return state

    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=3, async_save=True)
        res = ft.supervise(
            state=state, step_fn=step_fn, ckpt=ck, total_steps=60,
            checkpoint_every=10, heartbeat_path=os.path.join(d, "hb.json"))
        print(f"finished {res.steps_done} steps with {res.restarts} restart(s)"
              f" — training survived the failure.")


if __name__ == "__main__":
    main()
