"""Quickstart: GPTVQ-quantize a weight matrix and inspect the result.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import hessian as hes
from repro.core.bpv import VQConfig
from repro.core import vq_linear
from repro.core.gptvq import gptvq_quantize_matrix, layer_error
from repro.core.quant import rtn_quantize


def main():
    key = jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    # a weight matrix (out=256, in=512) and correlated calibration inputs
    W = jax.random.normal(k1, (256, 512))
    X = jax.random.normal(k2, (4096, 512))
    X = X.at[:, :64].mul(4.0)  # some input dims matter more (realistic)

    H = hes.finalize(hes.accumulate(hes.init_hessian(512), X))
    U = hes.inv_hessian_cholesky(H)

    # paper setting: 2D VQ, 2 bits/dim, int8 codebooks, 2.25 bpv total
    cfg = VQConfig(d=2, bits_per_dim=2, group_size=1024, em_iters=50,
                   codebook_update_iters=25)
    res = gptvq_quantize_matrix(W, U, cfg)
    print(f"GPTVQ @ {cfg.bits_per_value} bpv")
    print(f"  layer error (tr EHE^T): {float(layer_error(W, res.arrays.Q, H)):.4f}")

    Q_rtn = rtn_quantize(W, bits=2, group_size=64)  # same 2.25 bpv budget
    print(f"  RTN 2b@g64 layer error: {float(layer_error(W, Q_rtn, H)):.4f}")

    vql = vq_linear.quantize_array(W, H, cfg)
    n = W.size
    print(f"  packed size: {vql.payload_bytes()} bytes "
          f"({vql.payload_bytes() * 8 / n:.3f} bits/value vs 32 fp32)")
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 512))
    y = vq_linear.apply(vql, x, dtype=jnp.float32)
    y_ref = x @ W.T
    rel = float(jnp.linalg.norm(y - y_ref) / jnp.linalg.norm(y_ref))
    print(f"  matmul relative error through packed path: {rel:.4f}")


if __name__ == "__main__":
    main()
