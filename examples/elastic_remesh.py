"""Elastic re-mesh example: plan a production mesh, lose devices, re-plan,
and reshard a parameter tree onto the degraded mesh (single-host demo of
runtime/elastic.py using however many devices jax exposes).

Run: PYTHONPATH=src python examples/elastic_remesh.py
"""
import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.runtime import elastic


def main():
    n = len(jax.devices())
    print(f"devices available: {n}")
    plan = elastic.plan_mesh(n, model_parallel=2, pods=1)
    print(f"initial plan: {plan}")
    mesh = elastic.build_mesh(plan)

    params = {"w": jnp.arange(64.0).reshape(8, 8), "b": jnp.ones(8)}
    specs = {"w": P("data", "model"), "b": P(None)}
    sharded = elastic.reshard(params, specs, mesh)
    print("initial sharding:", sharded["w"].sharding)

    # lose 2 devices -> re-plan, rebuild, reshard (restore path would reload
    # the latest checkpoint; here we reuse the live values)
    plan2 = elastic.degrade_plan(plan, 2)
    print(f"after losing 2 devices: {plan2} (spares={plan2.spares})")
    mesh2 = elastic.build_mesh(plan2)
    resharded = elastic.reshard(params, specs, mesh2)
    print("new sharding:", resharded["w"].sharding)
    assert jnp.allclose(resharded["w"], params["w"])
    print("values preserved across re-mesh — elastic path OK.")


if __name__ == "__main__":
    main()
