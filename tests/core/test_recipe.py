"""QuantRecipe semantics and the recipe-driven pipeline.

Covers: first-match-wins resolution, group: patterns, strict-mode
unmatched errors, JSON round-trip, legacy-kwarg shim bitwise equivalence,
adapter-declared keep_dense surfacing (sLSTM r_*), shape-aware bpv
accounting, the Hessian-budget allocator's ceiling, and a mixed recipe's
quantize -> pack -> checkpoint -> serve round trip on dense and hybrid.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import FAMILY_REPRESENTATIVE as FAMILY_ARCH, SMOKE
from repro.configs.base import ModelConfig
from repro.core import vq_linear as vql
from repro.core.bpv import PAPER_SETTINGS, VQConfig, effective_bpv
from repro.core.pipeline import quantize_model
from repro.core.recipe import (
    IntQuant,
    KeepDense,
    QuantRecipe,
    Quantize,
    RecipeError,
    Rule,
    TargetInfo,
    get_recipe,
)
from repro.data.synthetic import sample_batch
from repro.models import model_zoo
from repro.serve.engine import Engine, Request

VQ_TINY = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=4,
                   codebook_update_iters=2)


def _tiny(setting: str) -> Quantize:
    return Quantize(dataclasses.replace(
        PAPER_SETTINGS[setting], em_iters=4, codebook_update_iters=0))


def _targets(*names, group="attn", default=None):
    return [TargetInfo(name=n, group=group, r=64, c=64, numel=4096,
                       default_action=default) for n in names]


def _dense_model():
    cfg = ModelConfig(
        name="recipe-t", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=256,
        max_seq_len=128, dtype="float32", vocab_pad_multiple=64)
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 16, 4)
    return cfg, model, params, calib


# ---------------------------------------------------------------------------
# resolution semantics (no model needed)
# ---------------------------------------------------------------------------

def test_first_match_wins():
    rec = QuantRecipe(rules=(
        Rule("layers.0.attn.*", KeepDense("first")),
        Rule("layers.*.attn.*", _tiny("2.25bpv_2d")),
    ))
    plan = rec.resolve(_targets("layers.0.attn.wq", "layers.1.attn.wq"))
    assert isinstance(plan["layers.0.attn.wq"].action, KeepDense)
    assert plan["layers.0.attn.wq"].action.reason == "first"
    assert isinstance(plan["layers.1.attn.wq"].action, Quantize)
    assert plan["layers.1.attn.wq"].rule.startswith("rule[1]:")


def test_group_pattern_matches_spec_group():
    rec = QuantRecipe(rules=(Rule("group:mlp", IntQuant(4, 128)),),
                      default=_tiny("2.25bpv_2d"))
    plan = rec.resolve(
        _targets("layers.0.attn.wq")
        + _targets("layers.0.ffn.w_in", group="mlp"))
    assert isinstance(plan["layers.0.ffn.w_in"].action, IntQuant)
    assert plan["layers.0.attn.wq"].rule == "default"


def test_strict_mode_unmatched_target_errors():
    rec = QuantRecipe(rules=(Rule("layers.0.*", _tiny("2.25bpv_2d")),),
                      default=None, strict=True)
    with pytest.raises(RecipeError, match="layers.1.attn.wq"):
        rec.resolve(_targets("layers.0.attn.wq", "layers.1.attn.wq"))
    # adapter-declared defaults are explicit exclusions, not misses
    plan = rec.resolve(
        _targets("layers.0.attn.wq")
        + _targets("layers.1.core.r_z", default=KeepDense("no tap")))
    assert plan["layers.1.core.r_z"].rule == "adapter:no tap"


def test_adapter_default_yields_only_to_explicit_rules():
    """A by-name rule overrides an adapter-declared keep_dense; broad
    glob / group: patterns fall through to it (a blanket group:attn rule
    must not drag tap-less recurrent weights into quantization)."""
    target = _targets("layers.1.core.r_z", default=KeepDense("no tap"))
    exact = QuantRecipe(rules=(
        Rule("layers.1.core.r_z", _tiny("2.25bpv_2d")),))
    plan = exact.resolve(target)
    assert isinstance(plan["layers.1.core.r_z"].action, Quantize)
    for pattern in ("*.core.r_z", "group:attn", "layers.?.core.r_z"):
        broad = QuantRecipe(rules=(Rule(pattern, _tiny("2.25bpv_2d")),))
        plan = broad.resolve(target)
        assert isinstance(plan["layers.1.core.r_z"].action, KeepDense), \
            pattern
        assert plan["layers.1.core.r_z"].rule == "adapter:no tap"


def test_mixed_demo_preset_resolves_on_ssm():
    """The shipped mixed_demo preset must not crash on families with
    adapter-declared dense targets (3-D sLSTM r_* under group:attn)."""
    cfg = SMOKE[FAMILY_ARCH["ssm"]].scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    from repro.core import adapters
    from repro.core.pipeline import _check_plan, _collect_targets
    blocks = adapters.get_adapter(model, params).blocks()
    plan = get_recipe("mixed_demo").resolve(_collect_targets(blocks))
    _check_plan(blocks, plan)  # must not raise on the 3-D r_* leaves
    assert isinstance(plan["layers.1.core.r_z"].action, KeepDense)
    assert isinstance(plan["layers.0.core.w_i"].action, KeepDense)


def test_json_roundtrip_and_presets():
    rec = QuantRecipe(
        rules=(
            Rule("group:attn", Quantize(PAPER_SETTINGS["2.125bpv_2d"])),
            Rule("group:mlp", IntQuant(4, 128, method="rtn")),
            Rule("layers.0.ffn.w_in", KeepDense("ablation")),
        ),
        default=Quantize(PAPER_SETTINGS["2.25bpv_2d"]), name="rt")
    assert QuantRecipe.from_json(rec.to_json()) == rec
    with pytest.raises(RecipeError):
        QuantRecipe.from_json({"rules": [{"pattern": "*", "action": "zap"}]})
    with pytest.raises(RecipeError):  # unknown override field
        QuantRecipe.from_json({"rules": [
            {"pattern": "*", "action": "quantize",
             "overrides": {"em_itres": 3}}]})
    mixed = get_recipe("mixed_demo")
    assert any(r.pattern == "group:attn" for r in mixed.rules)
    assert get_recipe("2.25bpv_2d").default.cfg == PAPER_SETTINGS["2.25bpv_2d"]
    # omitting "default" never silently quantizes unmatched targets
    nod = QuantRecipe.from_json(
        {"rules": [{"pattern": "layers.0.*", "action": "keep_dense"}]})
    assert nod.default is None
    with pytest.raises(RecipeError, match="no default"):
        nod.resolve(_targets("layers.1.attn.wq"))


def test_effective_bpv_accounts_for_small_tensors():
    cfg = PAPER_SETTINGS["2.25bpv_2d"]
    # big matrix amortizes the codebook to the nominal figure
    assert effective_bpv(cfg, 4096, 4096) == pytest.approx(
        cfg.bits_per_value)
    # a 64x64 tensor cannot amortize a 4D/32768-group codebook
    cfg4 = PAPER_SETTINGS["2.25bpv_4d"]
    assert effective_bpv(cfg4, 64, 64) > cfg4.bits_per_value + 1.0


# ---------------------------------------------------------------------------
# pipeline integration
# ---------------------------------------------------------------------------

def test_legacy_kwargs_shim_bitwise_identical():
    """The deprecated (method, cfg, quantize_mlp=...) surface must produce
    bitwise-identical packed params to the recipe it compiles to."""
    _, model, params, calib = _dense_model()
    with pytest.deprecated_call():
        qp_old, rep_old = quantize_model(
            model, params, calib, "gptvq", VQ_TINY, pack=True, chunk=4,
            seed=3, quantize_mlp=False)
    qp_new, rep_new = quantize_model(
        model, params, calib, pack=True, chunk=4, seed=3,
        recipe=QuantRecipe.from_legacy("gptvq", VQ_TINY,
                                       quantize_mlp=False))
    old, new = jax.tree.leaves(qp_old), jax.tree.leaves(qp_new)
    assert len(old) == len(new)
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(old, new))
    # legacy bpv accounting is preserved; both report the same per-target
    assert rep_old.bits_per_value == pytest.approx(VQ_TINY.bits_per_value)
    assert rep_old.per_target.keys() == rep_new.per_target.keys()
    kd = [k for k, v in rep_old.per_target.items()
          if v["action"] == "keep_dense"]
    assert kd and all(".ffn." in k for k in kd)


def test_mixed_recipe_per_target_report():
    """Different settings for attn vs mlp + a named keep_dense target all
    show up (with rule provenance) in QuantizeReport.per_target."""
    _, model, params, calib = _dense_model()
    rec = QuantRecipe(
        rules=(
            Rule("layers.1.ffn.w_out", KeepDense("ablation")),
            Rule("group:attn", _tiny("2.25bpv_2d")),
            Rule("group:mlp", _tiny("4.125bpv_1d")),
        ), default=_tiny("2.25bpv_2d"), name="mixed")
    qp, rep = quantize_model(model, params, calib, recipe=rec, pack=True,
                             chunk=4)
    pt = rep.per_target
    assert pt["layers.1.ffn.w_out"]["action"] == "keep_dense"
    assert pt["layers.1.ffn.w_out"]["rule"] == "rule[0]:layers.1.ffn.w_out"
    assert pt["layers.0.attn.wq"]["d"] == 2
    assert pt["layers.0.ffn.w_in"]["d"] == 1
    assert pt["layers.0.ffn.w_in"]["bits_per_dim"] == 4
    assert rep.achieved_bpv == pytest.approx(
        sum(e["numel"] * e["bpv"] for e in pt.values())
        / sum(e["numel"] for e in pt.values()))
    # packed leaves record the rule that produced them
    layer0 = qp["layers"][0] if isinstance(qp["layers"], list) else \
        jax.tree.map(lambda a: a[0], qp["layers"])
    wq = layer0["attn"]["wq"]
    assert isinstance(wq, vql.VQLinear)
    assert wq.rule == "rule[1]:group:attn"
    # the named target stayed dense
    w_out1 = (qp["layers"][1] if isinstance(qp["layers"], list)
              else jax.tree.map(lambda a: a[1], qp["layers"]))["ffn"]["w_out"]
    assert not isinstance(w_out1, vql.VQLinear)


def test_legacy_kmeans_default_cfg_is_vq():
    """method="kmeans"/cfg=None must default to a VQConfig (regression:
    it got the int-quant dict and crashed in bpv accounting)."""
    cfg = ModelConfig(
        name="km-t", family="dense", n_layers=1, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=256,
        max_seq_len=128, dtype="float32", vocab_pad_multiple=64)
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 16, 2)
    _, rep = quantize_model(model, params, calib, "kmeans", chunk=2)
    assert rep.bits_per_value == pytest.approx(VQConfig().bits_per_value)


def test_maybe_stack_blocks_provenance_semantics():
    """Provenance-only divergence unifies to 'mixed' and stacks; genuine
    metadata divergence keeps the ORIGINAL per-leaf rules in the list."""
    import jax.numpy as jnp
    from repro.core.adapters.base import maybe_stack_blocks

    def leaf(k, rule):
        return vql.VQLinear(
            words=jnp.zeros((4, 2), jnp.uint32),
            codebooks=jnp.zeros((1, 1, k, 2), jnp.int8),
            cb_scale=jnp.ones((1, 1)), scale_sint=jnp.zeros((1, 4, 1),
                                                            jnp.int8),
            scale_a=jnp.zeros((1,)), scale_z=jnp.zeros((1,)),
            r=4, c=8, d=2, k=k, group_cols=8, rows_per_band=4, rule=rule)

    stacked = maybe_stack_blocks([{"w": leaf(16, "rule[0]:x")},
                                  {"w": leaf(16, "default")}])
    assert not isinstance(stacked, list)
    assert stacked["w"].rule == "mixed"
    hetero = maybe_stack_blocks([{"w": leaf(16, "budget[a]")},
                                 {"w": leaf(4, "budget[b]")}])
    assert isinstance(hetero, list)
    assert [b["w"].rule for b in hetero] == ["budget[a]", "budget[b]"]


def test_strict_recipe_rejects_default():
    with pytest.raises(RecipeError, match="cannot carry a default"):
        QuantRecipe(rules=(), default=_tiny("2.25bpv_2d"), strict=True)


def test_rule_provenance_alone_does_not_break_stacking():
    """A by-name rule whose action equals the default must not force the
    list-of-layers fallback: rules are unified to 'mixed' and the stack
    stays scannable."""
    _, model, params, calib = _dense_model()
    act = _tiny("2.25bpv_2d")
    rec = QuantRecipe(rules=(Rule("layers.0.attn.wq", act),),
                      default=act, name="same-action")
    qp, _ = quantize_model(model, params, calib, recipe=rec, pack=True,
                           chunk=4)
    assert not isinstance(qp["layers"], list), \
        "provenance-only divergence fell back to the slow list path"
    wq = jax.tree.map(lambda a: a[0], qp["layers"])["attn"]["wq"]
    assert wq.rule == "mixed"
    wk = jax.tree.map(lambda a: a[0], qp["layers"])["attn"]["wk"]
    assert wk.rule == "default"


def test_r_star_dense_exclusion_surfaces_in_report():
    cfg = SMOKE[FAMILY_ARCH["ssm"]].scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 8, 4)
    _, rep = quantize_model(model, params, calib, "gptvq", VQ_TINY, chunk=4)
    r_targets = {k: v for k, v in rep.per_target.items()
                 if ".core.r_" in k}
    assert r_targets, "sLSTM r_* no longer surfaced"
    for v in r_targets.values():
        assert v["action"] == "keep_dense"
        assert "lagged hidden states" in v["reason"]
        assert v["rule"].startswith("adapter:")


def test_budget_allocation_respects_ceiling_and_beats_uniform():
    """--budget-bpv 2.5: model-wide achieved bpv <= budget, allocation is
    non-uniform, and total reconstruction error beats uniform 2.25bpv_2d."""
    _, model, params, calib = _dense_model()
    base = dataclasses.replace(PAPER_SETTINGS["2.25bpv_2d"], em_iters=6,
                               codebook_update_iters=2)
    qp, rep = quantize_model(
        model, params, calib, recipe=QuantRecipe.uniform(base),
        budget_bpv=2.5, pack=True, chunk=4, seed=1)
    assert rep.achieved_bpv <= 2.5 + 1e-9
    settings = {(e["d"], e["bits_per_dim"], e["group_size"])
                for e in rep.per_target.values()
                if e["action"] == "quantize"}
    assert len(settings) > 1, "budget allocation degenerated to uniform"
    assert all(e["rule"].startswith("budget[")
               for e in rep.per_target.values()
               if e["action"] == "quantize")
    _, rep_uniform = quantize_model(
        model, params, calib, recipe=QuantRecipe.uniform(base), chunk=4,
        seed=1)
    assert rep.total_error() < rep_uniform.total_error(), (
        rep.total_error(), rep_uniform.total_error())


@pytest.mark.parametrize("family", ["dense", "hybrid"])
def test_mixed_recipe_roundtrip_checkpoint_serve(family, tmp_path):
    """Mixed recipe (attn 2D@2b vs mlp 1D@4b, keep_dense named target)
    round-trips quantize -> pack -> checkpoint -> engine serving."""
    cfg = SMOKE[FAMILY_ARCH[family]].scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 8, 4)
    named_dense = ("layers.0.attn.wq" if family == "dense"
                   else "mamba.0.0.mixer.in_proj")
    rec = QuantRecipe(
        rules=(
            Rule(named_dense, KeepDense("round-trip ablation")),
            Rule("group:attn", _tiny("2.25bpv_2d")),
            Rule("group:mlp", _tiny("4.125bpv_1d")),
        ), default=_tiny("2.25bpv_2d"), name="mixed-rt")
    qp, rep = quantize_model(model, params, calib, recipe=rec, pack=True,
                             chunk=4)
    assert rep.per_target[named_dense]["action"] == "keep_dense"
    assert vql.tree_has_vq(qp)

    ck = Checkpointer(str(tmp_path), keep=1)
    ck.save(0, qp, metadata={"recipe": rep.recipe,
                             "per_target": rep.per_target,
                             "achieved_bpv": rep.achieved_bpv})
    restored, meta = ck.restore(qp)
    assert meta["recipe"]["name"] == "mixed-rt"
    assert meta["per_target"][named_dense]["action"] == "keep_dense"
    for a, b in zip(jax.tree.leaves(qp), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    rng = np.random.RandomState(0)
    eng = Engine(model, restored, max_batch=2, max_len=64)
    reqs = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, size=6),
                    max_new_tokens=4) for i in range(3)]
    eng.run(reqs)
    assert all(len(r.out_tokens) >= 4 for r in reqs)
