"""VQLinear packing / dequantization consistency with the quantizer output."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hessian as hes
from repro.core.bpv import VQConfig
from repro.core.codebook_compress import quantize_codebooks
from repro.core.gptvq import gptvq_quantize_matrix
from repro.core import vq_linear as vql_mod

from tests.core.test_quant_core import make_problem


@pytest.mark.parametrize(
    "d,b,gs,scale_block",
    [(1, 2, 256, 0), (2, 2, 2048, 0), (2, 3, 4096, 16), (4, 2, 4096, 0)],
)
def test_roundtrip_matches_reconstruction(d, b, gs, scale_block):
    W, X, H, U = make_problem(r=32, c=256)
    cfg = VQConfig(d=d, bits_per_dim=b, group_size=gs, em_iters=10,
                   scale_block=scale_block, codebook_update_iters=0)
    res = quantize_codebooks(gptvq_quantize_matrix(W, U, cfg))
    vql = vql_mod.from_vq_result(res)
    # unpack -> same indices
    np.testing.assert_array_equal(
        np.asarray(vql_mod.unpack_indices(vql)), np.asarray(res.arrays.indices)
    )
    # dequantize -> same fake-quantized weights (codebooks already int8)
    Wq = vql_mod.dequantize(vql, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(Wq), np.asarray(res.arrays.Q), rtol=2e-2, atol=2e-2
    )
    # matmul path agrees with dense
    x = jax.random.normal(jax.random.PRNGKey(0), (8, 256))
    y = vql_mod.apply(vql, x, dtype=jnp.float32)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x @ res.arrays.Q.T), rtol=3e-2, atol=3e-2
    )


def test_payload_bytes_matches_bpv():
    W, X, H, U = make_problem(r=64, c=512)
    cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, em_iters=5,
                   codebook_update_iters=0)
    res = quantize_codebooks(gptvq_quantize_matrix(W, U, cfg))
    vql = vql_mod.from_vq_result(res)
    n_weights = 64 * 512
    measured_bpv = vql.payload_bytes() * 8 / n_weights
    # measured includes fp32 codebook scales (small constant); nominal is 2.125
    assert measured_bpv < cfg.bits_per_value + 0.3, measured_bpv
    assert measured_bpv >= cfg.index_bits_per_value


def test_quantize_array_end_to_end():
    W, X, H, U = make_problem(r=32, c=256)
    cfg = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=10,
                   codebook_update_iters=5)
    vql = vql_mod.quantize_array(W, H, cfg)
    Wq = vql_mod.dequantize(vql, jnp.float32)
    # iid Gaussian weights are the VQ worst case (max entropy); ~0.24 rel
    # F-norm error at 3 bits/dim is in line with rate-distortion expectations
    rel = float(jnp.linalg.norm(Wq - W) / jnp.linalg.norm(W))
    assert rel < 0.3, rel
