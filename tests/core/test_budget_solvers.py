"""The production-throughput quantization path and pluggable solvers.

Covers: the O(c) diagonal-Hessian pre-pass (never materializes (c, c)),
the live-column damping fix in inv_hessian_cholesky, mesh-sharded Hessian
accumulation vs single-device (subprocess with forced host devices),
closed-form budget scoring vs the refit validation oracle, allocator
properties (ceiling, determinism, monotone upgrades) as plain seeded
loops, the solver knob (gptq/babai/cd) including default-path bitwise
identity, the pre-pass tap-miss warning fallback, and the em_init /
column_sweep stage split.
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import hessian as hes
from repro.core.bpv import PAPER_SETTINGS, VQConfig, effective_bpv
from repro.core.gptvq import gptvq_quantize_matrix, layer_error, plan_groups
from repro.core.recipe import (
    BUDGET_CANDIDATES,
    BudgetEntry,
    QuantRecipe,
    Quantize,
    RecipeError,
    Rule,
    allocate_budget,
    closed_form_proxy_error,
)
from repro.core.solvers import VALID_SOLVERS

jax.config.update("jax_enable_x64", False)


def _problem(r=64, c=128, seed=0):
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
    W = jax.random.normal(k1, (r, c)) * (1.0 + jax.random.uniform(k2, (r, 1)))
    A = jax.random.normal(k3, (c, c)) / np.sqrt(c)
    X = jax.random.normal(jax.random.PRNGKey(seed + 1), (256, c)) @ (
        jnp.eye(c) + 0.5 * A)
    H = hes.finalize(hes.accumulate(hes.init_hessian(c), X))
    return W, X, H


# ---------------------------------------------------------------------------
# damping regression
# ---------------------------------------------------------------------------

class TestDamping:
    def test_damp_divides_by_live_columns(self):
        """Regression: damping used to average the live diagonal over all
        c columns, so layers with many dead columns (unrouted MoE expert
        dims) were under-damped by the dead fraction. With a diagonal H
        the live columns decouple, so the live block of U must match the
        dense sub-problem exactly — which only holds when damp is
        normalized by the live count, not c."""
        c = 64
        live_diag = jnp.array([4.0, 2.0])
        H = jnp.zeros((c, c)).at[0, 0].set(4.0).at[1, 1].set(2.0)
        U = hes.inv_hessian_cholesky(H, percdamp=0.01)
        U_sub = hes.inv_hessian_cholesky(jnp.diag(live_diag), percdamp=0.01)
        np.testing.assert_allclose(np.asarray(U[:2, :2]),
                                   np.asarray(U_sub), rtol=1e-6)
        # pin the damp value itself: 0.01 * mean(live diag) = 0.03
        expected = 1.0 / jnp.sqrt(4.0 + 0.03)
        np.testing.assert_allclose(float(U[0, 0]), float(expected),
                                   rtol=1e-6)

    def test_mostly_dead_hessian_stays_finite(self):
        W, X, _ = _problem(32, 128, seed=3)
        mask = jnp.arange(128) < 12  # only 12 live columns
        H = hes.finalize(hes.accumulate(hes.init_hessian(128),
                                        X * mask[None, :]))
        U = hes.inv_hessian_cholesky(H)
        assert bool(jnp.all(jnp.isfinite(U)))
        cfg = VQConfig(d=2, bits_per_dim=2, group_size=4096, em_iters=4,
                       codebook_update_iters=0)
        res = gptvq_quantize_matrix(W, U, cfg)
        assert bool(jnp.all(jnp.isfinite(res.arrays.Q)))


# ---------------------------------------------------------------------------
# O(c) pre-pass
# ---------------------------------------------------------------------------

class TestDiagPrepass:
    def test_diag_accumulator_matches_full_diagonal(self):
        _, X, H = _problem()
        dstate = hes.accumulate_diag(hes.init_diag_hessian(X.shape[1]), X)
        np.testing.assert_allclose(np.asarray(hes.finalize_diag(dstate)),
                                   np.asarray(jnp.diagonal(H)), rtol=1e-4)

    def test_diag_state_is_o_c_by_shape(self):
        """eval_shape proves the accumulator's state and output stay (c,)
        even at 70B-class column counts — nothing (c, c) is ever built."""
        c = 28672
        state = hes.DiagHessianState(
            jax.ShapeDtypeStruct((c,), jnp.float32),
            jax.ShapeDtypeStruct((), jnp.int32))
        x = jax.ShapeDtypeStruct((8, 64, c), jnp.float32)
        out = jax.eval_shape(hes.accumulate_diag, state, x)
        assert out.diag.shape == (c,)
        assert max(a.size for a in jax.tree.leaves(out)) == c

    def test_budget_prepass_never_builds_full_hessian(self, monkeypatch):
        """The pre-pass runs entirely under diag_capture: patching the
        full-Hessian constructor to explode proves no code path in the
        budget pre-pass materializes (c, c)."""
        from repro.configs.base import ModelConfig
        from repro.core import adapters
        from repro.core.pipeline import _budget_prepass, _collect_targets
        from repro.data.synthetic import sample_batch
        from repro.models import model_zoo

        cfg = ModelConfig(
            name="prepass-t", family="dense", n_layers=1, d_model=64,
            n_heads=4, n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=256,
            max_seq_len=128, dtype="float32", vocab_pad_multiple=64)
        model = model_zoo.build(cfg)
        params = model.init_params(jax.random.PRNGKey(0))
        calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 8, 2)

        def boom(*a, **k):
            raise AssertionError("budget pre-pass materialized (c, c)")

        monkeypatch.setattr(hes, "init_hessian", boom)
        adapter = adapters.get_adapter(model, params)
        plan = QuantRecipe.uniform(PAPER_SETTINGS["2.25bpv_2d"]).resolve(
            _collect_targets(adapter.blocks()))
        diag, missed = _budget_prepass(adapter, [calib], plan, None)
        assert not missed
        assert diag and all(v.ndim == 1 for v in diag.values())


# ---------------------------------------------------------------------------
# mesh-parallel accumulation (subprocess: needs >1 host device)
# ---------------------------------------------------------------------------

MESH_SCRIPT = textwrap.dedent("""
    import jax, jax.numpy as jnp
    from repro.core import hessian as hes
    assert jax.device_count() >= 4, jax.device_count()
    mesh = jax.make_mesh((4,), ("data",))
    # 21 rows: not a multiple of 4, exercises the zero-pad path
    x = jax.random.normal(jax.random.PRNGKey(0), (21, 96))
    ref = hes.accumulate(hes.init_hessian(96), x)
    sh = hes.accumulate_sharded(hes.init_hessian(96), x, mesh)
    assert int(sh.n) == int(ref.n) == 21
    dmax = float(jnp.max(jnp.abs(sh.H - ref.H)))
    refd = hes.accumulate_diag(hes.init_diag_hessian(96), x)
    shd = hes.accumulate_sharded(hes.init_diag_hessian(96), x, mesh)
    dmax = max(dmax, float(jnp.max(jnp.abs(shd.diag - refd.diag))))
    assert int(shd.n) == 21
    print("MAXDIFF", dmax)
""")


class TestMeshAccumulation:
    def test_sharded_matches_single_device(self):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        env["PYTHONPATH"] = os.pathsep.join(
            [os.path.join(os.path.dirname(__file__), "..", "..", "src")]
            + env.get("PYTHONPATH", "").split(os.pathsep))
        proc = subprocess.run([sys.executable, "-c", MESH_SCRIPT], env=env,
                              capture_output=True, text=True, timeout=300)
        assert proc.returncode == 0, proc.stderr
        dmax = float(proc.stdout.split("MAXDIFF")[1])
        assert dmax < 1e-4, proc.stdout

    def test_sharded_single_device_mesh_inline(self):
        # degenerate 1-device mesh runs in-process on any host
        mesh = jax.make_mesh((1,), ("data",))
        _, X, H = _problem()
        st = hes.accumulate_sharded(hes.init_hessian(X.shape[1]), X, mesh)
        np.testing.assert_allclose(np.asarray(hes.finalize(st)),
                                   np.asarray(H), rtol=1e-5)


# ---------------------------------------------------------------------------
# allocator properties (plain seeded loops; hypothesis variants in
# test_properties.py run where the extra is installed)
# ---------------------------------------------------------------------------

def _entries(n=6, seed=0):
    base = dataclasses.replace(PAPER_SETTINGS["2.25bpv_2d"], em_iters=6,
                               codebook_update_iters=0)
    shapes = [(64, 128), (128, 128), (96, 192), (64, 256), (128, 384),
              (32, 128), (192, 128), (64, 384)][:n]
    out = []
    for i, (r, c) in enumerate(shapes):
        k1, k2 = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(seed), i))
        W = jax.random.normal(k1, (r, c)) * (
            1.0 + jax.random.uniform(k2, (r, 1)))
        dh = jnp.abs(jax.random.normal(k2, (c,))) + 0.1
        out.append(BudgetEntry(name=f"t{i}", W=W, diag_h=dh, base_cfg=base,
                               numel=r * c, replicas=1))
    return out


class TestAllocatorProps:
    def test_plan_groups_invariants(self):
        for r in (16, 32, 64, 96):
            for c in (128, 256, 384):
                for d in (1, 2, 4):
                    for gs in (256, 1024, 4096):
                        cfg = VQConfig(d=d, bits_per_dim=2, group_size=gs)
                        cg, rg = plan_groups(r, c, cfg)
                        assert c % cg == 0 and cg % d == 0, (r, c, d, gs)
                        assert r % rg == 0, (r, c, d, gs)

    def test_budget_ceiling_and_determinism(self):
        for seed in range(3):
            entries = _entries(seed=seed)
            for budget in (2.25, 2.5, 3.0):
                a = allocate_budget(entries, budget)
                b = allocate_budget(entries, budget)
                assert a == b, "allocation is not deterministic"
                total = sum(e.numel for e in entries)
                bits = sum(
                    effective_bpv(a[e.name][1], *e.W.shape) * e.numel
                    for e in entries)
                assert bits / total <= budget + 1e-9, (seed, budget)

    def test_budget_monotone_upgrades(self):
        """More budget never downgrades any target: the greedy applies
        the same ratio-ordered upgrade sequence, just further."""
        entries = _entries(seed=1)
        prev = None
        for budget in (2.25, 2.5, 3.0, 4.0):
            alloc = allocate_budget(entries, budget)
            bpv = {e.name: effective_bpv(alloc[e.name][1], *e.W.shape)
                   for e in entries}
            if prev is not None:
                for nm in bpv:
                    assert bpv[nm] >= prev[nm] - 1e-9, (nm, budget)
            prev = bpv

    def test_closed_form_agrees_with_refit_argmin(self):
        """>= 90% of targets: both scorers name the same best candidate
        (the refit oracle is what the closed form replaced)."""
        from repro.core.recipe import _proxy_error

        entries = _entries(n=6, seed=0)
        same = total = 0
        for e in entries:
            rows = []
            for s in BUDGET_CANDIDATES:
                b = PAPER_SETTINGS[s]
                if e.W.shape[1] % b.d:
                    continue
                cfg = dataclasses.replace(
                    e.base_cfg, d=b.d, bits_per_dim=b.bits_per_dim,
                    group_size=b.group_size, codebook_bits=b.codebook_bits)
                rows.append((s, closed_form_proxy_error(e.W, e.diag_h, cfg),
                             _proxy_error(e.W, e.diag_h, cfg)))
            same += (min(rows, key=lambda t: t[1])[0]
                     == min(rows, key=lambda t: t[2])[0])
            total += 1
        assert same / total >= 0.9, f"{same}/{total}"

    def test_closed_form_zero_when_codebook_covers_vectors(self):
        """k >= n_vec means every vector gets its own centroid; the
        closed form must report ~0 like the refit oracle does."""
        W = jax.random.normal(jax.random.PRNGKey(0), (8, 16))
        cfg = VQConfig(d=4, bits_per_dim=2, group_size=4096, em_iters=4)
        assert closed_form_proxy_error(W, None, cfg) == 0.0

    def test_unknown_scorer_raises(self):
        with pytest.raises(RecipeError, match="unknown budget scorer"):
            allocate_budget(_entries(n=2), 2.5, scorer="vibes")


# ---------------------------------------------------------------------------
# solver knob
# ---------------------------------------------------------------------------

SOLVER_CFG = VQConfig(d=2, bits_per_dim=2, group_size=4096, em_iters=8,
                      codebook_update_iters=0)


class TestSolvers:
    def test_default_path_bitwise_identical(self):
        """solver="gptq" must be the identity refactor: same jitted ops,
        bitwise-equal packed payload arrays."""
        W, _, H = _problem()
        U = hes.inv_hessian_cholesky(H)
        a = gptvq_quantize_matrix(W, U, SOLVER_CFG, jax.random.PRNGKey(0))
        b = gptvq_quantize_matrix(W, U, SOLVER_CFG, jax.random.PRNGKey(0),
                                  solver="gptq")
        for x, y in zip(a.arrays, b.arrays):
            assert np.array_equal(np.asarray(x), np.asarray(y))

    @pytest.mark.parametrize("solver", ["babai", "cd"])
    def test_solver_no_worse_than_gptq(self, solver):
        W, _, H = _problem(seed=7)
        U = hes.inv_hessian_cholesky(H)
        base = gptvq_quantize_matrix(W, U, SOLVER_CFG,
                                     jax.random.PRNGKey(0))
        res = gptvq_quantize_matrix(
            W, U, SOLVER_CFG, jax.random.PRNGKey(0), solver=solver,
            H=H if solver == "cd" else None)
        e0 = float(layer_error(W, base.arrays.Q, H))
        e1 = float(layer_error(W, res.arrays.Q, H))
        assert e1 <= e0 * 1.01, (solver, e0, e1)
        # packed payload stays self-consistent
        np.testing.assert_allclose(np.asarray(res.reconstruct()),
                                   np.asarray(res.arrays.Q), rtol=1e-4,
                                   atol=1e-5)

    def test_cd_requires_hessian(self):
        W, _, H = _problem(32, 64)
        U = hes.inv_hessian_cholesky(H)
        with pytest.raises(ValueError, match="solver='cd'"):
            gptvq_quantize_matrix(W, U, SOLVER_CFG, jax.random.PRNGKey(0),
                                  solver="cd")

    def test_unknown_solver_raises(self):
        W, _, H = _problem(32, 64)
        with pytest.raises(ValueError, match="unknown solver"):
            gptvq_quantize_matrix(W, hes.inv_hessian_cholesky(H),
                                  SOLVER_CFG, solver="newton")

    def test_recipe_solver_json_roundtrip(self):
        rec = QuantRecipe(
            rules=(Rule("group:attn",
                        Quantize(PAPER_SETTINGS["2.25bpv_2d"],
                                 solver="babai")),),
            default=Quantize(PAPER_SETTINGS["2.25bpv_2d"]), name="sv")
        assert QuantRecipe.from_json(rec.to_json()) == rec
        js = rec.to_json()
        assert js["rules"][0]["solver"] == "babai"
        assert "solver" not in js["default"]  # default stays implicit

    def test_with_solver_applies_and_validates(self):
        rec = QuantRecipe.uniform(PAPER_SETTINGS["2.25bpv_2d"])
        assert rec.with_solver("cd").default.solver == "cd"
        for s in VALID_SOLVERS:
            assert rec.with_solver(s).default.solver == s
        with pytest.raises(RecipeError, match="unknown solver"):
            rec.with_solver("sgd")


# ---------------------------------------------------------------------------
# pipeline integration: stage split + tap-miss warning
# ---------------------------------------------------------------------------

def _tiny_model():
    from repro.configs.base import ModelConfig
    from repro.data.synthetic import sample_batch
    from repro.models import model_zoo

    cfg = ModelConfig(
        name="bs-t", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=256,
        max_seq_len=128, dtype="float32", vocab_pad_multiple=64)
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 16, 4)
    return model, params, calib


TINY = Quantize(dataclasses.replace(PAPER_SETTINGS["2.25bpv_2d"],
                                    em_iters=4, codebook_update_iters=0))


class TestPipelineIntegration:
    def test_stage_seconds_splits_em_init_from_column_sweep(self):
        from repro.core.pipeline import quantize_model
        from repro.obs import Telemetry

        model, params, calib = _tiny_model()
        tel = Telemetry()
        _, rep = quantize_model(
            model, params, calib,
            recipe=QuantRecipe(rules=(), default=TINY), chunk=4,
            telemetry=tel)
        assert "em_init" in rep.stage_seconds
        assert "column_sweep" in rep.stage_seconds
        assert rep.stage_seconds["em_init"] > 0
        assert rep.stage_seconds["column_sweep"] > 0
        # the split surfaces in the span flame-graph metrics too
        metrics = tel.metrics_snapshot()["metrics"]
        assert "span.quant/em_init" in metrics
        assert "span.quant/column_sweep" in metrics
        tel.close()

    def test_budgeted_run_records_prepass_stages(self):
        from repro.core.pipeline import quantize_model

        model, params, calib = _tiny_model()
        _, rep = quantize_model(
            model, params, calib,
            recipe=QuantRecipe(rules=(), default=TINY), budget_bpv=2.5,
            chunk=4)
        assert "budget_prepass" in rep.stage_seconds
        assert "budget_allocate" in rep.stage_seconds
        assert rep.achieved_bpv <= 2.5 + 1e-9
        assert rep.warnings == []

    def test_tap_miss_warns_and_falls_back_to_weight_variance(self,
                                                              monkeypatch):
        """A target whose Hessian tap never fires must be called out in
        report.warnings (and via warnings.warn), then scored by weight
        variance instead of being silently treated like the others."""
        from repro.core import pipeline as pl

        model, params, calib = _tiny_model()
        real = pl._budget_prepass

        def drop_one(adapter, chunks, plan, progress, **kw):
            diag, missed = real(adapter, chunks, plan, progress, **kw)
            victim = "layers.0.attn.wq"
            diag.pop(victim, None)
            missed[victim] = "tap 'attn_in' never fired"
            return diag, missed

        monkeypatch.setattr(pl, "_budget_prepass", drop_one)
        with pytest.warns(UserWarning, match="layers.0.attn.wq"):
            _, rep = pl.quantize_model(
                model, params, calib,
                recipe=QuantRecipe(rules=(), default=TINY),
                budget_bpv=2.5, chunk=4)
        assert any("layers.0.attn.wq" in w and "weight variance" in w
                   for w in rep.warnings)
        # the target still got quantized under the budget
        assert rep.per_target["layers.0.attn.wq"]["action"] == "quantize"

    def test_budget_scorer_refit_still_available(self):
        from repro.core.pipeline import quantize_model

        model, params, calib = _tiny_model()
        _, rep = quantize_model(
            model, params, calib,
            recipe=QuantRecipe(rules=(), default=TINY), budget_bpv=2.5,
            budget_scorer="refit", chunk=4)
        assert rep.achieved_bpv <= 2.5 + 1e-9
