"""Unit tests for the GPTVQ core: uniform quant, Hessian, EM, Algorithm 1."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import codebook as cb
from repro.core import hessian as hes
from repro.core import normalization as norm
from repro.core import packing
from repro.core.bpv import PAPER_SETTINGS, VQConfig, group_size_for_overhead
from repro.core.codebook_compress import codebook_update, quantize_codebooks, svd_compress
from repro.core.gptvq import gptvq_quantize_matrix, layer_error, plan_groups
from repro.core.quant import gptq_quantize, rtn_quantize, rtn_int_weights, dequantize_int

jax.config.update("jax_enable_x64", False)


def make_problem(r=64, c=128, n=512, seed=0):
    """Random weights + correlated calibration inputs -> (W, X, H, U)."""
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    W = jax.random.normal(k1, (r, c)) * (1.0 + jax.random.uniform(k2, (r, 1)))
    # correlated inputs (realistic activations have structure)
    A = jax.random.normal(k3, (c, c)) / np.sqrt(c)
    X = jax.random.normal(jax.random.PRNGKey(seed + 1), (n, c)) @ (
        jnp.eye(c) + 0.5 * A
    )
    st = hes.accumulate(hes.init_hessian(c), X)
    H = hes.finalize(st)
    U = hes.inv_hessian_cholesky(H)
    return W, X, H, U


class TestUniform:
    def test_rtn_error_bound(self):
        W, *_ = make_problem()
        Q = rtn_quantize(W, bits=4, group_size=32)
        # max error bounded by half a quantization step per group
        scale_bound = (
            (W.reshape(64, 4, 32).max(-1) - jnp.minimum(W.reshape(64, 4, 32).min(-1), 0))
            / 15.0
        )
        err = jnp.abs(W - Q).reshape(64, 4, 32).max(-1)
        assert jnp.all(err <= scale_bound * 0.51 + 1e-6)

    def test_int_roundtrip(self):
        W, *_ = make_problem()
        q, p = rtn_int_weights(W, bits=3, group_size=64)
        assert q.min() >= 0 and q.max() <= 7
        np.testing.assert_allclose(
            dequantize_int(q, p), rtn_quantize(W, 3, 64), rtol=1e-5, atol=1e-5
        )

    def test_gptq_identity_hessian_equals_rtn(self):
        W, *_ = make_problem(32, 64)
        U = jnp.eye(64)
        Q1 = gptq_quantize(W, U, bits=4, group_size=64, block_size=32)
        Q2 = rtn_quantize(W, 4, 64)
        np.testing.assert_allclose(np.asarray(Q1), np.asarray(Q2), atol=1e-5)

    def test_gptq_beats_rtn_on_layer_error(self):
        W, X, H, U = make_problem()
        Qr = rtn_quantize(W, bits=3, group_size=128)
        Qg = gptq_quantize(W, U, bits=3, group_size=128, block_size=64)
        e_rtn = layer_error(W, Qr, H)
        e_gptq = layer_error(W, Qg, H)
        assert e_gptq < e_rtn * 0.9, (e_gptq, e_rtn)

    @pytest.mark.parametrize("gs,B", [(32, 64), (64, 64), (128, 64), (64, 32)])
    def test_gptq_group_block_combos(self, gs, B):
        W, X, H, U = make_problem()
        Q = gptq_quantize(W, U, bits=4, group_size=gs, block_size=B)
        assert jnp.all(jnp.isfinite(Q))
        assert layer_error(W, Q, H) < layer_error(W, jnp.zeros_like(W), H)


class TestCodebook:
    def test_em_monotone_objective(self):
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (256, 2))
        Hw = jnp.abs(jax.random.normal(jax.random.PRNGKey(1), (256, 2))) + 0.1
        C = cb.mahalanobis_init(X, 8)
        prev = cb.em_objective(X, Hw, C)
        for _ in range(5):
            C = cb.em(X, Hw, C, iters=1)
            cur = cb.em_objective(X, Hw, C)
            assert cur <= prev + 1e-5
            prev = cur

    def test_em_identity_weights_is_kmeans(self):
        # with Hw=1 the M-step is the plain mean -> matches manual kmeans step
        key = jax.random.PRNGKey(0)
        X = jax.random.normal(key, (128, 2))
        Hw = jnp.ones_like(X)
        C0 = cb.mahalanobis_init(X, 4)
        idx = cb.assign(X, Hw, C0)
        C1 = cb.m_step(X, Hw, idx, C0)
        for m in range(4):
            mask = idx == m
            if mask.sum() > 0:
                np.testing.assert_allclose(
                    np.asarray(C1[m]), np.asarray(X[mask].mean(0)), rtol=1e-4, atol=1e-5
                )

    def test_mahalanobis_init_shape_and_spread(self):
        X = jax.random.normal(jax.random.PRNGKey(2), (1000, 4))
        C = cb.mahalanobis_init(X, 16)
        assert C.shape == (16, 4)
        assert jnp.all(jnp.isfinite(C))
        # seeds should be distinct points for continuous data
        assert len(np.unique(np.asarray(C), axis=0)) == 16

    def test_kmeanspp_init(self):
        X = jax.random.normal(jax.random.PRNGKey(3), (200, 2))
        Hw = jnp.ones_like(X)
        C = cb.kmeanspp_init(X, Hw, 8, jax.random.PRNGKey(0))
        assert C.shape == (8, 2)
        assert len(np.unique(np.asarray(C), axis=0)) == 8


class TestBPV:
    def test_paper_settings_bpv(self):
        # paper Table 2 configurations hit their nominal bpv exactly
        expect = {
            "2.125bpv_1d": 2.125, "2.125bpv_2d": 2.125,
            "2.25bpv_1d": 2.25, "2.25bpv_2d": 2.25, "2.25bpv_4d": 2.25,
            "3.125bpv_1d": 3.125, "3.125bpv_2d": 3.125,
            "4.125bpv_1d": 4.125, "4.125bpv_2d": 4.125,
        }
        for name, bpv in expect.items():
            assert abs(PAPER_SETTINGS[name].bits_per_value - bpv) < 1e-9, name

    def test_group_size_for_overhead_matches_paper(self):
        # paper §4.1: 2D, 2 bits/dim, int8 codebook, 0.125 bpv -> 2048 weights
        assert group_size_for_overhead(2, 2, 0.125, 8) == 2048

    def test_scale_overhead(self):
        cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, scale_block=32)
        assert abs(cfg.bits_per_value - (2.125 + 4 / 32)) < 1e-9


class TestPacking:
    @pytest.mark.parametrize("bits", [2, 3, 4, 5, 8])
    def test_roundtrip(self, bits):
        n = 4096
        idx = np.random.RandomState(0).randint(0, 2**bits, size=n).astype(np.int32)
        words = packing.pack(jnp.asarray(idx), bits)
        back = packing.unpack(words, bits, n)
        np.testing.assert_array_equal(np.asarray(back), idx)
        # container accounting
        cb_ = packing.container_bits(bits)
        assert words.size == n * cb_ // 32


class TestNormalization:
    def test_roundtrip_accuracy(self):
        W = jax.random.normal(jax.random.PRNGKey(0), (32, 128)) * jnp.exp2(
            jax.random.randint(jax.random.PRNGKey(1), (32, 1), -6, 6).astype(jnp.float32)
        )
        bs = norm.compute_block_scales(W, block=16, bits=4)
        Wn = norm.normalize(W, bs)
        # normalized blocks should be O(1)
        assert jnp.max(jnp.abs(Wn)) < 4.0
        np.testing.assert_allclose(
            np.asarray(norm.denormalize(Wn, bs)), np.asarray(W), rtol=1e-5
        )

    def test_identity_scales(self):
        W = jnp.ones((4, 64))
        bs = norm.identity_scales(W, block=64)
        np.testing.assert_allclose(np.asarray(bs.expand(64)), 1.0)


class TestGPTVQ:
    def test_plan_groups(self):
        cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, group_cols=256)
        cg, rg = plan_groups(64, 512, cfg)
        assert cg == 256 and rg == 8
        # non-divisible columns fall back to a divisor
        cg, rg = plan_groups(64, 384, cfg)
        assert 384 % cg == 0

    @pytest.mark.parametrize("name", ["2.125bpv_2d", "3.125bpv_1d", "2.25bpv_4d"])
    def test_sweep_finite_and_shapes(self, name):
        cfg = PAPER_SETTINGS[name]
        cfg = type(cfg)(**{**cfg.__dict__, "em_iters": 10, "codebook_update_iters": 0})
        W, X, H, U = make_problem(r=32, c=256)
        res = gptvq_quantize_matrix(W, U, cfg)
        assert res.arrays.Q.shape == W.shape
        assert jnp.all(jnp.isfinite(res.arrays.Q))
        assert res.arrays.indices.shape == (32, 256 // cfg.d)
        assert int(res.arrays.indices.max()) < cfg.k
        # reconstruction matches the sweep's Q (same codebooks)
        np.testing.assert_allclose(
            np.asarray(res.reconstruct()), np.asarray(res.arrays.Q), rtol=1e-4, atol=1e-5
        )

    def test_gptvq_beats_datafree_kmeans(self):
        """Paper Table 1: hessian-aware sweep must beat data-free clustering."""
        W, X, H, U = make_problem(r=64, c=256)
        cfg = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=30,
                       codebook_update_iters=0)
        res = gptvq_quantize_matrix(W, U, cfg)
        e_gptvq = float(layer_error(W, res.arrays.Q, H))

        # data-free: plain kmeans per group, no error feedback
        res_df = gptvq_quantize_matrix(W, jnp.eye(256), cfg)
        e_df = float(layer_error(W, res_df.arrays.Q, H))
        assert e_gptvq < e_df, (e_gptvq, e_df)

    def test_higher_d_better_sqnr_at_equal_bpv(self):
        """Fig. 2: at matched index bits, 2D VQ >= 1D VQ in SQNR (typical)."""
        W, X, H, U = make_problem(r=64, c=256, seed=3)
        e = {}
        for name in ["2.25bpv_1d", "2.25bpv_2d"]:
            cfg = PAPER_SETTINGS[name]
            cfg = type(cfg)(**{**cfg.__dict__, "em_iters": 30,
                               "codebook_update_iters": 0})
            res = gptvq_quantize_matrix(W, U, cfg)
            e[name] = float(layer_error(W, res.arrays.Q, H))
        assert e["2.25bpv_2d"] < e["2.25bpv_1d"], e

    def test_codebook_update_reduces_error(self):
        W, X, H, U = make_problem(r=32, c=256)
        cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, em_iters=20,
                       codebook_update_iters=30)
        res = gptvq_quantize_matrix(W, U, cfg)
        e0 = float(layer_error(W, res.arrays.Q, H))
        res2 = codebook_update(res, W, H)
        e1 = float(layer_error(W, res2.arrays.Q, H))
        assert e1 <= e0 * 1.001, (e0, e1)

    def test_codebook_quantization_small_effect(self):
        W, X, H, U = make_problem(r=32, c=256)
        cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, em_iters=20,
                       codebook_update_iters=0)
        res = gptvq_quantize_matrix(W, U, cfg)
        resq = quantize_codebooks(res)
        # int8 codebooks change reconstruction by <1% relative
        rel = float(
            jnp.linalg.norm(resq.arrays.Q - res.arrays.Q)
            / jnp.linalg.norm(res.arrays.Q)
        )
        assert rel < 0.02, rel
        assert resq.codebook_scale is not None

    def test_svd_compress_runs_and_reconstructs(self):
        W, X, H, U = make_problem(r=32, c=256)
        cfg = VQConfig(d=1, bits_per_dim=3, group_size=512, em_iters=20,
                       codebook_update_iters=0, svd_rank_frac=0.5)
        res = gptvq_quantize_matrix(W, U, cfg)
        out, svd = svd_compress(res, W, H)
        assert jnp.all(jnp.isfinite(out.arrays.Q))
        assert svd.U.shape[1] == max(1, int(round(0.5 * cfg.k)))
        # compression should not blow up the error catastrophically
        e0 = float(layer_error(W, res.arrays.Q, H))
        e1 = float(layer_error(W, out.arrays.Q, H))
        assert e1 < 10 * e0 + 1e-6, (e0, e1)

    def test_normalization_path(self):
        W, X, H, U = make_problem(r=32, c=256, seed=5)
        # give rows wildly different scales so normalization matters
        W = W * jnp.exp2(jnp.arange(32, dtype=jnp.float32) % 8 - 4)[:, None]
        cfg = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=20,
                       scale_block=16, codebook_update_iters=0)
        res = gptvq_quantize_matrix(W, U, cfg)
        assert jnp.all(jnp.isfinite(res.arrays.Q))
        cfg_off = type(cfg)(**{**cfg.__dict__, "scale_block": 0})
        res_off = gptvq_quantize_matrix(W, U, cfg_off)
        e_on = float(layer_error(W, res.arrays.Q, H))
        e_off = float(layer_error(W, res_off.arrays.Q, H))
        # with extreme per-row scale variation, normalization should help
        assert e_on < e_off, (e_on, e_off)

    def test_d1_gptvq_close_to_gptq_nonuniform_vs_uniform(self):
        """1D VQ with k=2^b centroids is a nonuniform grid; with error
        feedback it should be at least competitive with uniform GPTQ."""
        W, X, H, U = make_problem(r=64, c=256, seed=7)
        cfg = VQConfig(d=1, bits_per_dim=3, group_size=512, em_iters=50,
                       codebook_update_iters=0)
        res = gptvq_quantize_matrix(W, U, cfg)
        e_vq = float(layer_error(W, res.arrays.Q, H))
        Qg = gptq_quantize(W, U, bits=3, group_size=128, block_size=128)
        e_gptq = float(layer_error(W, Qg, H))
        assert e_vq < e_gptq * 1.5, (e_vq, e_gptq)
