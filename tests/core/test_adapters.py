"""Adapter-registry coverage: every model family quantizes through the
same generic driver, MoE per-expert Hessians match a naive per-token loop,
and the data-aware method beats RTN on reconstruction error."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FAMILY_REPRESENTATIVE as FAMILY_ARCH, SMOKE
from repro.core import adapters
from repro.core import vq_linear as vql
from repro.core.bpv import VQConfig
from repro.core.pipeline import quantize_model
from repro.data.synthetic import sample_batch
from repro.models import common as cm, model_zoo, moe
from repro.train.loss import perplexity

VQ_TINY = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=4,
                   codebook_update_iters=2)


def _errors(report):
    return [v for row in report.per_layer for k, v in row.items()
            if k not in ("layer", "block")]


@pytest.mark.parametrize("family", sorted(FAMILY_ARCH))
def test_registry_quantizes_and_packs_every_family(family):
    """quantize_model(gptvq, pack=True) end-to-end on a tiny config from
    each family: finite per-target reconstruction errors, VQLinear leaves
    in the tree, and a finite perplexity when serving the packed params."""
    cfg = SMOKE[FAMILY_ARCH[family]].scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 16, 4)
    qp, rep = quantize_model(model, params, calib, "gptvq", VQ_TINY,
                             pack=True, chunk=4, seed=1)
    errs = _errors(rep)
    assert errs, f"{family}: no quantized targets reported"
    assert all(np.isfinite(e) for e in errs), (family, errs)
    assert vql.tree_has_vq(qp), f"{family}: pack=True produced no VQLinear"
    heldout = sample_batch(jax.random.PRNGKey(4), cfg.vocab_size, 16, 2)
    extras = adapters.calib_extras(cfg, heldout)
    ppl = perplexity(model, qp, heldout, batch_extra=extras)
    assert np.isfinite(ppl), f"{family}: packed forward diverged"


def test_gptvq_reconstruction_beats_rtn_on_dense():
    """Data-aware GPTVQ must reconstruct better (Hessian-weighted
    layer_error) than round-to-nearest at comparable bits on the dense
    family."""
    cfg = SMOKE[FAMILY_ARCH["dense"]].scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 16, 4)
    _, rep_vq = quantize_model(model, params, calib, "gptvq", VQ_TINY,
                               chunk=4, seed=1)
    _, rep_rtn = quantize_model(model, params, calib, "rtn",
                                {"bits": 3, "group_size": 128}, chunk=4)
    err_vq, err_rtn = rep_vq.total_error(), rep_rtn.total_error()
    assert np.isfinite(err_vq) and np.isfinite(err_rtn)
    assert err_vq < err_rtn, (err_vq, err_rtn)


def test_unknown_family_raises():
    class FakeCfg:
        family = "granite-moe-hybrid"

    class FakeModel:
        cfg = FakeCfg()

    with pytest.raises(KeyError):
        adapters.get_adapter(FakeModel(), {})


def test_moe_expert_hessians_match_naive_token_loop():
    """moe.expert_hessians (the adapter's per-expert tap) against a naive
    per-token python loop: routed-token accumulation on the input side and
    routed-token *masking* on the w_out (hidden) side."""
    cfg = SMOKE[FAMILY_ARCH["moe"]].scaled(dtype="float32")
    E, K = cfg.n_experts, cfg.n_experts_active
    p = moe.init(jax.random.PRNGKey(3), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 6, cfg.d_model),
                          jnp.float32)
    (Hin, n_in), (Hout, n_out) = moe.expert_hessians(p, cfg, x)

    xf = np.asarray(x, np.float64).reshape(-1, cfg.d_model)
    router = np.asarray(p["router"], np.float64)
    w_in = np.asarray(p["w_in"], np.float64)
    w_gate = np.asarray(p["w_gate"], np.float64)
    F = w_in.shape[-1]
    Hin_ref = np.zeros((E, cfg.d_model, cfg.d_model))
    Hout_ref = np.zeros((E, F, F))
    n_ref = np.zeros(E)
    for t in range(xf.shape[0]):
        logits = xf[t] @ router
        probs = np.exp(logits - logits.max())
        probs /= probs.sum()
        routed = np.argsort(-probs, kind="stable")[:K]
        for e in routed:
            n_ref[e] += 1
            Hin_ref[e] += np.outer(xf[t], xf[t])
            # hidden state of THIS expert for this token (swiglu gate)
            g = xf[t] @ w_gate[e]
            h = (g / (1 + np.exp(-g))) * (xf[t] @ w_in[e])
            Hout_ref[e] += np.outer(h, h)
        # tokens NOT routed to e contribute nothing on the w_out side —
        # the masking the vectorized path implements with the onehot
    np.testing.assert_allclose(np.asarray(n_in), n_ref, rtol=0, atol=0)
    np.testing.assert_allclose(np.asarray(Hin), Hin_ref, rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(Hout), Hout_ref, rtol=1e-4,
                               atol=1e-4)


def test_moe_pack_roundtrip_expert_stack():
    """Packed MoE expert stacks (leading E dim on every VQLinear leaf)
    dequantize to the fake-quant weights."""
    cfg = SMOKE[FAMILY_ARCH["moe"]].scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 16, 4)
    qp_fake, _ = quantize_model(model, params, calib, "gptvq", VQ_TINY,
                                chunk=4, seed=7)
    qp_pack, _ = quantize_model(model, params, calib, "gptvq", VQ_TINY,
                                pack=True, chunk=4, seed=7)
    fake_w = jax.tree.map(lambda a: a[0], qp_fake["layers"])["ffn"]["w_in"]
    # slicing the stacked tree's array leaves keeps VQLinear metadata
    packed = jax.tree.map(lambda a: a[0], qp_pack["layers"])
    packed_w = packed["ffn"]["w_in"]
    assert isinstance(packed_w, vql.VQLinear)
    dense = vql.dequant_tree({"w": packed_w}, jnp.float32)["w"]
    np.testing.assert_allclose(np.asarray(dense), np.asarray(fake_w),
                               rtol=2e-2, atol=2e-2)
