"""Property-based tests (hypothesis) on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis",
    reason="property tests need hypothesis (declared in pyproject [test]); "
           "skipped on bare containers")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import codebook as cb
from repro.core import packing
from repro.core.bpv import VQConfig, group_size_for_overhead
from repro.core.gptvq import gptvq_quantize_matrix, plan_groups
from repro.core.quant import rtn_quantize
from repro.models.common import sanitize_specs
from repro.runtime.straggler import StragglerMonitor

SETTINGS = dict(max_examples=15, deadline=None)


class TestPackingProps:
    @settings(**SETTINGS)
    @given(bits=st.sampled_from([1, 2, 3, 4, 5, 8]),
           n_words=st.integers(1, 64), seed=st.integers(0, 2**31 - 1))
    def test_roundtrip_any_codes(self, bits, n_words, seed):
        lanes = 32 // packing.container_bits(bits)
        n = n_words * lanes
        rng = np.random.RandomState(seed)
        codes = rng.randint(0, 2**bits, size=n).astype(np.int32)
        back = packing.unpack(packing.pack(jnp.asarray(codes), bits), bits, n)
        np.testing.assert_array_equal(np.asarray(back), codes)


class TestQuantProps:
    @settings(**SETTINGS)
    @given(bits=st.sampled_from([2, 3, 4, 8]),
           gs=st.sampled_from([16, 32, 64]),
           seed=st.integers(0, 1000))
    def test_rtn_elementwise_error_bound(self, bits, gs, seed):
        W = jax.random.normal(jax.random.PRNGKey(seed), (8, 64)) * 3.0
        Q = rtn_quantize(W, bits, gs)
        wg = W.reshape(8, 64 // gs, gs)
        hi = jnp.maximum(wg.max(-1), 0.0)
        lo = jnp.minimum(wg.min(-1), 0.0)
        step = (hi - lo) / (2**bits - 1)
        err = jnp.abs(W - Q).reshape(8, 64 // gs, gs).max(-1)
        assert bool(jnp.all(err <= step * 0.5 + 1e-5))

    @settings(**SETTINGS)
    @given(d=st.sampled_from([1, 2, 4]), b=st.sampled_from([2, 3]),
           target=st.sampled_from([0.125, 0.25, 0.5]))
    def test_overhead_target_met(self, d, b, target):
        gs = group_size_for_overhead(d, b, target, 8)
        cfg = VQConfig(d=d, bits_per_dim=b, group_size=gs)
        assert cfg.codebook_bits_per_value <= target + 1e-9

    @settings(**SETTINGS)
    @given(r=st.sampled_from([16, 32, 64]), c=st.sampled_from([128, 256, 384]),
           d=st.sampled_from([1, 2, 4]),
           gs=st.sampled_from([256, 1024, 4096]))
    def test_plan_groups_invariants(self, r, c, d, gs):
        cfg = VQConfig(d=d, bits_per_dim=2, group_size=gs)
        cg, rg = plan_groups(r, c, cfg)
        assert c % cg == 0 and cg % d == 0 and r % rg == 0


class TestEMProps:
    @settings(**SETTINGS)
    @given(seed=st.integers(0, 1000), k=st.sampled_from([4, 8, 16]))
    def test_em_objective_monotone(self, seed, k):
        ks = jax.random.split(jax.random.PRNGKey(seed), 2)
        X = jax.random.normal(ks[0], (128, 2))
        Hw = jnp.abs(jax.random.normal(ks[1], (128, 2))) + 0.05
        C = cb.mahalanobis_init(X, k)
        prev = float(cb.em_objective(X, Hw, C))
        for _ in range(3):
            C = cb.em(X, Hw, C, iters=1)
            cur = float(cb.em_objective(X, Hw, C))
            assert cur <= prev + 1e-4 * abs(prev) + 1e-6
            prev = cur

    @settings(**SETTINGS)
    @given(seed=st.integers(0, 500))
    def test_assignment_is_argmin(self, seed):
        ks = jax.random.split(jax.random.PRNGKey(seed), 3)
        X = jax.random.normal(ks[0], (64, 2))
        Hw = jnp.abs(jax.random.normal(ks[1], (64, 2))) + 0.1
        C = jax.random.normal(ks[2], (8, 2))
        idx = cb.assign(X, Hw, C)
        dist = cb.weighted_distances(X, Hw, C)
        chosen = jnp.take_along_axis(dist, idx[:, None], 1)[:, 0]
        assert bool(jnp.all(chosen <= dist.min(-1) + 1e-5))


class TestGPTVQProps:
    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 100), d=st.sampled_from([1, 2]),
           b=st.sampled_from([2, 3]))
    def test_indices_in_range_and_reconstruction_consistent(self, seed, d, b):
        key = jax.random.PRNGKey(seed)
        W = jax.random.normal(key, (16, 128))
        cfg = VQConfig(d=d, bits_per_dim=b, group_size=1024, em_iters=5,
                       codebook_update_iters=0)
        res = gptvq_quantize_matrix(W, jnp.eye(128), cfg)
        assert int(res.arrays.indices.min()) >= 0
        assert int(res.arrays.indices.max()) < cfg.k
        np.testing.assert_allclose(np.asarray(res.reconstruct()),
                                   np.asarray(res.arrays.Q), rtol=1e-4,
                                   atol=1e-5)


class TestBudgetProps:
    @settings(max_examples=8, deadline=None)
    @given(budget=st.sampled_from([2.5, 3.0, 4.0]),
           seed=st.integers(0, 20))
    def test_allocation_under_ceiling_and_deterministic(self, budget, seed):
        import dataclasses
        from repro.core.bpv import PAPER_SETTINGS, effective_bpv
        from repro.core.recipe import BudgetEntry, allocate_budget

        base = dataclasses.replace(PAPER_SETTINGS["2.25bpv_2d"], em_iters=4,
                                   codebook_update_iters=0)
        entries = []
        for i, (r, c) in enumerate([(64, 128), (32, 256), (96, 192)]):
            k1, k2 = jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(seed), i))
            W = jax.random.normal(k1, (r, c))
            dh = jnp.abs(jax.random.normal(k2, (c,))) + 0.1
            entries.append(BudgetEntry(name=f"t{i}", W=W, diag_h=dh,
                                       base_cfg=base, numel=r * c))
        alloc = allocate_budget(entries, budget)
        assert alloc == allocate_budget(entries, budget)
        total = sum(e.numel for e in entries)
        bits = sum(effective_bpv(alloc[e.name][1], *e.W.shape) * e.numel
                   for e in entries)
        assert bits / total <= budget + 1e-9


class TestShardingProps:
    @settings(**SETTINGS)
    @given(dims=st.tuples(st.sampled_from([1, 3, 8, 16, 64, 100]),
                          st.sampled_from([1, 5, 16, 48, 256])))
    def test_sanitize_always_divisible(self, dims):
        import os
        from jax.sharding import PartitionSpec as P
        import jax as j
        mesh = j.make_mesh((1, 1), ("data", "model"))
        shapes = {"w": jax.ShapeDtypeStruct(dims, jnp.float32)}
        specs = {"w": P("data", "model")}
        fixed = sanitize_specs(shapes, specs, mesh)
        for i, ax in enumerate(fixed["w"]):
            if ax is not None:
                assert dims[i] % 1 == 0  # axis size 1 always divides


class TestStragglerProps:
    @settings(**SETTINGS)
    @given(base=st.floats(0.01, 10.0), n=st.integers(10, 50))
    def test_constant_durations_never_flag(self, base, n):
        mon = StragglerMonitor(min_samples=4)
        for i in range(n):
            rep = mon.record(i, base)
            assert not rep.is_straggler
