"""End-to-end behaviour test: train -> GPTVQ quantize -> packed serving.

The full-system happy path at tiny scale; deeper coverage lives in
tests/core, tests/models, tests/kernels, tests/substrate.
"""
import jax
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.bpv import VQConfig
from repro.core.pipeline import quantize_model
from repro.data.synthetic import SyntheticStream, sample_batch
from repro.models import model_zoo
from repro.serve.engine import Engine, Request
from repro.train import optimizer as opt
from repro.train.loss import perplexity
from repro.train.train_step import init_state, make_train_step


def test_train_quantize_serve_end_to_end():
    cfg = ModelConfig(
        name="e2e", family="dense", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=192, vocab_size=256,
        max_seq_len=128, dtype="float32", vocab_pad_multiple=64)
    model = model_zoo.build(cfg)

    # train
    ocfg = opt.OptConfig(lr=3e-3, warmup_steps=5, total_steps=40)
    state = init_state(model, jax.random.PRNGKey(0), ocfg)
    step = jax.jit(make_train_step(model, ocfg, microbatches=2))
    stream = SyntheticStream(cfg.vocab_size, seq_len=32, global_batch=8)
    first = last = None
    for i in range(40):
        state, metrics = step(state, {"tokens": stream.next()})
        if i == 0:
            first = float(metrics["loss"])
        last = float(metrics["loss"])
    assert last < first

    # quantize (paper's 2D setting) into the packed serving format
    calib = sample_batch(jax.random.PRNGKey(9), cfg.vocab_size, 32, 8)
    vq_cfg = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=10,
                      codebook_update_iters=5)
    qparams, report = quantize_model(model, state.params, calib, "gptvq",
                                     vq_cfg, pack=True)
    assert abs(report.bits_per_value - vq_cfg.bits_per_value) < 1e-9

    heldout = sample_batch(jax.random.PRNGKey(4), cfg.vocab_size, 64, 8)
    ppl_fp = perplexity(model, state.params, heldout)
    ppl_vq = perplexity(model, qparams, heldout)
    assert np.isfinite(ppl_vq) and ppl_vq < ppl_fp * 2.0, (ppl_fp, ppl_vq)

    # serve batched requests with the quantized weights
    rng = np.random.RandomState(0)
    eng = Engine(model, qparams, max_batch=2, max_len=64)
    reqs = [Request(rid=i, prompt=rng.randint(0, 255, size=6),
                    max_new_tokens=4) for i in range(3)]
    out = eng.run(reqs)
    assert all(len(r.out_tokens) >= 4 for r in out)
