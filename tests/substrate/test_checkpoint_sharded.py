"""Checkpoint restore with explicit shardings + flash backend toggle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint.checkpointer import Checkpointer
from repro.models import attention


def test_restore_with_shardings(tmp_path):
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    tree = {"w": jnp.arange(16.0).reshape(4, 4), "b": jnp.ones(4)}
    ck = Checkpointer(str(tmp_path))
    ck.save(3, tree)
    sh = {"w": NamedSharding(mesh, P("data", "model")),
          "b": NamedSharding(mesh, P(None))}
    restored, _ = ck.restore(tree, shardings=sh)
    assert restored["w"].sharding.is_equivalent_to(sh["w"], 2)
    np.testing.assert_array_equal(np.asarray(restored["w"]),
                                  np.arange(16.0).reshape(4, 4))


def test_flash_backend_toggle_agrees():
    """models/attention with the Pallas backend == XLA backend."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 128, 4, 32))
    k = jax.random.normal(ks[1], (2, 128, 2, 32))
    v = jax.random.normal(ks[2], (2, 128, 2, 32))
    try:
        attention.set_flash_impl("xla")
        o_xla = attention.flash_attention(q, k, v, causal=True,
                                          q_chunk=64, kv_chunk=64)
        attention.set_flash_impl("pallas")
        o_pl = attention.flash_attention(q, k, v, causal=True)
    finally:
        attention.set_flash_impl("xla")
    np.testing.assert_allclose(np.asarray(o_xla), np.asarray(o_pl),
                               rtol=2e-4, atol=2e-4)
