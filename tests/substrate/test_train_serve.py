"""Substrate tests: optimizer, train step, checkpointing, serving engine,
fault tolerance, elastic re-mesh, and the model-level GPTVQ pipeline."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import SMOKE
from repro.core.bpv import VQConfig
from repro.core.pipeline import quantize_model
from repro.data.synthetic import SyntheticStream, sample_batch
from repro.models import model_zoo
from repro.runtime import elastic, fault_tolerance as ft
from repro.runtime.straggler import StragglerMonitor
from repro.serve.engine import Engine, Request
from repro.train import optimizer as opt
from repro.train.train_step import TrainState, init_state, make_train_step


def tiny_model():
    cfg = SMOKE["llama2-7b"].scaled(dtype="float32", n_layers=2, d_model=64,
                                    vocab_size=256, max_seq_len=64)
    return model_zoo.build(cfg)


class TestOptimizer:
    def test_adamw_converges_quadratic(self):
        params = {"w": jnp.ones((4, 4)) * 5.0}
        state = opt.init(params)
        cfg = opt.OptConfig(lr=0.5, warmup_steps=0, total_steps=100,
                            weight_decay=0.0)
        for _ in range(60):
            g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
            params, state, m = opt.update(cfg, g, state)
        assert float(jnp.max(jnp.abs(params["w"]))) < 1.0

    def test_clip_and_schedule(self):
        cfg = opt.OptConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(opt.schedule(cfg, jnp.asarray(0))) == 0.0
        assert abs(float(opt.schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-6
        assert float(opt.schedule(cfg, jnp.asarray(100))) <= 0.11


class TestTrainStep:
    @pytest.mark.parametrize("microbatches", [1, 2])
    def test_loss_decreases(self, microbatches):
        model = tiny_model()
        ocfg = opt.OptConfig(lr=1e-2, warmup_steps=2, total_steps=40)
        state = init_state(model, jax.random.PRNGKey(0), ocfg)
        step = jax.jit(make_train_step(model, ocfg, microbatches=microbatches))
        stream = SyntheticStream(model.cfg.vocab_size, seq_len=32,
                                 global_batch=4)
        losses = []
        for _ in range(12):
            batch = {"tokens": stream.next()}
            state, metrics = step(state, batch)
            losses.append(float(metrics["loss"]))
        assert losses[-1] < losses[0], losses
        assert np.isfinite(losses).all()

    def test_microbatch_equivalence(self):
        """grad accumulation over k microbatches == single big batch."""
        model = tiny_model()
        ocfg = opt.OptConfig(lr=1e-3, warmup_steps=0, total_steps=10)
        state = init_state(model, jax.random.PRNGKey(0), ocfg)
        batch = {"tokens": sample_batch(jax.random.PRNGKey(5),
                                        model.cfg.vocab_size, 32, 4)}
        s1 = jax.jit(make_train_step(model, ocfg, microbatches=1))
        s2 = jax.jit(make_train_step(model, ocfg, microbatches=2))
        st1, m1 = s1(state, batch)
        st2, m2 = s2(state, batch)
        np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                                   rtol=1e-5)
        d = jax.tree.map(lambda a, b: float(jnp.max(jnp.abs(a - b))),
                         st1.params, st2.params)
        assert max(jax.tree.leaves(d)) < 1e-4


class TestCheckpoint:
    def test_roundtrip_and_gc(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=2)
        tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones(4)}}
        for s in (1, 2, 3):
            ck.save(s, jax.tree.map(lambda x: x * s, tree), {"tag": s})
        assert ck.all_steps() == [2, 3]  # gc kept last 2
        restored, meta = ck.restore(tree)
        assert meta["tag"] == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]),
                                      np.arange(6).reshape(2, 3) * 3)

    def test_async_save(self, tmp_path):
        ck = Checkpointer(str(tmp_path), async_save=True)
        ck.save(7, {"x": jnp.ones(8)})
        ck.wait()
        assert ck.latest_step() == 7

    def test_atomicity_no_partial_dirs(self, tmp_path):
        ck = Checkpointer(str(tmp_path))
        ck.save(1, {"x": jnp.ones(2)})
        assert not [d for d in os.listdir(tmp_path) if d.startswith(".tmp")]


class TestFaultTolerance:
    def test_restart_from_checkpoint(self, tmp_path):
        ck = Checkpointer(str(tmp_path), keep=5)
        fails = {"n": 0}

        def step_fn(state, step):
            if step == 7 and fails["n"] == 0:
                fails["n"] += 1
                raise RuntimeError("simulated device failure")
            return {"v": state["v"] + 1}

        res = ft.supervise(
            state={"v": jnp.zeros(())}, step_fn=step_fn, ckpt=ck,
            total_steps=10, checkpoint_every=2, max_restarts=2,
            heartbeat_path=str(tmp_path / "hb.json"))
        assert res.restarts == 1
        assert res.steps_done == 10
        assert float(res.final_state["v"]) == 10.0
        assert os.path.exists(tmp_path / "hb.json")


class TestElastic:
    def test_plan_and_degrade(self):
        plan = elastic.plan_mesh(512, model_parallel=16, pods=2)
        assert (plan.pod, plan.data, plan.model, plan.spares) == (2, 16, 16, 0)
        # lose 20 devices -> data axis shrinks, remainder spared
        p2 = elastic.degrade_plan(plan, 20)
        assert p2.used <= 492 and p2.model == 16
        assert p2.used + p2.spares == 492

    def test_build_mesh_single_device(self):
        plan = elastic.plan_mesh(1, model_parallel=1, pods=1)
        mesh = elastic.build_mesh(plan)
        assert mesh.axis_names == ("data", "model")


class TestStraggler:
    def test_flags_outliers(self):
        mon = StragglerMonitor(window=16, k_mad=4.0, min_samples=4)
        for i in range(10):
            mon.record(i, 1.0 + 0.01 * (i % 3), host=i % 4)
        rep = mon.record(10, 5.0, host=2)
        assert rep.is_straggler
        for i in range(3):
            mon.record(11 + i, 6.0, host=2)
        assert 2 in mon.quarantine_candidates(repeat_threshold=3)


class TestEngine:
    def test_serve_batched_requests(self):
        model = tiny_model()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_batch=3, max_len=48)
        rng = np.random.RandomState(0)
        reqs = [Request(rid=i, prompt=rng.randint(0, 255, size=5 + i),
                        max_new_tokens=4) for i in range(5)]
        out = eng.run(reqs)
        assert all(len(r.out_tokens) >= 4 or r.done for r in out)
        assert all(all(0 <= t < model.cfg.padded_vocab for t in r.out_tokens)
                   for r in out)

    def test_per_slot_temperature_sampling(self):
        """A greedy (t=0) request must decode deterministically even when
        batched with a high-temperature request in the same tick."""
        from repro.serve import sampling

        # unit level: vector temperature mixes greedy and sampled rows
        logits = jnp.log(jnp.asarray([[0.05, 0.9, 0.05],
                                      [0.05, 0.9, 0.05]]))
        temps = jnp.asarray([0.0, 50.0])
        draws = {int(sampling.sample(jax.random.PRNGKey(s), logits,
                                     temperature=temps)[1])
                 for s in range(64)}
        for s in range(8):
            out = sampling.sample(jax.random.PRNGKey(s), logits,
                                  temperature=temps)
            assert int(out[0]) == 1  # greedy row pinned to argmax
        assert len(draws) > 1  # hot row actually samples

        # engine level: the greedy request's tokens are independent of the
        # stochastic neighbour sharing its batch
        model = tiny_model()
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        prompt = rng.randint(0, 255, size=6)
        outs = []
        for seed in (0, 1):
            eng = Engine(model, params, max_batch=2, max_len=48, seed=seed)
            greedy = Request(rid=0, prompt=prompt, max_new_tokens=6,
                             temperature=0.0)
            hot = Request(rid=1, prompt=rng.randint(0, 255, size=6),
                          max_new_tokens=6, temperature=5.0)
            eng.run([greedy, hot])
            outs.append(list(greedy.out_tokens))
        assert outs[0] == outs[1], outs

    def test_two_engines_with_different_max_batch_coexist(self):
        """Slot-merge must use each engine's own max_batch (regression for
        the module-global _MERGE_BATCH hack)."""
        model = tiny_model()
        params = model.init_params(jax.random.PRNGKey(0))
        eng_a = Engine(model, params, max_batch=2, max_len=48)
        eng_b = Engine(model, params, max_batch=4, max_len=48)
        rng = np.random.RandomState(0)
        reqs_a = [Request(rid=i, prompt=rng.randint(0, 255, size=5),
                          max_new_tokens=3) for i in range(2)]
        reqs_b = [Request(rid=10 + i, prompt=rng.randint(0, 255, size=5),
                          max_new_tokens=3) for i in range(3)]
        # interleave admissions so each engine merges slots after the OTHER
        # engine was constructed (the old global held the latest max_batch)
        eng_a.admit(reqs_a[0])
        eng_b.admit(reqs_b[0])
        eng_a.run(reqs_a[1:])
        eng_b.run(reqs_b[1:])
        for r in reqs_a + reqs_b:
            assert len(r.out_tokens) >= 3 or r.done


class TestQuantizePipeline:
    def test_gptvq_improves_over_rtn_on_model(self):
        """End-to-end: quantize a small trained-ish model; data-aware GPTVQ
        must beat RTN at comparable bpv on held-out perplexity."""
        from repro.train.loss import perplexity

        model = tiny_model()
        # brief training so weights have structure for VQ to exploit
        ocfg = opt.OptConfig(lr=5e-3, warmup_steps=5, total_steps=100)
        state = init_state(model, jax.random.PRNGKey(0), ocfg)
        step = jax.jit(make_train_step(model, ocfg))
        stream = SyntheticStream(model.cfg.vocab_size, seq_len=32,
                                 global_batch=16)
        for _ in range(80):
            state, _ = step(state, {"tokens": stream.next()})
        params = state.params

        calib = sample_batch(jax.random.PRNGKey(9), model.cfg.vocab_size,
                             32, 8)
        heldout = sample_batch(jax.random.PRNGKey(11), model.cfg.vocab_size,
                               64, 8)
        ppl_fp = perplexity(model, params, heldout)

        # 2 bits/dim: the regime where the paper's gap is dramatic (Table 2)
        vq_cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, em_iters=30,
                          codebook_update_iters=15)
        qp, rep = quantize_model(model, params, calib, "gptvq", vq_cfg)
        ppl_vq = perplexity(model, qp, heldout)

        rp, _ = quantize_model(model, params, calib, "rtn",
                               {"bits": 2, "group_size": 128})
        ppl_rtn = perplexity(model, rp, heldout)

        assert ppl_fp < ppl_rtn  # sanity: training learned something
        assert ppl_vq < ppl_rtn, (ppl_fp, ppl_vq, ppl_rtn)
        assert ppl_vq < ppl_fp * 2.5, (ppl_fp, ppl_vq)

    def test_packed_serving_matches_fake_quant(self):
        model = tiny_model()
        params = model.init_params(jax.random.PRNGKey(0))
        calib = sample_batch(jax.random.PRNGKey(9), model.cfg.vocab_size, 32, 4)
        vq_cfg = VQConfig(d=2, bits_per_dim=3, group_size=4096, em_iters=10,
                          codebook_update_iters=0)
        qp_fake, _ = quantize_model(model, params, calib, "gptvq", vq_cfg,
                                    seed=3)
        qp_pack, _ = quantize_model(model, params, calib, "gptvq", vq_cfg,
                                    pack=True, seed=3)
        batch = {"tokens": calib[:2]}
        l1, _, _ = model.forward(qp_fake, batch, remat=False)
        l2, _, _ = model.forward(qp_pack, batch, remat=False)
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   rtol=2e-2, atol=2e-1)
