"""Per-architecture smoke tests: reduced config, forward + one train step on
CPU, asserting output shapes and finiteness (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, SMOKE
from repro.models import model_zoo

B, S = 2, 16


def make_batch(cfg, key):
    ks = jax.random.split(key, 3)
    batch = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size)}
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[1], (B, cfg.encoder_seq, cfg.d_model), jnp.float32) * 0.1
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(
            ks[1], (B, cfg.n_image_tokens, cfg.d_model), jnp.float32) * 0.1
    return batch


def expected_logit_len(cfg):
    return S + (cfg.n_image_tokens if cfg.family == "vlm" else 0)


@pytest.mark.parametrize("name", sorted(SMOKE.keys()))
def test_forward_shapes_finite(name):
    cfg = SMOKE[name].scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, cache, aux = model.forward(params, batch, remat=False)
    assert logits.shape == (B, expected_logit_len(cfg), cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{name}: non-finite logits"
    assert cache is None
    assert jnp.isfinite(aux)


@pytest.mark.parametrize("name", sorted(SMOKE.keys()))
def test_train_step_decreases_loss_and_finite_grads(name):
    cfg = SMOKE[name].scaled(dtype="float32")
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    labels = jnp.roll(batch["tokens"], -1, axis=1)

    def loss_fn(p):
        logits, _, aux = model.forward(p, batch, remat=False)
        logits = logits[:, -S:, :]  # text positions only (vlm prepends image)
        lp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.mean(jnp.take_along_axis(lp, labels[..., None], -1))
        return nll + aux

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert jnp.isfinite(loss), name
    finite = jax.tree.map(lambda g: bool(jnp.all(jnp.isfinite(g))), grads)
    assert all(jax.tree.leaves(finite)), f"{name}: non-finite grads"
    # one SGD step must change the loss (graph is connected)
    params2 = jax.tree.map(lambda p, g: p - 1e-2 * g, params, grads)
    loss2 = loss_fn(params2)
    assert jnp.isfinite(loss2) and abs(float(loss2 - loss)) > 0

@pytest.mark.parametrize("name", sorted(SMOKE.keys()))
def test_decode_matches_prefill(name):
    """KV-cache decode must agree with the parallel forward (tolerance for
    recurrent fp accumulation)."""
    # dropless capacity: token-drop patterns legitimately differ between
    # prefill and decode, so remove drops for this equivalence check
    cfg = SMOKE[name].scaled(dtype="float32", moe_capacity_factor=8.0)
    model = model_zoo.build(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    n_img = cfg.n_image_tokens if cfg.family == "vlm" else 0
    max_len = S + n_img + 4
    full_logits, _, _ = model.forward(params, batch, remat=False)

    cache = model.init_cache(B, max_len, dtype=jnp.float32)
    prefill = dict(batch)
    prefill["tokens"] = batch["tokens"][:, : S - 1]
    logits_p, cache, _ = model.forward(params, prefill, cache=cache, pos=0,
                                       remat=False)
    step = {"tokens": batch["tokens"][:, S - 1 :]}
    logits_d, cache, _ = model.forward(params, step, cache=cache,
                                       pos=S - 1 + n_img, remat=False)
    np.testing.assert_allclose(
        np.asarray(logits_d[:, 0]), np.asarray(full_logits[:, -1]),
        rtol=2e-2, atol=2e-2,
    )


def test_param_specs_match_param_trees():
    """Every arch: spec tree structure == param tree structure."""
    for name, cfg in SMOKE.items():
        model = model_zoo.build(cfg.scaled(dtype="float32"))
        shapes = model_zoo.abstract_params(model)
        specs = model.param_specs()
        t1 = jax.tree.structure(shapes)
        t2 = jax.tree.structure(
            specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        assert t1 == t2, f"{name}: param/spec tree mismatch\n{t1}\n{t2}"


def test_full_config_param_counts():
    """Full (non-smoke) configs roughly match their nameplate sizes."""
    import re
    expect = {
        "qwen3-1.7b": (1.4e9, 2.6e9),
        "qwen2-72b": (65e9, 80e9),
        "minitron-4b": (3.5e9, 5.5e9),
        "yi-34b": (30e9, 38e9),
        "xlstm-125m": (0.1e9, 0.25e9),
        "dbrx-132b": (110e9, 145e9),
        "qwen3-moe-30b-a3b": (26e9, 34e9),
        "phi-3-vision-4.2b": (3.6e9, 4.8e9),
        "whisper-small": (0.2e9, 0.45e9),
        "zamba2-7b": (6e9, 9e9),
        "llama2-7b": (6e9, 7.5e9),
    }
    for name, cfg in ARCHS.items():
        model = model_zoo.build(cfg)
        n = model_zoo.count_params(model)
        lo, hi = expect[name]
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params not in [{lo/1e9},{hi/1e9}]B"
