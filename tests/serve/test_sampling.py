"""Unit tests for serve/sampling.py — the top-k edge cases the rank-based
cut fixes (tied logits at the k-th value, top_k >= V), plus top-k/top-p
composition and the greedy/temperature dispatch contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serve import sampling


def empirical_support(key, logits, n=256, **kw):
    """Indices a sampler can actually produce, over n independent draws."""
    keys = jax.random.split(key, n)
    draws = {int(sampling.sample(k, logits, **kw)[0]) for k in keys}
    return draws


class TestTopK:
    def test_ties_at_kth_keep_exactly_k(self):
        """Four-way tie at the top with k=2: a threshold cut keeps all
        four; the rank cut must keep exactly two (the lowest indices,
        by stable-sort determinism)."""
        logits = jnp.asarray([[1.0, 1.0, 1.0, 1.0, 0.0]])
        got = empirical_support(jax.random.PRNGKey(0), logits,
                                temperature=1.0, top_k=2)
        assert got == {0, 1}

    def test_k_equals_vocab_matches_unrestricted(self):
        """top_k == V filters nothing: same distribution as no top_k
        (bitwise — the surviving logits are untouched)."""
        logits = jnp.asarray([[0.3, -0.2, 0.9, 0.0]])
        key = jax.random.PRNGKey(1)
        a = sampling.sample(key, logits, temperature=1.0, top_k=4)
        b = sampling.sample(key, logits, temperature=1.0)
        assert int(a[0]) == int(b[0])

    def test_k_larger_than_vocab_no_crash(self):
        """top_k > V used to index out of range on the sorted axis; it
        must clamp to V and behave like unrestricted sampling."""
        logits = jnp.asarray([[0.5, 0.1, -0.4]])
        key = jax.random.PRNGKey(2)
        a = sampling.sample(key, logits, temperature=1.0, top_k=100)
        b = sampling.sample(key, logits, temperature=1.0)
        assert int(a[0]) == int(b[0])

    def test_k1_is_argmax(self):
        logits = jnp.asarray([[0.1, 2.0, -1.0, 1.9]])
        got = empirical_support(jax.random.PRNGKey(3), logits, n=64,
                                temperature=1.0, top_k=1)
        assert got == {1}

    def test_distinct_logits_keep_top_k_set(self):
        logits = jnp.asarray([[0.0, 3.0, 1.0, 2.0, -1.0]])
        got = empirical_support(jax.random.PRNGKey(4), logits,
                                temperature=1.0, top_k=3)
        assert got == {1, 2, 3}

    def test_per_row_independence(self):
        """The rank cut is per row: a tie in one row must not leak
        candidates into another."""
        logits = jnp.asarray([[1.0, 1.0, 1.0, 0.0],
                              [0.0, 0.0, 5.0, 4.0]])
        keys = jax.random.split(jax.random.PRNGKey(5), 128)
        row0 = {int(sampling.sample(k, logits, temperature=1.0,
                                    top_k=2)[0]) for k in keys}
        row1 = {int(sampling.sample(k, logits, temperature=1.0,
                                    top_k=2)[1]) for k in keys}
        assert row0 == {0, 1}
        assert row1 == {2, 3}


class TestTopKTopP:
    def test_combined_restricts_to_intersection(self):
        """top-k prunes first, top-p then cuts the renormalized tail of
        the survivors: with a dominant pair and tiny top_p only the
        top-1 of the top-k set remains."""
        logits = jnp.asarray([[5.0, 4.0, 3.0, 2.0, 1.0]])
        got = empirical_support(jax.random.PRNGKey(6), logits,
                                temperature=1.0, top_k=3, top_p=0.5)
        assert got <= {0, 1, 2}
        assert 0 in got
        assert 3 not in got and 4 not in got

    def test_combined_with_ties_no_crash_exact_support(self):
        logits = jnp.asarray([[2.0, 2.0, 2.0, 2.0, -5.0, -5.0]])
        got = empirical_support(jax.random.PRNGKey(7), logits,
                                temperature=1.0, top_k=8, top_p=0.95)
        assert got <= {0, 1, 2, 3}


class TestDispatch:
    def test_greedy_ignores_filters(self):
        logits = jnp.asarray([[0.0, 1.0, 0.5]])
        out = sampling.sample(jax.random.PRNGKey(8), logits,
                              temperature=0.0, top_k=1)
        assert int(out[0]) == 1

    def test_traced_temperature_vector_mixes_greedy_and_sampled(self):
        logits = jnp.asarray([[0.0, 9.0, 0.0], [1.0, 1.0, 1.0]])
        t = jnp.asarray([0.0, 1.0])
        out = sampling.sample(jax.random.PRNGKey(9), logits, temperature=t)
        assert int(out[0]) == 1
        assert int(out[1]) in (0, 1, 2)

    def test_traced_temperature_with_top_k_ties(self):
        """The engine's jitted path (traced (B,) temperatures) runs
        through the same rank cut."""
        logits = jnp.asarray([[1.0, 1.0, 1.0, 0.0]])
        t = jnp.asarray([1.0])
        f = jax.jit(lambda k, l: sampling.sample(k, l, temperature=t,
                                                 top_k=2))
        keys = jax.random.split(jax.random.PRNGKey(10), 128)
        got = {int(f(k, logits)[0]) for k in keys}
        assert got == {0, 1}
