"""Scheduler fuzz: random admit/prefill/decode/preempt sequences against
serve/scheduler.py + the block allocator, asserting the structural
invariants directly (no model in the loop), plus an engine-level fuzz that
drives random workloads through oversubscribed pools and checks preempted
prompts replay to identical greedy outputs.

The host-side fuzz mirrors exactly the calls the engine makes each tick
(admit_from_queue -> prefill_chunk_len/pos advance -> ensure_block ->
emit/finish), so any interleaving the engine can produce is reachable.
"""
import dataclasses
from collections import Counter

import numpy as np
import pytest

from repro.serve.engine import Engine, Request
from repro.serve.paged_cache import BlockAllocator
from repro.serve.prefix_cache import PrefixCache
from repro.serve.scheduler import Scheduler


@dataclasses.dataclass
class FuzzReq:
    rid: int
    prompt: np.ndarray
    max_new_tokens: int
    out: int = 0          # tokens emitted so far


def check_invariants(sched: Scheduler, num_blocks: int):
    """Structural invariants that must hold between any two ticks.

    Refcount-aware: blocks may legitimately appear in several sequences'
    page lists (prefix sharing) and in the radix cache at once — but the
    allocator's refcount must equal the exact number of holders, every
    referenced block must be off the free list, and no block may be
    neither free nor referenced (leak) or both (corruption)."""
    alloc = sched.allocator
    refs = Counter(b for s in sched.active() for b in s.pages)
    cache_blocks = (sched.prefix_cache.blocks()
                    if sched.prefix_cache is not None else set())
    for b in cache_blocks:
        refs[b] += 1
    # one sequence never maps the same block at two logical pages, and
    # scratch block 0 is never handed out anywhere
    for s in sched.active():
        assert len(s.pages) == len(set(s.pages)), "page list repeats block"
    assert 0 not in refs and 0 not in alloc._free
    assert not (set(refs) & set(alloc._free)), "block both held and free"
    for b, n in refs.items():
        assert alloc.refcount(b) == n, \
            f"block {b}: refcount {alloc.refcount(b)} != {n} holders"
    assert len(refs) + alloc.free_blocks == num_blocks - 1, \
        "blocks leaked or conjured"
    for s in sched.active():
        # every written position is backed by a mapped page, and the page
        # count never overshoots what placement (all prompt pages up
        # front) plus the decode block supply (one page per boundary
        # crossing) can have mapped
        assert s.pos <= len(s.pages) * sched.page_size
        prompt_pages = -(-s.prompt_len // sched.page_size)
        decode_pages = -(-max(s.pos, 1) // sched.page_size) + 1
        assert len(s.pages) <= max(prompt_pages, decode_pages)
        assert 0 <= s.pos <= s.prompt_len + s.req.max_new_tokens
        assert sched.running[s.slot] is s


def check_metric_invariants(eng: Engine):
    """Telemetry invariants the engine must uphold at every tick boundary:
    the occupancy gauges mirror the allocator exactly (which the structural
    invariants above tie to the blocks actually held by sequences), and the
    per-request token records sum to the engine's token counter — the
    recompute-style preempt discards both sides together, so replay never
    double-counts."""
    alloc = eng.scheduler.allocator
    reg = eng.telemetry.registry
    assert reg.gauge("serve.pool_used_blocks").value == alloc.used_blocks
    assert reg.gauge("serve.pool_free_blocks").value == alloc.free_blocks
    assert reg.gauge("serve.shared_blocks").value == alloc.shared_blocks
    held = {b for s in eng.scheduler.active() for b in s.pages}
    if eng.prefix_cache is not None:
        held |= eng.prefix_cache.blocks()
    assert alloc.used_blocks == len(held), \
        "occupancy gauge ground truth drifted"
    assert eng.telemetry.request_token_total() == eng.stats["tokens"]
    assert reg.counter("serve.tokens").value == eng.stats["tokens"]


@pytest.mark.parametrize("with_prefix_cache", [False, True])
@pytest.mark.parametrize("seed", range(6))
def test_scheduler_fuzz_invariants(seed, with_prefix_cache):
    """The refcounted-pool fuzz: random admit/tick/preempt interleavings,
    with and without the radix prefix cache attached. With the cache on,
    prompts are drawn from a 3-token alphabet so shared full-page
    prefixes (and divergent tails) occur constantly, admissions share
    blocks, LRU eviction fires under pool pressure, and every tick
    asserts the exact per-block refcount against the set of holders."""
    rng = np.random.RandomState(seed)
    num_blocks = int(rng.randint(4, 12))
    page_size = int(rng.choice([2, 4, 8]))
    max_batch = int(rng.randint(1, 4))
    max_len = page_size * (num_blocks - 1)
    alloc = BlockAllocator(num_blocks)
    cache = PrefixCache(alloc, page_size) if with_prefix_cache else None
    sched = Scheduler(
        max_batch=max_batch, max_len=max_len, page_size=page_size,
        allocator=alloc, prefix_cache=cache,
        prefill_chunk=int(rng.choice([4, 8, 16])),
        pad_prefill=bool(rng.randint(2)))
    reqs = {}
    emitted = {}          # rid -> tokens counted where sampled (engine rule)
    next_rid = 0
    for step in range(300):
        op = rng.randint(3)
        if op == 0 and len(reqs) < 25:
            # submit a random (sometimes infeasible) request; the tiny
            # alphabet makes full-page prefix collisions the norm
            plen = int(rng.randint(1, max_len + 2))
            mnt = int(rng.randint(1, 6))
            r = FuzzReq(next_rid, rng.randint(0, 3, size=plen).astype(
                np.int32), mnt)
            next_rid += 1
            try:
                sched.submit(r)
                reqs[r.rid] = r
                emitted[r.rid] = 0
            except Exception:
                assert plen + mnt > max_len or \
                    -(-(plen + mnt) // page_size) > sched.allocator.capacity
        elif op == 1:
            # one engine tick: admissions + one prefill chunk per
            # prefilling seq + a decode pass with block supply
            sched.admit_from_queue()
            for s in sorted((x for x in sched.active()
                             if x.phase == "prefill"),
                            key=lambda x: x.order):
                size, real = sched.prefill_chunk_len(s)
                assert size & (size - 1) == 0, "non-pow2 chunk"
                assert real <= size and real <= s.prompt_len - s.pos
                s.pos += real
                if s.pos == s.prompt_len:
                    s.phase = "decode"
                    if cache is not None:  # engine's _on_prompt_done
                        cache.insert(s.req.prompt, s.pages)
                    s.req.out += 1
                    emitted[s.req.rid] += 1
                    if s.req.out >= s.req.max_new_tokens:
                        sched.finish(s)
            for s in sorted((x for x in sched.active()
                             if x.phase == "decode"),
                            key=lambda x: x.order):
                if sched.running[s.slot] is not s:
                    continue  # preempted by an earlier victim this tick
                for v in sched.ensure_block(s):
                    emitted[v.req.rid] -= v.req.out  # recompute-style
                    v.req.out = 0
            for s in [x for x in sched.active() if x.phase == "decode"]:
                s.pos += 1
                s.req.out += 1
                emitted[s.req.rid] += 1
                if s.req.out >= s.req.max_new_tokens:
                    sched.finish(s)
        else:
            # spontaneous preemption of a random running sequence
            live = sched.active()
            if live:
                victim = live[rng.randint(len(live))]
                sched.preempt(victim)
                emitted[victim.req.rid] -= victim.req.out
                victim.req.out = 0
        check_invariants(sched, num_blocks)
    # token accounting: every finished request emitted exactly
    # max_new_tokens; running/queued ones no more than that
    for r in reqs.values():
        assert emitted[r.rid] == r.out
        assert 0 <= r.out <= r.max_new_tokens
    # drain: with no more fuzz preemptions everything must complete
    for _ in range(2000):
        if not sched.has_work():
            break
        sched.admit_from_queue()
        for s in sorted((x for x in sched.active()
                         if x.phase == "prefill"), key=lambda x: x.order):
            _, real = sched.prefill_chunk_len(s)
            s.pos += real
            if s.pos == s.prompt_len:
                s.phase = "decode"
                if cache is not None:
                    cache.insert(s.req.prompt, s.pages)
                s.req.out += 1
                if s.req.out >= s.req.max_new_tokens:
                    sched.finish(s)
        for s in sorted((x for x in sched.active()
                         if x.phase == "decode"), key=lambda x: x.order):
            if sched.running[s.slot] is not s:
                continue
            for v in sched.ensure_block(s):
                v.req.out = 0
        for s in [x for x in sched.active() if x.phase == "decode"]:
            s.pos += 1
            s.req.out += 1
            if s.req.out >= s.req.max_new_tokens:
                sched.finish(s)
        check_invariants(sched, num_blocks)
    assert not sched.has_work(), "drain did not converge"
    for r in reqs.values():
        assert r.out == r.max_new_tokens
    if cache is not None:
        # with every sequence gone, only the cache's own reference is
        # left on each cached block — and clearing it drains the pool
        for b in cache.blocks():
            assert alloc.refcount(b) == 1
        cache.clear()
        assert alloc.free_blocks == alloc.capacity


@pytest.mark.parametrize("seed", range(3))
def test_engine_fuzz_preemption_replay(seed):
    """Random workloads through a tight pool: preempted prompts must
    replay to the exact greedy outputs of an unpressured engine, and the
    engine's token accounting must match what the requests received."""
    import jax

    from tests.serve.test_paged_serving import family_model

    model, params = family_model("dense")
    rng = np.random.RandomState(100 + seed)
    V = model.cfg.vocab_size - 1
    prompts = [rng.randint(0, V, size=int(rng.randint(1, 20)))
               for _ in range(int(rng.randint(3, 7)))]
    news = [int(rng.randint(1, 9)) for _ in prompts]

    def run(num_blocks):
        eng = Engine(model, params, max_batch=2, max_len=64, page_size=4,
                     num_blocks=num_blocks)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, news))]
        eng.run(reqs)
        return eng, reqs

    big, ref = run(num_blocks=None)        # pool holds every slot fully
    assert big.stats["preemptions"] == 0
    tight, out = run(num_blocks=9)         # 8 usable blocks for 2 slots
    for a, b in zip(ref, out):
        assert a.out_tokens == b.out_tokens, (seed, a.rid)
        assert len(b.out_tokens) == b.max_new_tokens
    assert tight.stats["tokens"] == sum(len(r.out_tokens) for r in out)
    # telemetry stayed consistent through preemption + replay: the drained
    # request records credit exactly the tokens the engine counted, and
    # every preemption the engine saw was recorded
    check_metric_invariants(tight)
    recs = tight.drain_request_records()
    assert sum(r.tokens for r in recs) == tight.stats["tokens"]
    assert sum(r.preemptions for r in recs) == tight.stats["preemptions"]
    assert {r.rid for r in recs} == {r.rid for r in out}
    assert all(r.finish_reason == "length" for r in recs)


@pytest.mark.parametrize("seed", range(2))
def test_engine_fuzz_quantized_pool(seed):
    """The same random admit/tick/preempt workload with kv_cache_bits=8:
    the allocator/accounting invariants must hold between every engine
    tick of an oversubscribed *quantized* pool (recycled blocks now carry
    stale codes AND stale scales), and preemption-replay must reproduce
    the exact greedy tokens of a solo run with the same spec — per-row
    quantization is deterministic, so a replayed prefill re-creates
    byte-identical pages no matter which physical blocks it lands on."""
    import jax

    from tests.serve.test_paged_serving import family_model

    model, params = family_model("dense")
    rng = np.random.RandomState(200 + seed)
    V = model.cfg.vocab_size - 1
    prompts = [rng.randint(0, V, size=int(rng.randint(1, 20)))
               for _ in range(int(rng.randint(3, 6)))]
    news = [int(rng.randint(1, 8)) for _ in prompts]

    def run_checked(num_blocks):
        eng = Engine(model, params, max_batch=2, max_len=64, page_size=4,
                     num_blocks=num_blocks, kv_cache_bits=8)
        reqs = [Request(rid=i, prompt=p, max_new_tokens=n)
                for i, (p, n) in enumerate(zip(prompts, news))]
        for r in reqs:
            eng.scheduler.submit(r)
        while eng.scheduler.has_work() and eng.ticks < 10_000:
            eng.step()
            check_invariants(eng.scheduler, eng.layout.num_blocks)
            check_metric_invariants(eng)
        return eng, reqs

    tight, out = run_checked(num_blocks=9)   # 8 usable blocks for 2 slots
    assert tight.stats["tokens"] == sum(len(r.out_tokens) for r in out)
    for i, (p, n) in enumerate(zip(prompts, news)):
        solo = Engine(model, params, max_batch=2, max_len=64, page_size=4,
                      kv_cache_bits=8)
        r = Request(rid=500 + i, prompt=p, max_new_tokens=n)
        solo.run([r])
        assert r.out_tokens == out[i].out_tokens, (seed, i)
        assert len(r.out_tokens) == n


@pytest.mark.parametrize("seed", range(2))
def test_engine_fuzz_prefix_cache_oversubscribed(seed):
    """Shared-prefix workloads through an oversubscribed *refcounted*
    pool with the prefix cache on: every engine tick must uphold the
    per-block refcount invariants (holders = sequences' page lists + the
    cache, exactly) while admissions share blocks, the LRU evicts under
    pressure, and preempted sharers release-and-replay. Outputs must
    stay greedy-token-identical to unshared solo runs, and once all
    requests finish, evicting the cache must return the pool to full."""
    from tests.serve.test_paged_serving import family_model

    model, params = family_model("dense")
    rng = np.random.RandomState(300 + seed)
    V = model.cfg.vocab_size - 1
    header = rng.randint(0, V, size=8)
    prompts = [np.concatenate([
        header[:int(rng.choice([4, 8]))],
        rng.randint(0, V, size=int(rng.randint(1, 8)))])
        for _ in range(int(rng.randint(4, 7)))]
    news = [int(rng.randint(1, 8)) for _ in prompts]

    eng = Engine(model, params, max_batch=2, max_len=64, page_size=4,
                 num_blocks=9, prefix_cache=True)
    reqs = [Request(rid=i, prompt=p, max_new_tokens=n)
            for i, (p, n) in enumerate(zip(prompts, news))]
    for r in reqs:
        eng.submit(r)
    while eng.scheduler.has_work() and eng.ticks < 10_000:
        eng.step()
        check_invariants(eng.scheduler, eng.layout.num_blocks)
        check_metric_invariants(eng)
    assert all(len(r.out_tokens) == r.max_new_tokens for r in reqs)
    for i, (p, n) in enumerate(zip(prompts, news)):
        solo = Engine(model, params, max_batch=2, max_len=64, page_size=4)
        r = Request(rid=600 + i, prompt=p, max_new_tokens=n)
        solo.run([r])
        assert r.out_tokens == reqs[i].out_tokens, (seed, i)
    alloc, cache = eng.scheduler.allocator, eng.prefix_cache
    for b in cache.blocks():
        assert alloc.refcount(b) == 1
    while cache.evict_one():
        pass
    assert cache.cached_blocks == 0
    assert alloc.free_blocks == alloc.capacity
