"""Unit tests for the radix prefix cache (serve/prefix_cache.py):
host-side tree/refcount logic only — no jax, no model. Engine-level
token-identity coverage lives in test_paged_serving.TestPrefixSharing;
allocator-interaction fuzz in test_scheduler_fuzz."""
import numpy as np
import pytest

from repro.serve.paged_cache import BlockAllocator
from repro.serve.prefix_cache import PrefixCache

PS = 4  # page size for all tests here


def toks(*pages):
    """Concatenate page-sized runs of a repeated marker token each."""
    out = []
    for p in pages:
        out.extend([p] * PS)
    return np.asarray(out, np.int32)


def make(num_blocks=32):
    a = BlockAllocator(num_blocks)
    return a, PrefixCache(a, PS)


class TestLookupInsert:
    def test_miss_on_empty_cache(self):
        a, c = make()
        assert c.lookup(toks(1, 2, 3)) == []
        assert c.misses == 1 and c.hits == 0

    def test_roundtrip_shares_full_pages_only(self):
        a, c = make()
        prompt = np.concatenate([toks(1, 2), [7, 7]])  # 2 full pages + tail
        pages = a.alloc(3)
        c.insert(prompt, pages)
        assert c.cached_blocks == 2          # the partial page never caches
        got = c.lookup(prompt)
        assert got == pages[:2]
        # each matched block: owner + cache + the lookup's new reference
        assert all(a.refcount(b) == 3 for b in got)

    def test_exact_full_page_prompt_caps_at_minus_one(self):
        """A prompt that is exactly N full pages may share at most N-1:
        the last token must prefill privately (it supplies the logits
        the engine samples the first output token from)."""
        a, c = make()
        prompt = toks(1, 2, 3)
        pages = a.alloc(3)
        c.insert(prompt, pages)
        assert c.cached_blocks == 3          # insert caches all full pages
        assert c.lookup(prompt) == pages[:2]  # ...lookup stops at N-1

    def test_divergent_tail_matches_common_prefix(self):
        a, c = make()
        pa, pb = a.alloc(3), a.alloc(3)
        c.insert(toks(1, 2, 3), pa)
        c.insert(toks(1, 2, 9), pb)
        # page 0/1 nodes are shared in the tree; pb's third page forks
        assert c.cached_blocks == 4
        assert c.lookup(toks(1, 2, 9, 5)) == pa[:2] + [pb[2]]

    def test_insert_existing_keeps_first_block(self):
        """Re-inserting an identical prefix from a second sequence keeps
        the original node's block (contents are identical by
        determinism); the second sequence's private copy just releases
        normally when it finishes."""
        a, c = make()
        pa, pb = a.alloc(2), a.alloc(2)
        c.insert(toks(1, 2), pa)
        c.insert(toks(1, 2), pb)
        assert c.cached_blocks == 2
        assert c.lookup(toks(1, 2, 9)) == pa
        assert a.refcount(pb[0]) == 1        # no cache ref ever taken

    def test_single_page_prompt_never_shares(self):
        a, c = make()
        prompt = toks(1)
        c.insert(prompt, a.alloc(1))
        assert c.lookup(prompt) == []        # (len-1)//PS == 0 pages


class TestEviction:
    def test_evicts_lru_leaf_first(self):
        a, c = make()
        pa, pb = a.alloc(2), a.alloc(2)
        c.insert(toks(1, 2), pa)
        c.insert(toks(3, 4), pb)
        a.release(pa)
        a.release(pb)
        got = c.lookup(toks(3, 4, 9))        # refresh pb's branch
        a.release(got)
        assert c.evict_one()
        # pa's branch was LRU: its leaf (page 1) went first
        assert pa[1] not in c.blocks() and pb[1] in c.blocks()

    def test_interior_nodes_evict_after_children(self):
        a, c = make()
        pa = a.alloc(3)
        c.insert(toks(1, 2, 3), pa)
        a.release(pa)
        order = []
        while c.evict_one():
            order.append(True)
        assert len(order) == 3 and c.cached_blocks == 0
        assert a.free_blocks == a.capacity   # everything back in the pool

    def test_blocks_shared_with_live_sequence_not_evictable(self):
        a, c = make()
        pa = a.alloc(2)
        c.insert(toks(1, 2), pa)             # owner + cache hold both
        assert not c.evict_one()             # refcount 2 everywhere
        a.release([pa[1]])                   # owner drops the leaf page
        assert c.evict_one()                 # now the leaf is refcount 1
        assert not c.evict_one()             # page 0 still co-held
        a.release([pa[0]])
        assert c.evict_one()
        assert a.free_blocks == a.capacity

    def test_clear_releases_everything(self):
        a, c = make()
        pa = a.alloc(2)
        c.insert(toks(1, 2), pa)
        a.release(pa)
        c.clear()
        assert a.free_blocks == a.capacity and c.cached_blocks == 0


class TestStats:
    def test_hit_and_token_accounting(self):
        a, c = make()
        pa = a.alloc(3)
        c.insert(toks(1, 2, 3), pa)
        got = c.lookup(toks(1, 2, 5, 6))
        assert c.hits == 1 and c.hit_tokens == 2 * PS
        a.release(got)
        assert c.lookup(toks(9, 9)) == []
        assert c.misses == 1
