"""Paged serving subsystem tests: block allocator, chunked-prefill plan,
capacity-aware admission, token accounting, preemption, the mixed-length
continuous-batching regression (the shared-max-position bug: interleaved
admission of staggered-length prompts must be token-identical to serving
each request alone), quantized KV pages (int8/int4 pools: solo-vs-
interleaved token identity, an explicit int8 logit-drift bound vs the
fp32-cache anchor, and byte-denominated pool sizing headroom), and the
fused VQ-dequant matmul serving path (vq_matmul_impl: gather/xla/pallas
greedy token identity over VQ-packed checkpoints + dispatch-counter
pinning)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import FAMILY_REPRESENTATIVE, SMOKE
from repro.models import model_zoo
from repro.serve.engine import Engine, Request
from repro.serve.paged_cache import BlockAllocator
from repro.serve.scheduler import CapacityError, next_chunk_len
from repro.serve.serve_step import make_decode, make_prefill

FAMILIES = list(FAMILY_REPRESENTATIVE)  # dense moe vlm ssm hybrid audio
_MODELS: dict = {}


def family_model(family: str):
    """Cached smoke model per family (params are deterministic per key)."""
    if family not in _MODELS:
        if family == "dense":
            cfg = SMOKE["llama2-7b"].scaled(
                dtype="float32", n_layers=2, d_model=64, vocab_size=256,
                max_seq_len=64)
        else:
            cfg = SMOKE[FAMILY_REPRESENTATIVE[family]].scaled(
                dtype="float32")
        model = model_zoo.build(cfg)
        _MODELS[family] = (model,
                           model.init_params(jax.random.PRNGKey(0)))
    return _MODELS[family]


def dense_model():
    return family_model("dense")[0]


def hybrid_model():
    return family_model("hybrid")[0]


def greedy_reqs(prompts, n=6, rid0=0):
    return [Request(rid=rid0 + i, prompt=p, max_new_tokens=n)
            for i, p in enumerate(prompts)]


class TestBlockAllocator:
    def test_alloc_free_exhaust(self):
        a = BlockAllocator(5)  # block 0 reserved scratch -> 4 usable
        assert a.capacity == 4
        got = a.alloc(3)
        assert len(got) == 3 and all(0 < b < 5 for b in got)
        assert a.alloc(2) is None  # all-or-nothing
        assert a.free_blocks == 1
        a.free(got)
        assert a.free_blocks == 4

    def test_scratch_never_handed_out(self):
        a = BlockAllocator(4)
        assert 0 not in a.alloc(3)

    def test_double_free_raises(self):
        a = BlockAllocator(4)
        got = a.alloc(1)
        a.free(got)
        with pytest.raises(ValueError):
            a.free(got)
        assert a.free_blocks == 3  # free list not corrupted by the raise

    def test_free_unknown_or_invalid_id_raises(self):
        a = BlockAllocator(4)
        a.alloc(1)
        with pytest.raises(ValueError):
            a.free([3])   # in range but never handed out
        with pytest.raises(ValueError):
            a.free([0])   # scratch is never allocatable
        with pytest.raises(ValueError):
            a.free([99])  # out of range

    def test_refcount_share_release(self):
        a = BlockAllocator(4)
        (b,) = a.alloc(1)
        assert a.refcount(b) == 1 and a.shared_blocks == 0
        a.share([b, b])
        assert a.refcount(b) == 3 and a.shared_blocks == 1
        a.release([b])
        a.release([b])
        assert a.refcount(b) == 1 and a.free_blocks == 2
        a.release([b])
        assert a.refcount(b) == 0 and a.free_blocks == 3
        with pytest.raises(ValueError):
            a.release([b])  # already back in the pool

    def test_share_unallocated_raises(self):
        a = BlockAllocator(4)
        with pytest.raises(ValueError):
            a.share([2])

    def test_shared_block_survives_one_release(self):
        """The prefix-sharing contract: a block referenced by two holders
        stays out of the free list until BOTH release it."""
        a = BlockAllocator(4)
        (b,) = a.alloc(1)
        a.share([b])
        a.release([b])
        assert a.free_blocks == 2 and a.refcount(b) == 1
        got = a.alloc(2)
        assert b not in got  # still held — never re-handed out
        a.release([b])
        assert a.free_blocks == 1


class TestChunkPlan:
    def test_pow2_decomposition_covers_prompt(self):
        for S in (1, 2, 5, 13, 64, 100, 255):
            sizes, rem = [], S
            while rem:
                c = next_chunk_len(rem, 64)
                assert c & (c - 1) == 0 and c <= 64
                sizes.append(c)
                rem -= c
            assert sum(sizes) == S
            # O(log): at most ceil(S/max) full chunks + log2(max) tail
            assert len(sizes) <= S // 64 + 7, (S, sizes)


class TestAdmissionAndStats:
    def test_stats_initialized_before_run(self):
        model = dense_model()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_batch=2, max_len=48)
        assert eng.stats["tokens"] == 0  # no AttributeError pre-run

    def test_capacity_error_is_typed_and_graceful(self):
        model = dense_model()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_batch=2, max_len=32, page_size=4)
        rng = np.random.RandomState(0)
        with pytest.raises(CapacityError):
            eng.admit(Request(rid=0, prompt=rng.randint(0, 255, size=30),
                              max_new_tokens=8))
        # run() rejects the oversized request but still serves the rest
        bad = Request(rid=1, prompt=rng.randint(0, 255, size=30),
                      max_new_tokens=8)
        ok = Request(rid=2, prompt=rng.randint(0, 255, size=5),
                     max_new_tokens=4)
        eng.run([bad, ok])
        assert bad.error is not None and bad.out_tokens == []
        assert len(ok.out_tokens) == 4

    def test_token_accounting_counts_final_tick(self):
        """Regression: tokens sampled on a request's final tick used to be
        dropped (the old run() counted surviving slots after step() freed
        finished ones)."""
        model = dense_model()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_batch=3, max_len=48)
        rng = np.random.RandomState(0)
        reqs = greedy_reqs([rng.randint(0, 255, size=5 + i)
                            for i in range(5)], n=4)
        eng.run(reqs)
        assert eng.stats["tokens"] == sum(len(r.out_tokens) for r in reqs)
        assert eng.stats["tokens"] == 20

    def test_prefill_compiles_pow2_variants_only(self):
        """Admitting prompts of many distinct lengths must only trace the
        step fn at power-of-two chunk widths (plus the decode shape)."""
        model = dense_model()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_batch=2, max_len=64, page_size=8,
                     prefill_chunk=16)
        rng = np.random.RandomState(0)
        reqs = greedy_reqs([rng.randint(0, 255, size=s)
                            for s in (3, 5, 7, 9, 11, 13, 21)], n=2)
        eng.run(reqs)
        sizes = {1, 2, 4, 8, 16}  # pow2 chunks <= prefill_chunk
        assert eng._prefill_fn._cache_size() <= len(sizes)
        assert eng._decode_fn._cache_size() == 1


class TestMixedLengthContinuousBatching:
    """THE regression test for the shared-max-position bug: late-admitted
    slots used to write at the oldest slot's position, leaving gaps.

    Runs on every zoo family (attention caches page; recurrent state stays
    slot-resident; audio decodes against resident cross-K/V; MoE routes
    per-row so batched rows stay independent), with the paged decode
    attention on the fused-kernel path ("pallas", interpret off-TPU) and
    the gather path — interleaved continuous batching must be
    token-identical to serving each request alone under either impl."""

    @pytest.mark.parametrize("impl", ["gather", "pallas"])
    @pytest.mark.parametrize("family", FAMILIES)
    def test_interleaved_matches_solo(self, family, impl):
        if family == "ssm" and impl == "pallas":
            pytest.skip("ssm has no attention KV leaves — no paged "
                        "attention to fuse (covered by gather run)")
        model, params = family_model(family)
        rng = np.random.RandomState(1)
        V = model.cfg.vocab_size - 1
        prompts = [rng.randint(0, V, size=s) for s in (5, 9, 3, 12)]
        eng = Engine(model, params, max_batch=2, max_len=64, page_size=8,
                     paged_attn_impl=impl)
        reqs = greedy_reqs(prompts)
        eng.run(reqs)
        assert all(len(r.out_tokens) == 6 for r in reqs)
        for i, p in enumerate(prompts):
            solo = Engine(model, params, max_batch=2, max_len=64,
                          page_size=8, paged_attn_impl=impl)
            r = greedy_reqs([p], rid0=100 + i)[0]
            solo.run([r])
            assert r.out_tokens == reqs[i].out_tokens, (family, impl, i)

    def test_width1_prefill_chunk_keeps_gather_path(self, dispatch_counters):
        """Regression: a prompt whose pow2 decomposition ends in a width-1
        chunk satisfies the fused path's S == 1 shape test — prefill must
        still be pinned to the gather read path (only the decode closure
        bakes the fused impl). Pinned via the "paged" dispatch counters
        (obs/dispatch), which increment at trace time; the fixture zeroes
        them so the counts below are absolute."""
        model, params = family_model("dense")
        eng = Engine(model, params, max_batch=1, max_len=64, page_size=8,
                     prefill_chunk=16, paged_attn_impl="pallas")
        rng = np.random.RandomState(7)
        # 17 = 16 + 1: the tail prefill chunk is width 1
        req = greedy_reqs([rng.randint(0, 255, size=17)], n=3)[0]
        eng.run([req])
        counts = dispatch_counters()["paged"]
        assert len(req.out_tokens) == 3
        # exactly one fused trace (the decode closure); every prefill
        # trace — including the width-1 tail chunk — took gather
        assert counts["pallas"] == 1
        assert counts["gather"] > 0

    def test_padded_chunk_overhanging_max_len_matches_reference(self):
        """A prompt whose padded prefill bucket overhangs the page-table
        extent must not corrupt its own live K/V (regression: out-of-range
        pages used to be clipped into the slot's last page)."""
        model = dense_model()
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.RandomState(3)
        # 40 tokens pad to a 64-wide chunk; positions 48..63 overhang the
        # 48-token table and must land in scratch
        prompt = rng.randint(0, 255, size=40)
        eng = Engine(model, params, max_batch=1, max_len=48, page_size=16)
        req = greedy_reqs([prompt])[0]
        eng.run([req])

        cache = model.init_cache(1, 48, dtype=jnp.float32)
        logits, cache = jax.jit(make_prefill(model))(
            params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
        decode = jax.jit(make_decode(model))
        tok = int(jnp.argmax(logits[0, len(prompt) - 1]))
        ref, pos = [tok], len(prompt)
        for _ in range(5):
            logits, cache = decode(params, jnp.asarray([[tok]], jnp.int32),
                                   cache, pos)
            tok = int(jnp.argmax(logits[0, -1]))
            ref.append(tok)
            pos += 1
        assert ref == req.out_tokens

    def test_empty_prompt_rejected(self):
        model = dense_model()
        params = model.init_params(jax.random.PRNGKey(0))
        eng = Engine(model, params, max_batch=2, max_len=48)
        with pytest.raises(CapacityError):
            eng.admit(Request(rid=0, prompt=np.zeros(0, np.int32),
                              max_new_tokens=4))

    def test_dense_reference_decode_anchor(self):
        """Paged greedy decode must match a plain dense-cache decode loop
        (the pre-paged serving path) token for token."""
        model = dense_model()
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.RandomState(2)
        prompt = rng.randint(0, 255, size=7)
        eng = Engine(model, params, max_batch=3, max_len=48, page_size=4)
        req = greedy_reqs([prompt])[0]
        eng.run([req])

        cache = model.init_cache(1, 48, dtype=jnp.float32)
        prefill = jax.jit(make_prefill(model))
        decode = jax.jit(make_decode(model))
        logits, cache = prefill(
            params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]}, cache)
        tok = int(jnp.argmax(logits[0, len(prompt) - 1]))
        ref, pos = [tok], len(prompt)
        for _ in range(5):
            logits, cache = decode(params, jnp.asarray([[tok]], jnp.int32),
                                   cache, pos)
            tok = int(jnp.argmax(logits[0, -1]))
            ref.append(tok)
            pos += 1
        assert ref == req.out_tokens


class TestQuantizedKVPages:
    """int8/int4 paged KV pools (KVQuantSpec): serving correctness on top
    of the kernel-level differential suite — interleaved continuous
    batching must stay token-identical to solo serving under a quantized
    pool (quantization is deterministic per written row, so the codes a
    slot produces do not depend on its neighbors), and int8 logits must
    stay within an explicit drift bound of the fp32-cache anchor."""

    @pytest.mark.parametrize("family,impl", [
        ("dense", "gather"),   # portable write+read path
        ("dense", "pallas"),   # fused in-kernel dequant, interpret mode
        ("hybrid", "xla"),     # fused dispatch via the oracle + ssm state
    ])
    def test_interleaved_matches_solo_int8(self, family, impl):
        model, params = family_model(family)
        rng = np.random.RandomState(4)
        V = model.cfg.vocab_size - 1
        prompts = [rng.randint(0, V, size=s) for s in (5, 9, 3, 12)]
        eng = Engine(model, params, max_batch=2, max_len=64, page_size=8,
                     paged_attn_impl=impl, kv_cache_bits=8)
        reqs = greedy_reqs(prompts)
        eng.run(reqs)
        assert all(len(r.out_tokens) == 6 for r in reqs)
        for i, p in enumerate(prompts):
            solo = Engine(model, params, max_batch=2, max_len=64,
                          page_size=8, paged_attn_impl=impl,
                          kv_cache_bits=8)
            r = greedy_reqs([p], rid0=200 + i)[0]
            solo.run([r])
            assert r.out_tokens == reqs[i].out_tokens, (family, impl, i)

    @pytest.mark.parametrize("family", ["dense", "hybrid"])
    def test_int8_logit_drift_vs_fp32_anchor(self, family):
        """Greedy decode over an int8-page pool, logits compared step by
        step against the identical loop over a passthrough fp32 pool.
        Measured drift is ~0.03-0.07 on a ~3-4 logit scale for these
        models; 0.25 is a >3x margin that still fails on any masking or
        scale-handling bug (those blow drift past the logit scale)."""
        from repro.models.attention import KVQuantSpec, PagedLayout
        from repro.serve import paged_cache as pc

        model, params = family_model(family)
        max_len, page_size = 48, 8
        n_pages = max_len // page_size
        rng = np.random.RandomState(5)
        prompt = rng.randint(0, model.cfg.vocab_size - 1, size=9)
        table = np.arange(1, n_pages + 1, dtype=np.int32)[None]

        def logit_trace(bits):
            layout = PagedLayout(n_pages + 1, page_size, KVQuantSpec(bits))
            cache = model.init_cache(1, max_len, dtype=jnp.float32,
                                     paged=layout)
            cache = pc.push_page_table(cache, table)
            logits, cache, _ = model.forward(
                params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]},
                cache=cache, pos=jnp.zeros((1,), jnp.int32))
            out, pos = [logits[0, -1]], len(prompt)
            tok = int(jnp.argmax(logits[0, -1]))
            for _ in range(6):
                logits, cache, _ = model.forward(
                    params, {"tokens": jnp.asarray([[tok]], jnp.int32)},
                    cache=cache, pos=jnp.full((1,), pos, jnp.int32))
                out.append(logits[0, -1])
                tok = int(jnp.argmax(logits[0, -1]))
                pos += 1
            return out

        anchor = logit_trace(16)
        quant = logit_trace(8)
        drift = max(float(jnp.max(jnp.abs(a - b)))
                    for a, b in zip(anchor, quant))
        assert drift < 0.25, (family, drift)

    def test_pool_bytes_headroom(self):
        """Byte-denominated sizing: at a fixed pool budget the quantized
        formats must expose the page-count headroom that motivates them
        (int8 ~3.5x, int4 ~6x over the fp32 CPU-host pools; both >= 2x)."""
        from repro.serve.paged_cache import pool_blocks_for_bytes

        model = dense_model()
        cfg = model.cfg
        budget = 1 << 20
        fp = pool_blocks_for_bytes(budget, cfg, 8, 16, jnp.float32)
        i8 = pool_blocks_for_bytes(budget, cfg, 8, 8, jnp.float32)
        i4 = pool_blocks_for_bytes(budget, cfg, 8, 4, jnp.float32)
        # at this smoke config's hd=16 the f32 scale overhead is 4/20 of
        # an int8 row and 4/12 of an int4 row, so the exact ratios are
        # 3.2x / 5.3x (not 4x / 8x) — the accounting must reflect that
        assert i8 >= 3 * fp and i4 >= 5 * fp

    def test_engine_pool_bytes_ctor(self):
        """Engine(pool_bytes=...) sizes the allocator from bytes; the
        quantized engine gets more usable pages from the same budget and
        still serves correctly."""
        model, params = family_model("dense")
        cfg = model.cfg
        from repro.kernels import kv_quant
        budget = 40 * kv_quant.page_bytes(8, cfg.n_kv_heads, cfg.hd, 16,
                                          dtype_bytes=4)
        fp = Engine(model, params, max_batch=2, max_len=64, page_size=8,
                    pool_bytes=budget)
        q8 = Engine(model, params, max_batch=2, max_len=64, page_size=8,
                    pool_bytes=budget, kv_cache_bits=8)
        assert fp.scheduler.allocator.capacity == 39
        assert q8.scheduler.allocator.capacity >= 2 * 39
        rng = np.random.RandomState(6)
        reqs = greedy_reqs([rng.randint(0, 255, size=7)], n=4)
        q8.run(reqs)
        assert len(reqs[0].out_tokens) == 4


class TestVQKVPages:
    """vq2 vector-quantized KV pages (kv_cache_bits="vq2"): pages hold
    packed 4-bit codebook indices over d=2 head-dim vectors, with
    per-(pool, kv-head) codebooks EM-calibrated at engine load and then
    frozen. Assignment is a deterministic per-row argmin against frozen
    codebooks, so the serving invariants of the scalar formats carry
    over unchanged: interleaved continuous batching and preemption
    replay must stay token-identical to solo/unpressured serving, and
    logits must stay within an explicit drift bound of the fp32-cache
    anchor when decoding the same token path."""

    @pytest.mark.parametrize("impl", ["gather", "pallas"])
    def test_interleaved_matches_solo_vq2(self, impl):
        model, params = family_model("dense")
        rng = np.random.RandomState(14)
        prompts = [rng.randint(0, 255, size=s) for s in (5, 9, 3, 12)]
        eng = Engine(model, params, max_batch=2, max_len=64, page_size=8,
                     paged_attn_impl=impl, kv_cache_bits="vq2")
        reqs = greedy_reqs(prompts)
        eng.run(reqs)
        assert all(len(r.out_tokens) == 6 for r in reqs)
        for i, p in enumerate(prompts):
            # calibration is deterministic, so each solo engine freezes
            # the same codebooks as the interleaved one
            solo = Engine(model, params, max_batch=2, max_len=64,
                          page_size=8, paged_attn_impl=impl,
                          kv_cache_bits="vq2")
            r = greedy_reqs([p], rid0=800 + i)[0]
            solo.run([r])
            assert r.out_tokens == reqs[i].out_tokens, (impl, i)

    def test_vq2_logit_drift_vs_fp32_anchor(self):
        """Decode over a calibrated vq2 pool, teacher-forced onto the
        fp32 anchor's greedy token path so every step compares logits for
        identical inputs (free-running traces diverge in token space and
        then compare logits of different sequences — meaningless).

        Drift is the per-step RMS logit difference across the vocab, max
        over steps: the scale-stable statistic (a single-logit max is an
        order statistic of |V| near-iid errors — it grows with vocab
        size, not with cache quality). Measured ~0.5-0.7 here on this
        random-weight model's ~1.0 RMS logit scale — 2 bits/value is
        coarse — while any masking, scale, or codebook-indexing bug
        decorrelates the logits entirely and blows RMS drift past the
        ~1.4 level of independent draws; 1.0 separates the two
        regimes."""
        from repro.models.attention import KVQuantSpec, PagedLayout
        from repro.serve import paged_cache as pc
        from repro.serve.engine import calibrate_vq_codebooks

        model, params = family_model("dense")
        max_len, page_size = 48, 8
        n_pages = max_len // page_size
        rng = np.random.RandomState(15)
        prompt = rng.randint(0, model.cfg.vocab_size - 1, size=9)
        table = np.arange(1, n_pages + 1, dtype=np.int32)[None]

        def logit_trace(bits, forced=None):
            layout = PagedLayout(n_pages + 1, page_size,
                                 KVQuantSpec.of(bits))
            cache = model.init_cache(1, max_len, dtype=jnp.float32,
                                     paged=layout)
            if bits == "vq2":
                cache = calibrate_vq_codebooks(model, params, cache,
                                               page_size=page_size,
                                               calib_len=32)
            cache = pc.push_page_table(cache, table)
            logits, cache, _ = model.forward(
                params, {"tokens": jnp.asarray(prompt, jnp.int32)[None]},
                cache=cache, pos=jnp.zeros((1,), jnp.int32))
            out, toks, pos = [logits[0, -1]], [], len(prompt)
            tok = int(jnp.argmax(logits[0, -1]))
            for i in range(6):
                if forced is not None:
                    tok = forced[i]
                toks.append(tok)
                logits, cache, _ = model.forward(
                    params, {"tokens": jnp.asarray([[tok]], jnp.int32)},
                    cache=cache, pos=jnp.full((1,), pos, jnp.int32))
                out.append(logits[0, -1])
                tok = int(jnp.argmax(logits[0, -1]))
                pos += 1
            return out, toks

        anchor, anchor_toks = logit_trace(16)
        vq, _ = logit_trace("vq2", forced=anchor_toks)
        drift = max(float(jnp.sqrt(jnp.mean((a - b) ** 2)))
                    for a, b in zip(anchor, vq))
        assert drift < 1.0, drift
        # int8 on the same forced path sits two orders below — the vq2
        # drift is quantization coarseness, not a broken read path
        i8, _ = logit_trace(8, forced=anchor_toks)
        drift8 = max(float(jnp.sqrt(jnp.mean((a - b) ** 2)))
                     for a, b in zip(anchor, i8))
        assert drift8 < 0.05, drift8

    def test_vq2_preemption_replay_identical(self):
        """Recompute-style preemption replays the whole sequence through
        the same frozen codebooks; the rewritten pages are bit-identical
        to the originals, so outputs must match the unpressured run."""
        model, params = family_model("dense")
        rng = np.random.RandomState(16)
        prompts = [rng.randint(0, 255, size=s) for s in (10, 14, 7)]
        big = Engine(model, params, max_batch=2, max_len=64, page_size=4,
                     kv_cache_bits="vq2")
        ref = greedy_reqs(prompts, n=8)
        big.run(ref)
        assert big.stats["preemptions"] == 0
        tight = Engine(model, params, max_batch=2, max_len=64, page_size=4,
                       num_blocks=9, kv_cache_bits="vq2")
        out = greedy_reqs(prompts, n=8, rid0=10)
        tight.run(out)
        assert tight.stats["preemptions"] > 0
        for a, b in zip(ref, out):
            assert a.out_tokens == b.out_tokens

    def test_pool_bytes_headroom_vq2(self):
        """At this smoke config's hd=16 a vq2 row is 8 B (4 B packed
        indices + 4 B scale) vs 64 B fp32, so the page headroom lands
        just under 8x after the codebook overhead is charged against the
        budget (the >= 10x acceptance figure is at the bench hd=32,
        where the fixed 4 B scale amortizes over twice the row)."""
        from repro.serve.paged_cache import pool_blocks_for_bytes

        model = dense_model()
        cfg = model.cfg
        budget = 1 << 20
        fp = pool_blocks_for_bytes(budget, cfg, 8, 16, jnp.float32)
        vq = pool_blocks_for_bytes(budget, cfg, 8, "vq2", jnp.float32)
        i4 = pool_blocks_for_bytes(budget, cfg, 8, 4, jnp.float32)
        assert vq >= 7 * fp
        assert vq > i4  # strictly beyond the best scalar format

    def test_engine_pool_bytes_ctor_vq2(self):
        """Engine(pool_bytes=..., kv_cache_bits="vq2") sizes the
        allocator from bytes (codebook overhead included) and still
        serves correctly."""
        model, params = family_model("dense")
        cfg = model.cfg
        from repro.kernels import kv_quant
        budget = 40 * kv_quant.page_bytes(8, cfg.n_kv_heads, cfg.hd, 16,
                                          dtype_bytes=4)
        fp = Engine(model, params, max_batch=2, max_len=64, page_size=8,
                    pool_bytes=budget)
        vq = Engine(model, params, max_batch=2, max_len=64, page_size=8,
                    pool_bytes=budget, kv_cache_bits="vq2")
        assert fp.scheduler.allocator.capacity == 39
        assert vq.scheduler.allocator.capacity >= 7 * 39
        rng = np.random.RandomState(17)
        reqs = greedy_reqs([rng.randint(0, 255, size=7)], n=4)
        vq.run(reqs)
        assert len(reqs[0].out_tokens) == 4


_VQ_PACKED: dict = {}


def vq_packed_params(family: str):
    """Cached VQ-packed (GPTVQ + pack) params per family — the checkpoints
    the fused serving tests decode against."""
    if family not in _VQ_PACKED:
        from repro.core.bpv import VQConfig
        from repro.core.pipeline import quantize_model
        from repro.data.calibration import calibration_tokens

        model, params = family_model(family)
        calib = calibration_tokens(model.cfg.vocab_size, n_sequences=4,
                                   seq_len=32)
        cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, em_iters=3,
                       codebook_update_iters=0)
        _VQ_PACKED[family], _ = quantize_model(model, params, calib,
                                               "gptvq", cfg, pack=True)
    return _VQ_PACKED[family]


class TestFusedVQServing:
    """The fused VQ-dequant matmul serving path (Engine vq_matmul_impl=):
    greedy decode over a VQ-packed checkpoint must be token-identical
    across the gather (per-layer densify), XLA-fused, and Pallas-fused
    paths, on dense, MoE (stacked expert leaves), and hybrid (fused trunk
    + densified shared-attention LoRA) families — and the "vq" dispatch
    counters (obs/dispatch) must pin which path actually traced."""

    @pytest.mark.parametrize("family,impl", [
        ("dense", "xla"),     # fused-boundary oracle
        ("dense", "pallas"),  # in-VMEM decode kernel, interpret mode
        ("moe", "xla"),       # stacked expert leaves via expert_matmul
        ("hybrid", "xla"),    # fused trunk + dense shared-attn LoRA
    ])
    def test_fused_matches_gather(self, family, impl, dispatch_counters):
        model, _ = family_model(family)
        qparams = vq_packed_params(family)
        rng = np.random.RandomState(8)
        V = model.cfg.vocab_size - 1
        prompts = [rng.randint(0, V, size=s) for s in (5, 9, 3)]

        ref = Engine(model, qparams, max_batch=2, max_len=64, page_size=8,
                     vq_matmul_impl="gather")
        ref_reqs = greedy_reqs(prompts)
        ref.run(ref_reqs)
        assert all(len(r.out_tokens) == 6 for r in ref_reqs)

        before = dispatch_counters()["vq"]
        eng = Engine(model, qparams, max_batch=2, max_len=64, page_size=8,
                     vq_matmul_impl=impl)
        reqs = greedy_reqs(prompts, rid0=300)
        eng.run(reqs)
        counts = dispatch_counters()["vq"]
        assert counts[impl] > before[impl], \
            f"{impl} path never traced — silent fallback"
        for a, b in zip(ref_reqs, reqs):
            assert a.out_tokens == b.out_tokens, (family, impl, a.rid)

    def test_interleaved_matches_solo_vq_fused(self):
        """Continuous batching on the fused path: interleaved admission of
        staggered prompts over a VQ-packed checkpoint must stay
        token-identical to serving each request alone ("fused" resolves
        per-backend: Pallas on TPU, the XLA oracle elsewhere)."""
        model, _ = family_model("dense")
        qparams = vq_packed_params("dense")
        rng = np.random.RandomState(9)
        prompts = [rng.randint(0, 255, size=s) for s in (5, 9, 3, 12)]
        eng = Engine(model, qparams, max_batch=2, max_len=64, page_size=8,
                     vq_matmul_impl="fused")
        reqs = greedy_reqs(prompts)
        eng.run(reqs)
        assert all(len(r.out_tokens) == 6 for r in reqs)
        for i, p in enumerate(prompts):
            solo = Engine(model, qparams, max_batch=2, max_len=64,
                          page_size=8, vq_matmul_impl="fused")
            r = greedy_reqs([p], rid0=400 + i)[0]
            solo.run([r])
            assert r.out_tokens == reqs[i].out_tokens, i

    def test_fused_resolves_per_backend(self):
        """Engine(vq_matmul_impl="fused") resolves to the concrete impl at
        ctor time: off-TPU that is the XLA oracle, never Pallas."""
        model, _ = family_model("dense")
        qparams = vq_packed_params("dense")
        eng = Engine(model, qparams, max_batch=1, max_len=64, page_size=8,
                     vq_matmul_impl="fused")
        expected = "pallas" if jax.default_backend() == "tpu" else "xla"
        assert eng.vq_matmul_impl == expected


class TestPrefixSharing:
    """Prefix-sharing subsystem (serve/prefix_cache.py): admitted
    requests whose prompt prefix is already cached point their page
    tables at the shared physical blocks and skip those prefill chunks.
    Cached pages are byte-identical to what a private prefill would have
    written (content is a pure function of token ids + absolute
    positions), so warm serving must be greedy-token-identical to cold
    solo serving — checked here on the dense family with both the gather
    and fused decode read paths, and on hybrid (where the engine must
    detect the slot-resident ssm state and keep the cache inert rather
    than serve from state it cannot replay)."""

    def _shared_prompts(self, V, n=4, header=40, rng_seed=11):
        rng = np.random.RandomState(rng_seed)
        header_toks = rng.randint(0, V, size=header)
        return [np.concatenate([header_toks,
                                rng.randint(0, V, size=3 + i)])
                for i in range(n)]

    @pytest.mark.parametrize("family,impl", [
        ("dense", "gather"),
        ("dense", "pallas"),   # fused in-kernel page gather, interpret
        ("hybrid", "xla"),     # fused dispatch; cache must stay inert
    ])
    def test_shared_prefix_matches_solo(self, family, impl):
        model, params = family_model(family)
        V = model.cfg.vocab_size - 1
        prompts = self._shared_prompts(V)
        warm = Engine(model, params, max_batch=2, max_len=96, page_size=16,
                      paged_attn_impl=impl, prefix_cache=True)
        reqs = greedy_reqs(prompts)
        warm.run(reqs)
        assert all(len(r.out_tokens) == 6 for r in reqs)
        if family == "dense":
            # max_batch=2: the first pair admits before anything is
            # cached; every later request must hit the 2 shared pages
            assert warm.stats["prefix_hits"] >= len(prompts) - 2
            assert warm.stats["prefix_hit_tokens"] >= 32
        else:
            # slot-resident recurrent state detected structurally:
            # sharing stays off no matter what the ctor asked for
            assert warm.prefix_cache is None
        for i, p in enumerate(prompts):
            solo = Engine(model, params, max_batch=2, max_len=96,
                          page_size=16, paged_attn_impl=impl)
            r = greedy_reqs([p], rid0=500 + i)[0]
            solo.run([r])
            assert r.out_tokens == reqs[i].out_tokens, (family, impl, i)

    def test_prefix_hit_skips_prefill_chunks(self):
        """The point of the subsystem: a warm admission must run strictly
        fewer prefill chunks than its cold run (shared pages enter the
        page table without a forward), and emit the prefix_hit event."""
        model, params = family_model("dense")
        prompts = self._shared_prompts(254, n=2, header=64)
        kw = dict(max_batch=1, max_len=128, page_size=16, prefill_chunk=16)

        cold = Engine(model, params, **kw)
        cold.run(greedy_reqs([prompts[1]], n=2))
        warm = Engine(model, params, prefix_cache=True, **kw)
        warm.run(greedy_reqs([prompts[0]], n=2))       # populates cache
        chunks_before = warm.stats["prefill_chunks"]
        warm.run(greedy_reqs([prompts[1]], n=2, rid0=1))
        warm_chunks = warm.stats["prefill_chunks"] - chunks_before
        cold_chunks = cold.stats["prefill_chunks"]
        # 64 shared header tokens = 4 full pages skipped at chunk 16
        assert warm_chunks <= cold_chunks - 4, (warm_chunks, cold_chunks)
        hits = [e for e in warm.telemetry.events.events
                if e["event"] == "prefix_hit"]
        assert hits and hits[-1]["pages"] >= 4

    def test_preempted_sharer_releases_not_frees(self):
        """A preempted sequence holding shared pages must leave them
        alive for the cache/co-sharers (release, never free) and still
        complete token-identically after replay."""
        model, params = family_model("dense")
        prompts = self._shared_prompts(254, n=3, header=32)
        ref_out = []
        for i, p in enumerate(prompts):
            solo = Engine(model, params, max_batch=2, max_len=96,
                          page_size=8)
            r = greedy_reqs([p], n=8, rid0=600 + i)[0]
            solo.run([r])
            ref_out.append(r.out_tokens)
        # oversubscribed pool: 12 usable blocks for 2 live seqs needing
        # up to ~12 combined plus the cache's references -> preemptions
        # and cache evictions both fire
        tight = Engine(model, params, max_batch=2, max_len=96, page_size=8,
                       num_blocks=13, prefix_cache=True)
        reqs = greedy_reqs(prompts, n=8, rid0=700)
        tight.run(reqs)
        for r, ref in zip(reqs, ref_out):
            assert r.out_tokens == ref, r.rid
        alloc = tight.scheduler.allocator
        for b in tight.prefix_cache.blocks():
            assert alloc.refcount(b) == 1  # only the cache holds them


class TestForkedSampling:
    """Request(n=) parallel sampling: n-1 children fork off the parent's
    prompt blocks once its prefill completes."""

    def test_forks_greedy_identical_to_solo(self):
        model, params = family_model("dense")
        rng = np.random.RandomState(12)
        prompt = rng.randint(0, 254, size=40)
        solo = Engine(model, params, max_batch=1, max_len=96, page_size=16)
        sr = greedy_reqs([prompt])[0]
        solo.run([sr])

        eng = Engine(model, params, max_batch=3, max_len=96, page_size=16,
                     prefix_cache=True)
        parent = Request(rid=0, prompt=prompt, max_new_tokens=6, n=3)
        eng.run([parent])
        assert parent.done and len(parent.forks) == 2
        assert parent.out_tokens == sr.out_tokens
        for child in parent.forks:
            assert child.done and child.out_tokens == sr.out_tokens, \
                child.rid
        # children admitted after the parent's prefill registered the
        # prompt's full pages: every one of them must be a prefix hit
        assert eng.stats["prefix_hits"] >= 2
        assert eng.scheduler.allocator.shared_blocks > 0 or \
            eng.stats["prefix_hit_tokens"] > 0

    def test_forks_without_prefix_cache_still_serve(self):
        """n>1 must degrade gracefully with the cache off: children
        re-prefill privately and stay greedy-identical."""
        model, params = family_model("dense")
        rng = np.random.RandomState(13)
        prompt = rng.randint(0, 254, size=20)
        eng = Engine(model, params, max_batch=2, max_len=64, page_size=8)
        parent = Request(rid=0, prompt=prompt, max_new_tokens=4, n=3)
        eng.run([parent])
        assert parent.done and all(c.done for c in parent.forks)
        for child in parent.forks:
            assert child.out_tokens == parent.out_tokens


class TestPreemption:
    def test_pool_exhaustion_preempts_and_completes(self):
        """With an oversubscribed pool the youngest request is evicted and
        recomputed; greedy outputs still match the unpressured engine."""
        model = dense_model()
        params = model.init_params(jax.random.PRNGKey(0))
        rng = np.random.RandomState(0)
        prompts = [rng.randint(0, 255, size=s) for s in (10, 14, 7)]
        big = Engine(model, params, max_batch=2, max_len=64, page_size=4)
        ref = greedy_reqs(prompts, n=8)
        big.run(ref)
        assert big.stats["preemptions"] == 0

        tight = Engine(model, params, max_batch=2, max_len=64, page_size=4,
                       num_blocks=9)  # 8 usable; 2 live seqs need up to 12
        out = greedy_reqs(prompts, n=8, rid0=10)
        tight.run(out)
        assert tight.stats["preemptions"] > 0
        for a, b in zip(ref, out):
            assert a.out_tokens == b.out_tokens
        assert tight.stats["tokens"] == sum(len(r.out_tokens) for r in out)
