"""Roofline tooling: HLO collective parser, trip counts, analytic model."""
import pytest

from repro.configs.base import ModelConfig, SHAPES
from repro.launch import roofline as rl

HLO = """
HloModule test
fused {
  %p = bf16[16,1024]{1,0} parameter(0)
}
ENTRY main {
  %ag = bf16[32,4096,8192]{2,1,0} all-gather(%x), replica_groups={}
  %ar.1 = f32[1024,1024]{1,0} all-reduce(%y), to_apply=%add
  %rs = bf16[8,512]{1,0} reduce-scatter(%z), to_apply=%add
  %cp = u32[128]{0} collective-permute(%w), source_target_pairs={{0,1}}
  %dot = f32[64,64]{1,0} dot(%a, %b)
  %loop = (s32[]) while(%init), condition=%c, body=%b2,
    backend_config={"known_trip_count":{"n":"28"}}
}
"""


def test_collective_bytes_parser():
    out = rl.collective_bytes(HLO)
    assert out["all-gather"] == 32 * 4096 * 8192 * 2
    assert out["all-reduce"] == 1024 * 1024 * 4
    assert out["reduce-scatter"] == 8 * 512 * 2
    assert out["collective-permute"] == 128 * 4
    assert out["all-to-all"] == 0
    assert out["total"] == sum(out[k] for k in (
        "all-gather", "all-reduce", "reduce-scatter", "collective-permute"))
    # non-collective ops (dot) are not counted
    assert out["_counts"]["all-gather"] == 1


def test_trip_count_parser():
    assert rl.while_trip_counts(HLO) == [28]


@pytest.fixture
def dense_cfg():
    return ModelConfig(name="x", family="dense", n_layers=32, d_model=4096,
                       n_heads=32, n_kv_heads=8, head_dim=128, d_ff=11008,
                       vocab_size=32000)


class TestAnalyticModel:
    def test_decode_weight_bound_improves_with_vq(self, dense_cfg):
        common = dict(chips=256, dp=16, tp=16, n_total=6_700_000_000,
                      n_active=6_700_000_000)
        base = rl.analytic_cell(dense_cfg, SHAPES["decode_32k"], **common)
        vq = rl.analytic_cell(dense_cfg, SHAPES["decode_32k"], **common,
                              weight_payload_bytes=6.7e9 * 0.28)
        assert base["dominant"] == "memory"
        assert vq["memory_s"] < base["memory_s"]
        # and fp8 cache halves the cache term
        kv8 = rl.analytic_cell(dense_cfg, SHAPES["decode_32k"], **common,
                               kv_bytes=1.0)
        assert kv8["memory_s"] < base["memory_s"]

    def test_train_is_compute_bound_at_scale(self, dense_cfg):
        out = rl.analytic_cell(dense_cfg, SHAPES["train_4k"], chips=256,
                               dp=16, tp=16, n_total=6_700_000_000,
                               n_active=6_700_000_000, microbatches=16)
        assert out["dominant"] == "compute"
        assert 0 < out["roofline_fraction"] <= 1.0

    def test_terms_positive_all_shapes(self, dense_cfg):
        for s in SHAPES.values():
            out = rl.analytic_cell(dense_cfg, s, chips=256, dp=16, tp=16,
                                   n_total=1e9, n_active=1e9)
            assert out["compute_s"] > 0 and out["hbm_bytes"] > 0
            assert out["step_lower_bound_s"] >= max(
                out["compute_s"], out["memory_s"], out["collective_s"]) - 1e-12
