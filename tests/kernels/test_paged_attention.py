"""Differential suite for the fused paged-attention decode kernel.

Every case runs kernels/paged_attention.py in interpret mode (no TPU
required) against the pure-XLA oracle kernels/ref.paged_attention_ref, and
the oracle itself is anchored against models/attention._paged_apply's
gather path once — so kernel == oracle == the serving engine's read math.

Coverage: page_size/n_pages/GQA-group/head-dim shape sweep, ragged
per-slot positions, recycled-block staleness (a freed block re-mapped to
another slot, its stale tail poisoned), and the scratch-block-0 masking
invariant (block 0 filled with huge values must never leak into output) —
each across page storage formats in {16, 8, 4, vq2} (passthrough fp
pages, int8/packed-int4 code pages with per-row per-kv-head scales, and
vector-quantized pages: packed 4-bit codebook indices over d=2 head-dim
vectors with per-(pool, kv-head) codebooks). For the quantized formats
the staleness invariants additionally poison the *scales* of masked
rows: a stale scale must be discarded exactly like a stale key. The
quantized oracles are also pinned bitwise against the fp oracle
evaluated on the kv_quant-decoded pool, so every read path shares one
decode expression down to the last ulp.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import kv_quant as kvq
from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_attention_tpu

pytestmark = pytest.mark.kernels

BITS = [16, 8, 4, kvq.VQ_BITS]


def make_case(seed, *, B, H, KV, hd, page_size, n_pages, num_blocks,
              pos=None, dtype=jnp.float32, bits=16):
    """Random pools + a valid-looking page table: each slot maps its first
    pages to distinct physical blocks, the rest to scratch (block 0).
    ``bits`` < 16 quantizes the pools row-wise into code pages + scales;
    ``bits == "vq2"`` vector-quantizes them against random per-kv-head
    codebooks (scales/codebooks None where the format has none)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (num_blocks, page_size, KV, hd), dtype)
    vp = jax.random.normal(ks[2], (num_blocks, page_size, KV, hd), dtype)
    ksc = vsc = kcb = vcb = None
    if bits == kvq.VQ_BITS:
        kcb = jax.random.normal(ks[4], (KV, kvq.VQ_K, kvq.VQ_D))
        vcb = jax.random.normal(ks[5], (KV, kvq.VQ_K, kvq.VQ_D))
        kp, ksc = kvq.vq_quantize_rows(kp, kcb)
        vp, vsc = kvq.vq_quantize_rows(vp, vcb)
    elif bits < 16:
        kp, ksc = kvq.quantize_kv(kp, bits)
        vp, vsc = kvq.quantize_kv(vp, bits)
    if pos is None:
        pos = jax.random.randint(ks[3], (B,), 0, n_pages * page_size)
    pos = jnp.asarray(pos, jnp.int32)
    rng = np.random.RandomState(seed)
    table = np.zeros((B, n_pages), np.int32)
    free = list(rng.permutation(np.arange(1, num_blocks)))
    for b in range(B):
        live = int(pos[b]) // page_size + 1
        for p in range(min(live, n_pages)):
            table[b, p] = free.pop() if free else 0
    return q, kp, vp, jnp.asarray(table), pos, ksc, vsc, kcb, vcb


def assert_matches_oracle(q, kp, vp, table, pos, ksc=None, vsc=None,
                          kcb=None, vcb=None, tol=2e-5):
    got = paged_attention_tpu(q, kp, vp, table, pos, k_scale=ksc,
                              v_scale=vsc, k_codebook=kcb, v_codebook=vcb,
                              interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, table, pos, k_scale=ksc,
                                   v_scale=vsc, k_codebook=kcb,
                                   v_codebook=vcb)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


class TestDifferentialSweep:
    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.parametrize(
        "B,H,KV,hd,page_size,n_pages,num_blocks",
        [
            (1, 4, 4, 32, 8, 4, 8),     # MHA, B=1 decode (the bench case)
            (2, 8, 4, 32, 16, 4, 12),   # G=2 GQA
            (3, 8, 2, 64, 8, 6, 32),    # G=4, deep tables, big pool
            (4, 8, 1, 16, 4, 8, 40),    # MQA (KV=1), tiny pages
            (2, 16, 4, 8, 32, 2, 6),    # wide heads, narrow hd, 2 pages
            (5, 4, 2, 32, 1, 16, 90),   # degenerate page_size=1
        ],
    )
    def test_matches_oracle(self, B, H, KV, hd, page_size, n_pages,
                            num_blocks, bits):
        case = make_case(0, B=B, H=H, KV=KV, hd=hd, page_size=page_size,
                         n_pages=n_pages, num_blocks=num_blocks, bits=bits)
        assert_matches_oracle(*case)

    @pytest.mark.parametrize("bits", BITS)
    @pytest.mark.parametrize("seed", range(4))
    def test_ragged_positions(self, seed, bits):
        """Slots at wildly different depths in one batch — including a
        fresh slot at pos 0 and one on its last mapped row."""
        B, page_size, n_pages = 4, 8, 4
        pos = [0, 1, page_size * n_pages - 1, 2 * page_size]
        case = make_case(seed, B=B, H=8, KV=4, hd=32, page_size=page_size,
                         n_pages=n_pages, num_blocks=20, pos=pos, bits=bits)
        assert_matches_oracle(*case)

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                           (jnp.bfloat16, 4e-2)])
    def test_dtypes(self, dtype, tol):
        q, kp, vp, table, pos, _, _, _, _ = make_case(
            1, B=2, H=8, KV=4, hd=32, page_size=8, n_pages=4,
            num_blocks=12, dtype=dtype)
        got = paged_attention_tpu(q, kp, vp, table, pos, interpret=True)
        assert got.dtype == dtype
        assert_matches_oracle(q, kp, vp, table, pos, tol=tol)


class TestQuantizedDecode:
    @pytest.mark.parametrize("bits", [8, 4])
    def test_storage_really_shrinks(self, bits):
        """The quantized pool must be byte-for-byte smaller: int8 stores
        hd int8 columns, int4 packs two codes per byte (hd//2) — not
        low-bit values parked in wide containers."""
        hd = 32
        _, kp, _, _, _, ksc, _, _, _ = make_case(
            0, B=1, H=4, KV=2, hd=hd, page_size=8, n_pages=2,
            num_blocks=6, bits=bits)
        assert kp.dtype == jnp.int8
        assert kp.shape[-1] == (hd if bits == 8 else hd // 2)
        assert ksc.shape == kp.shape[:-1] and ksc.dtype == jnp.float32

    @pytest.mark.parametrize("bits", [8, 4])
    def test_quantized_oracle_bitwise_vs_decoded_pool(self, bits):
        """One decode expression to rule every read path: the quantized
        oracle must equal the fp oracle run on the kv_quant-decoded pool
        BITWISE — dequant happens before attention math, identically."""
        q, kp, vp, table, pos, ksc, vsc, _, _ = make_case(
            7, B=3, H=8, KV=4, hd=32, page_size=8, n_pages=4,
            num_blocks=16, bits=bits)
        quant = ref.paged_attention_ref(q, kp, vp, table, pos,
                                        k_scale=ksc, v_scale=vsc)
        kd = kvq.dequant_rows(kp, ksc, bits)
        vd = kvq.dequant_rows(vp, vsc, bits)
        fp = ref.paged_attention_ref(q, kd, vd, table, pos)
        np.testing.assert_array_equal(np.asarray(quant), np.asarray(fp))

    def test_int4_pack_roundtrip_bitwise(self):
        codes = jnp.asarray(
            np.random.RandomState(0).randint(-7, 8, size=(5, 8, 2, 16)),
            jnp.int8)
        rt = kvq.unpack_int4(kvq.pack_int4(codes))
        np.testing.assert_array_equal(np.asarray(rt), np.asarray(codes))

    def test_zero_rows_decode_to_zero(self):
        """An all-zero row quantizes to scale 0 / codes 0 and decodes to
        exactly 0.0 — no NaN from the amax=0 division guard."""
        x = jnp.zeros((4, 2, 16))
        for bits in (8, 4):
            codes, scales = kvq.quantize_kv(x, bits)
            assert float(jnp.max(jnp.abs(scales))) == 0.0
            out = kvq.dequant_rows(codes, scales, bits)
            np.testing.assert_array_equal(np.asarray(out),
                                          np.zeros_like(np.asarray(out)))

    @pytest.mark.parametrize("bits,err", [(8, 0.006), (4, 0.1)])
    def test_roundtrip_error_bounded(self, bits, err):
        """Per-row amax scaling bounds |x - dq(q(x))| by scale/2 per
        element: ~amax/254 at int8, ~amax/14 at int4."""
        x = jax.random.normal(jax.random.PRNGKey(3), (16, 4, 64))
        codes, scales = kvq.quantize_kv(x, bits)
        dq = kvq.dequant_rows(codes, scales, bits)
        amax = float(jnp.max(jnp.abs(x)))
        assert float(jnp.max(jnp.abs(dq - x))) <= err * amax


class TestMaskingInvariants:
    @pytest.mark.parametrize("bits", BITS)
    def test_scratch_block_never_leaks(self, bits):
        """Block 0 is the reserved scratch block: inactive slots' writes
        land there, so it holds garbage — codes AND scales. Poison both
        with huge values — no live slot's output may move (its kpos are
        all > pos or mapped to blocks != 0 at kpos <= pos)."""
        q, kp, vp, table, pos, ksc, vsc, kcb, vcb = make_case(
            2, B=3, H=8, KV=4, hd=32, page_size=8, n_pages=4, num_blocks=16,
            pos=[5, 17, 30], bits=bits)
        assert int(jnp.min(table[:, 0])) > 0  # live pages avoid scratch
        base = paged_attention_tpu(q, kp, vp, table, pos, k_scale=ksc,
                                   v_scale=vsc, k_codebook=kcb,
                                   v_codebook=vcb, interpret=True)
        if bits == 16:
            kp2 = kp.at[0].set(1e4)
            vp2 = vp.at[0].set(-1e4)
            ksc2, vsc2 = ksc, vsc
        else:
            kp2 = kp.at[0].set(127)
            vp2 = vp.at[0].set(-127)
            ksc2 = ksc.at[0].set(1e4)   # stale scale poisoning
            vsc2 = vsc.at[0].set(1e4)
        poisoned = paged_attention_tpu(q, kp2, vp2, table, pos,
                                       k_scale=ksc2, v_scale=vsc2,
                                       k_codebook=kcb, v_codebook=vcb,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                                   rtol=1e-6, atol=1e-6)
        assert_matches_oracle(q, kp2, vp2, table, pos, ksc2, vsc2, kcb, vcb)

    @pytest.mark.parametrize("bits", BITS)
    def test_idle_slot_pos0_is_finite(self, bits):
        """An idle slot (all-scratch table, pos 0) attends exactly one
        scratch row: output must be finite (no empty-softmax NaN), and the
        kernel must agree with the oracle on it."""
        q, kp, vp, table, pos, ksc, vsc, kcb, vcb = make_case(
            3, B=2, H=4, KV=2, hd=16, page_size=8, n_pages=2, num_blocks=6,
            pos=[9, 0], bits=bits)
        table = table.at[1].set(0)
        assert_matches_oracle(q, kp, vp, table, pos, ksc, vsc, kcb, vcb)
        out = paged_attention_tpu(q, kp, vp, table, pos, k_scale=ksc,
                                  v_scale=vsc, k_codebook=kcb,
                                  v_codebook=vcb, interpret=True)
        assert bool(jnp.all(jnp.isfinite(out)))

    @pytest.mark.parametrize("bits", BITS)
    def test_recycled_block_staleness(self, bits):
        """A block freed by one slot and handed to another still holds the
        old slot's rows past the new owner's write depth — codes and, for
        quantized pools, their scales. The kpos <= pos rule must hide the
        stale tail: poisoning rows (and scale rows) past ``pos`` of the
        slot's last live page changes nothing."""
        page_size, n_pages = 8, 3
        q, kp, vp, table, pos, ksc, vsc, kcb, vcb = make_case(
            4, B=1, H=8, KV=4, hd=32, page_size=page_size, n_pages=n_pages,
            num_blocks=8, pos=[11], bits=bits)  # last live page row off = 3
        last_blk = int(table[0, 1])   # page holding pos 11
        off = 11 % page_size
        base = paged_attention_tpu(q, kp, vp, table, pos, k_scale=ksc,
                                   v_scale=vsc, k_codebook=kcb,
                                   v_codebook=vcb, interpret=True)
        kmag, vmag = (7e3, -7e3) if bits == 16 else (127, -127)
        # stale tail: rows (off+1..) of the slot's own last page
        kp2 = kp.at[last_blk, off + 1:].set(kmag)
        vp2 = vp.at[last_blk, off + 1:].set(vmag)
        ksc2, vsc2 = ksc, vsc
        if bits != 16:
            ksc2 = ksc.at[last_blk, off + 1:].set(9e3)
            vsc2 = vsc.at[last_blk, off + 1:].set(9e3)
        # and a mapped-but-beyond-depth page (logical page 2, kpos 16..23)
        far_blk = int(table[0, 2])
        if far_blk > 0:
            kp2 = kp2.at[far_blk].set(kmag)
            vp2 = vp2.at[far_blk].set(vmag)
            if bits != 16:
                ksc2 = ksc2.at[far_blk].set(9e3)
                vsc2 = vsc2.at[far_blk].set(9e3)
        poisoned = paged_attention_tpu(q, kp2, vp2, table, pos,
                                       k_scale=ksc2, v_scale=vsc2,
                                       k_codebook=kcb, v_codebook=vcb,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                                   rtol=1e-6, atol=1e-6)
        assert_matches_oracle(q, kp2, vp2, table, pos, ksc2, vsc2, kcb, vcb)


class TestVQPages:
    def test_storage_really_shrinks(self):
        """A vq2 page stores hd//4 packed-index int8 columns per row —
        2 bits/value in the pool, 6x fewer bytes per row than int4."""
        hd = 32
        _, kp, _, _, _, ksc, _, kcb, vcb = make_case(
            0, B=1, H=4, KV=2, hd=hd, page_size=8, n_pages=2,
            num_blocks=6, bits=kvq.VQ_BITS)
        assert kp.dtype == jnp.int8
        assert kp.shape[-1] == hd // 4
        assert ksc.shape == kp.shape[:-1] and ksc.dtype == jnp.float32
        assert kcb.shape == (2, kvq.VQ_K, kvq.VQ_D)
        assert vcb.shape == (2, kvq.VQ_K, kvq.VQ_D)

    def test_vq_oracle_bitwise_vs_decoded_pool(self):
        """Same one-decode-expression pin as the scalar formats: the vq
        oracle must equal the fp oracle on the vq_dequant_rows-decoded
        pool BITWISE."""
        q, kp, vp, table, pos, ksc, vsc, kcb, vcb = make_case(
            7, B=3, H=8, KV=4, hd=32, page_size=8, n_pages=4,
            num_blocks=16, bits=kvq.VQ_BITS)
        vq = ref.paged_attention_ref(q, kp, vp, table, pos, k_scale=ksc,
                                     v_scale=vsc, k_codebook=kcb,
                                     v_codebook=vcb)
        kd = kvq.vq_dequant_rows(kp, ksc, kcb)
        vd = kvq.vq_dequant_rows(vp, vsc, vcb)
        fp = ref.paged_attention_ref(q, kd, vd, table, pos)
        np.testing.assert_array_equal(np.asarray(vq), np.asarray(fp))

    def test_codebook_poison_masked_rows_inert(self):
        """Stale codes in masked rows must stay inert even when they
        index the most extreme codebook entries: replace every masked
        row's packed indices with 0xFF (entry 15 twice) after making
        entry 15 huge — no live output may move."""
        q, kp, vp, table, pos, ksc, vsc, kcb, vcb = make_case(
            8, B=2, H=4, KV=2, hd=16, page_size=4, n_pages=4,
            num_blocks=10, pos=[5, 9], bits=kvq.VQ_BITS)
        kcb = kcb.at[:, 15].set(1e4)
        vcb = vcb.at[:, 15].set(-1e4)
        base = paged_attention_tpu(q, kp, vp, table, pos, k_scale=ksc,
                                   v_scale=vsc, k_codebook=kcb,
                                   v_codebook=vcb, interpret=True)
        # poison the scratch block's codes toward the huge entry
        kp2 = kp.at[0].set(-1)  # 0xFF -> nibbles (15, 15)
        vp2 = vp.at[0].set(-1)
        ksc2 = ksc.at[0].set(9e3)
        vsc2 = vsc.at[0].set(9e3)
        poisoned = paged_attention_tpu(q, kp2, vp2, table, pos,
                                       k_scale=ksc2, v_scale=vsc2,
                                       k_codebook=kcb, v_codebook=vcb,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                                   rtol=1e-6, atol=1e-6)
        assert_matches_oracle(q, kp2, vp2, table, pos, ksc2, vsc2, kcb, vcb)


class TestServingPathConsistency:
    def test_oracle_matches_paged_apply_gather(self):
        """Anchor the oracle against the serving engine's actual gather
        read path (models/attention._paged_apply decode): identical wo=I
        layer outputs for the same pool/table/pos."""
        from repro.configs import SMOKE
        from repro.models import attention

        cfg = SMOKE["llama2-7b"].scaled(
            dtype="float32", n_layers=1, d_model=128, vocab_size=64,
            max_seq_len=32)
        B, H, KV, hd = 2, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        page_size, n_pages, num_blocks = 4, 8, 12
        q, kp, vp, table, pos, _, _, _, _ = make_case(
            5, B=B, H=H, KV=KV, hd=hd, page_size=page_size,
            n_pages=n_pages, num_blocks=num_blocks, pos=[6, 21])
        cache = attention.PagedKVCache(kp, vp, table)
        p = {"wo": jnp.eye(H * hd, dtype=jnp.float32)}
        knew = jax.random.normal(jax.random.PRNGKey(9), (B, 1, KV, hd))
        vnew = jax.random.normal(jax.random.PRNGKey(10), (B, 1, KV, hd))

        attention.set_paged_impl("gather")
        try:
            got_g, newc = attention._paged_apply(
                p, cache, q[:, None], knew, vnew, pos[:, None], jnp.float32)
        finally:
            attention.set_paged_impl("gather")
        # oracle on the post-scatter pools (the write the gather path did)
        want = ref.paged_attention_ref(q, newc.k, newc.v, table, pos)
        np.testing.assert_allclose(
            np.asarray(got_g[:, 0]), np.asarray(want).reshape(B, H * hd),
            rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("bits", [8, 4])
    def test_quantized_oracle_matches_paged_apply_gather(self, bits):
        """Same anchor for quantized pools: _paged_apply quantizes the
        fresh K/V in-graph (write site) and its gather path dequantizes —
        the oracle on the post-scatter code pools + scales must agree."""
        from repro.configs import SMOKE
        from repro.models import attention

        cfg = SMOKE["llama2-7b"].scaled(
            dtype="float32", n_layers=1, d_model=128, vocab_size=64,
            max_seq_len=32)
        B, H, KV, hd = 2, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q, kp, vp, table, pos, ksc, vsc, _, _ = make_case(
            5, B=B, H=H, KV=KV, hd=hd, page_size=4, n_pages=8,
            num_blocks=12, pos=[6, 21], bits=bits)
        cache = attention.PagedKVCache(kp, vp, table, ksc, vsc)
        p = {"wo": jnp.eye(H * hd, dtype=jnp.float32)}
        knew = jax.random.normal(jax.random.PRNGKey(9), (B, 1, KV, hd))
        vnew = jax.random.normal(jax.random.PRNGKey(10), (B, 1, KV, hd))
        got, newc = attention._paged_apply(
            p, cache, q[:, None], knew, vnew, pos[:, None], jnp.float32,
            impl="gather")
        assert newc.k.dtype == jnp.int8  # the write stayed quantized
        want = ref.paged_attention_ref(q, newc.k, newc.v, table, pos,
                                       k_scale=newc.k_scale,
                                       v_scale=newc.v_scale)
        np.testing.assert_allclose(
            np.asarray(got[:, 0]), np.asarray(want).reshape(B, H * hd),
            rtol=2e-5, atol=2e-5)

    def test_vq_oracle_matches_paged_apply_gather(self):
        """Same anchor for vq2 pools: _paged_apply vector-quantizes the
        fresh K/V in-graph against the cache's frozen codebooks and its
        gather path decodes through the codebook — the oracle on the
        post-scatter index pools + scales + codebooks must agree."""
        from repro.configs import SMOKE
        from repro.models import attention

        cfg = SMOKE["llama2-7b"].scaled(
            dtype="float32", n_layers=1, d_model=128, vocab_size=64,
            max_seq_len=32)
        B, H, KV, hd = 2, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        q, kp, vp, table, pos, ksc, vsc, kcb, vcb = make_case(
            5, B=B, H=H, KV=KV, hd=hd, page_size=4, n_pages=8,
            num_blocks=12, pos=[6, 21], bits=kvq.VQ_BITS)
        cache = attention.PagedKVCache(kp, vp, table, ksc, vsc, kcb, vcb)
        p = {"wo": jnp.eye(H * hd, dtype=jnp.float32)}
        knew = jax.random.normal(jax.random.PRNGKey(9), (B, 1, KV, hd))
        vnew = jax.random.normal(jax.random.PRNGKey(10), (B, 1, KV, hd))
        got, newc = attention._paged_apply(
            p, cache, q[:, None], knew, vnew, pos[:, None], jnp.float32,
            impl="gather")
        assert newc.k.dtype == jnp.int8
        assert newc.k.shape[-1] == hd // 4  # the write stayed vq-packed
        want = ref.paged_attention_ref(q, newc.k, newc.v, table, pos,
                                       k_scale=newc.k_scale,
                                       v_scale=newc.v_scale,
                                       k_codebook=kcb, v_codebook=vcb)
        np.testing.assert_allclose(
            np.asarray(got[:, 0]), np.asarray(want).reshape(B, H * hd),
            rtol=2e-5, atol=2e-5)

    @pytest.mark.parametrize("bits", BITS)
    def test_ops_dispatch(self, bits):
        """use_pallas toggles kernel vs oracle; both agree."""
        q, kp, vp, table, pos, ksc, vsc, kcb, vcb = make_case(
            6, B=2, H=4, KV=4, hd=16, page_size=4, n_pages=4, num_blocks=10,
            bits=bits)
        o_k = ops.paged_attention(q, kp, vp, table, pos, k_scale=ksc,
                                  v_scale=vsc, k_codebook=kcb,
                                  v_codebook=vcb, use_pallas=True,
                                  interpret=True)
        o_r = ops.paged_attention(q, kp, vp, table, pos, k_scale=ksc,
                                  v_scale=vsc, k_codebook=kcb,
                                  v_codebook=vcb, use_pallas=False)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=2e-5, atol=2e-5)
