"""Differential suite for the fused paged-attention decode kernel.

Every case runs kernels/paged_attention.py in interpret mode (no TPU
required) against the pure-XLA oracle kernels/ref.paged_attention_ref, and
the oracle itself is anchored against models/attention._paged_apply's
gather path once — so kernel == oracle == the serving engine's read math.

Coverage: page_size/n_pages/GQA-group/head-dim shape sweep, ragged
per-slot positions, recycled-block staleness (a freed block re-mapped to
another slot, its stale tail poisoned), and the scratch-block-0 masking
invariant (block 0 filled with huge values must never leak into output).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.paged_attention import paged_attention_tpu


def make_case(seed, *, B, H, KV, hd, page_size, n_pages, num_blocks,
              pos=None, dtype=jnp.float32):
    """Random pools + a valid-looking page table: each slot maps its first
    pages to distinct physical blocks, the rest to scratch (block 0)."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    kp = jax.random.normal(ks[1], (num_blocks, page_size, KV, hd), dtype)
    vp = jax.random.normal(ks[2], (num_blocks, page_size, KV, hd), dtype)
    if pos is None:
        pos = jax.random.randint(ks[3], (B,), 0, n_pages * page_size)
    pos = jnp.asarray(pos, jnp.int32)
    rng = np.random.RandomState(seed)
    table = np.zeros((B, n_pages), np.int32)
    free = list(rng.permutation(np.arange(1, num_blocks)))
    for b in range(B):
        live = int(pos[b]) // page_size + 1
        for p in range(min(live, n_pages)):
            table[b, p] = free.pop() if free else 0
    return q, kp, vp, jnp.asarray(table), pos


def assert_matches_oracle(q, kp, vp, table, pos, tol=2e-5):
    got = paged_attention_tpu(q, kp, vp, table, pos, interpret=True)
    want = ref.paged_attention_ref(q, kp, vp, table, pos)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=tol, atol=tol)


class TestDifferentialSweep:
    @pytest.mark.parametrize(
        "B,H,KV,hd,page_size,n_pages,num_blocks",
        [
            (1, 4, 4, 32, 8, 4, 8),     # MHA, B=1 decode (the bench case)
            (2, 8, 4, 32, 16, 4, 12),   # G=2 GQA
            (3, 8, 2, 64, 8, 6, 32),    # G=4, deep tables, big pool
            (4, 8, 1, 16, 4, 8, 40),    # MQA (KV=1), tiny pages
            (2, 16, 4, 8, 32, 2, 6),    # wide heads, narrow hd, 2 pages
            (5, 4, 2, 32, 1, 16, 90),   # degenerate page_size=1
        ],
    )
    def test_matches_oracle(self, B, H, KV, hd, page_size, n_pages,
                            num_blocks):
        case = make_case(0, B=B, H=H, KV=KV, hd=hd, page_size=page_size,
                         n_pages=n_pages, num_blocks=num_blocks)
        assert_matches_oracle(*case)

    @pytest.mark.parametrize("seed", range(4))
    def test_ragged_positions(self, seed):
        """Slots at wildly different depths in one batch — including a
        fresh slot at pos 0 and one on its last mapped row."""
        B, page_size, n_pages = 4, 8, 4
        pos = [0, 1, page_size * n_pages - 1, 2 * page_size]
        case = make_case(seed, B=B, H=8, KV=4, hd=32, page_size=page_size,
                         n_pages=n_pages, num_blocks=20, pos=pos)
        assert_matches_oracle(*case)

    @pytest.mark.parametrize("dtype,tol", [(jnp.float32, 2e-5),
                                           (jnp.bfloat16, 4e-2)])
    def test_dtypes(self, dtype, tol):
        q, kp, vp, table, pos = make_case(
            1, B=2, H=8, KV=4, hd=32, page_size=8, n_pages=4,
            num_blocks=12, dtype=dtype)
        got = paged_attention_tpu(q, kp, vp, table, pos, interpret=True)
        assert got.dtype == dtype
        assert_matches_oracle(q, kp, vp, table, pos, tol=tol)


class TestMaskingInvariants:
    def test_scratch_block_never_leaks(self):
        """Block 0 is the reserved scratch block: inactive slots' writes
        land there, so it holds garbage. Poison it with huge values — no
        live slot's output may move (its kpos are all > pos or mapped to
        blocks != 0 at kpos <= pos)."""
        q, kp, vp, table, pos = make_case(
            2, B=3, H=8, KV=4, hd=32, page_size=8, n_pages=4, num_blocks=16,
            pos=[5, 17, 30])
        assert int(jnp.min(table[:, 0])) > 0  # live pages avoid scratch
        base = paged_attention_tpu(q, kp, vp, table, pos, interpret=True)
        kp2 = kp.at[0].set(1e4)
        vp2 = vp.at[0].set(-1e4)
        poisoned = paged_attention_tpu(q, kp2, vp2, table, pos,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                                   rtol=1e-6, atol=1e-6)
        assert_matches_oracle(q, kp2, vp2, table, pos)

    def test_idle_slot_pos0_is_finite(self):
        """An idle slot (all-scratch table, pos 0) attends exactly one
        scratch row: output must be finite (no empty-softmax NaN), and the
        kernel must agree with the oracle on it."""
        q, kp, vp, table, pos = make_case(
            3, B=2, H=4, KV=2, hd=16, page_size=8, n_pages=2, num_blocks=6,
            pos=[9, 0])
        table = table.at[1].set(0)
        assert_matches_oracle(q, kp, vp, table, pos)
        out = paged_attention_tpu(q, kp, vp, table, pos, interpret=True)
        assert bool(jnp.all(jnp.isfinite(out)))

    def test_recycled_block_staleness(self):
        """A block freed by one slot and handed to another still holds the
        old slot's rows past the new owner's write depth. The kpos <= pos
        rule must hide the stale tail: poisoning rows past ``pos`` of the
        slot's last live page changes nothing."""
        page_size, n_pages = 8, 3
        q, kp, vp, table, pos = make_case(
            4, B=1, H=8, KV=4, hd=32, page_size=page_size, n_pages=n_pages,
            num_blocks=8, pos=[11])  # last live page row offset = 3
        last_blk = int(table[0, 1])   # page holding pos 11
        off = 11 % page_size
        base = paged_attention_tpu(q, kp, vp, table, pos, interpret=True)
        # stale tail: rows (off+1..) of the slot's own last page
        kp2 = kp.at[last_blk, off + 1:].set(7e3)
        vp2 = vp.at[last_blk, off + 1:].set(-7e3)
        # and a mapped-but-beyond-depth page (logical page 2, kpos 16..23)
        far_blk = int(table[0, 2])
        if far_blk > 0:
            kp2 = kp2.at[far_blk].set(9e3)
            vp2 = vp2.at[far_blk].set(-9e3)
        poisoned = paged_attention_tpu(q, kp2, vp2, table, pos,
                                       interpret=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(poisoned),
                                   rtol=1e-6, atol=1e-6)
        assert_matches_oracle(q, kp2, vp2, table, pos)


class TestServingPathConsistency:
    def test_oracle_matches_paged_apply_gather(self):
        """Anchor the oracle against the serving engine's actual gather
        read path (models/attention._paged_apply decode): identical wo=I
        layer outputs for the same pool/table/pos."""
        from repro.configs import SMOKE
        from repro.models import attention

        cfg = SMOKE["llama2-7b"].scaled(
            dtype="float32", n_layers=1, d_model=128, vocab_size=64,
            max_seq_len=32)
        B, H, KV, hd = 2, cfg.n_heads, cfg.n_kv_heads, cfg.hd
        page_size, n_pages, num_blocks = 4, 8, 12
        q, kp, vp, table, pos = make_case(
            5, B=B, H=H, KV=KV, hd=hd, page_size=page_size,
            n_pages=n_pages, num_blocks=num_blocks, pos=[6, 21])
        cache = attention.PagedKVCache(kp, vp, table)
        p = {"wo": jnp.eye(H * hd, dtype=jnp.float32)}
        knew = jax.random.normal(jax.random.PRNGKey(9), (B, 1, KV, hd))
        vnew = jax.random.normal(jax.random.PRNGKey(10), (B, 1, KV, hd))

        attention.set_paged_impl("gather")
        try:
            got_g, newc = attention._paged_apply(
                p, cache, q[:, None], knew, vnew, pos[:, None], jnp.float32)
        finally:
            attention.set_paged_impl("gather")
        # oracle on the post-scatter pools (the write the gather path did)
        want = ref.paged_attention_ref(q, newc.k, newc.v, table, pos)
        np.testing.assert_allclose(
            np.asarray(got_g[:, 0]), np.asarray(want).reshape(B, H * hd),
            rtol=2e-5, atol=2e-5)

    def test_ops_dispatch(self):
        """use_pallas toggles kernel vs oracle; both agree."""
        q, kp, vp, table, pos = make_case(
            6, B=2, H=4, KV=4, hd=16, page_size=4, n_pages=4, num_blocks=10)
        o_k = ops.paged_attention(q, kp, vp, table, pos, use_pallas=True,
                                  interpret=True)
        o_r = ops.paged_attention(q, kp, vp, table, pos, use_pallas=False)
        np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r),
                                   rtol=2e-5, atol=2e-5)
