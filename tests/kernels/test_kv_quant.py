"""Unit tests for kernels/kv_quant.py edges: the scalar int8/int4 pack
and byte-accounting corners that every paged read/write path leans on,
plus the vq2 vector-quantized page format (pack/unpack, deterministic
assignment, the shared one-hot-matmul decode expression, and the
codebook-overhead byte math)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import kv_quant as kvq


class TestInt4Pack:
    def test_roundtrip_full_code_range(self):
        """Every legal int4 code in [-7, 7] survives pack -> unpack, in
        every nibble position."""
        codes = jnp.asarray(
            np.stack([np.arange(-7, 8, dtype=np.int8),
                      np.arange(7, -8, -1, dtype=np.int8)]).reshape(2, -1))
        # odd length: pad to even head dim as the packer requires
        codes = jnp.concatenate([codes, codes[:, :1]], axis=-1)
        assert codes.shape[-1] % 2 == 0
        out = kvq.unpack_int4(kvq.pack_int4(codes))
        np.testing.assert_array_equal(np.asarray(out), np.asarray(codes))

    def test_minus_eight_never_produced(self):
        """quantize_kv clips symmetric to [-7, 7]: -8 must not appear even
        for adversarial inputs at the negative extreme."""
        x = jnp.asarray([[-1.0, 1.0, -1.0, -0.99] * 4], jnp.float32)
        x = x.reshape(1, 1, 16)  # (rows, KV, hd)
        codes, _ = kvq.quantize_kv(x, 4)
        unpacked = np.asarray(kvq.unpack_int4(codes))
        assert unpacked.min() >= -7 and unpacked.max() <= 7

    def test_int8_codes_symmetric(self):
        x = jnp.asarray(np.linspace(-3, 3, 32, dtype=np.float32)
                        ).reshape(1, 2, 16)
        codes, _ = kvq.quantize_kv(x, 8)
        c = np.asarray(codes)
        assert c.min() >= -127 and c.max() <= 127


class TestInferBits:
    def test_hd2_edges(self):
        """hd=2 is the smallest packable head dim: cols==2 must read as
        int8, cols==1 (== hd//2) as packed int4."""
        assert kvq.infer_bits(2, 2) == 8
        assert kvq.infer_bits(1, 2) == 4

    def test_typical_shapes(self):
        assert kvq.infer_bits(64, 64) == 8
        assert kvq.infer_bits(32, 64) == 4

    def test_mismatched_cols_rejected(self):
        with pytest.raises(AssertionError):
            kvq.infer_bits(3, 8)


class TestZeroRows:
    @pytest.mark.parametrize("bits", [8, 4])
    def test_scalar_zero_row_scale0_dequants_to_zero(self, bits):
        x = jnp.zeros((3, 2, 16), jnp.float32)
        codes, scales = kvq.quantize_kv(x, bits)
        assert float(jnp.max(jnp.abs(scales))) == 0.0
        dec = kvq.dequant_rows(codes, scales, bits)
        assert float(jnp.max(jnp.abs(dec))) == 0.0
        assert bool(jnp.all(jnp.isfinite(dec)))

    def test_vq_zero_row_scale0_dequants_to_zero(self):
        cb = kvq.default_codebook(2)
        x = jnp.zeros((3, 2, 16), jnp.float32)
        codes, scales = kvq.vq_quantize_rows(x, cb)
        assert float(jnp.max(jnp.abs(scales))) == 0.0
        dec = kvq.vq_dequant_rows(codes, scales, cb)
        assert float(jnp.max(jnp.abs(dec))) == 0.0


class TestBlocksForBytes:
    def test_two_block_boundary(self):
        """Exactly 2 blocks (scratch + one usable) is the legal minimum;
        one byte less must raise, not silently round up."""
        per_block = kvq.page_bytes(8, 2, 16, 8, dtype_bytes=4)
        assert kvq.blocks_for_bytes(2 * per_block, 8, 2, 16, 8,
                                    dtype_bytes=4) == 2
        with pytest.raises(ValueError):
            kvq.blocks_for_bytes(2 * per_block - 1, 8, 2, 16, 8,
                                 dtype_bytes=4)

    def test_vq2_boundary_includes_codebook_overhead(self):
        """For vq2 the frozen codebooks' bytes are charged against the
        budget before dividing: a budget of exactly 2 blocks of index
        pages without the codebook allowance must raise."""
        per_block = kvq.page_bytes(8, 2, 16, kvq.VQ_BITS, dtype_bytes=4)
        overhead = kvq.vq_overhead_bytes(2)
        assert kvq.blocks_for_bytes(2 * per_block + overhead, 8, 2, 16,
                                    kvq.VQ_BITS, dtype_bytes=4) == 2
        with pytest.raises(ValueError):
            kvq.blocks_for_bytes(2 * per_block + overhead - 1, 8, 2, 16,
                                 kvq.VQ_BITS, dtype_bytes=4)


class TestVQ2Format:
    def test_storage_cols(self):
        assert kvq.storage_cols(16, kvq.VQ_BITS) == 4
        assert kvq.storage_cols(32, kvq.VQ_BITS) == 8
        with pytest.raises(AssertionError):
            kvq.storage_cols(6, kvq.VQ_BITS)  # hd % 4 != 0

    def test_pack_unpack_roundtrip_full_index_range(self):
        """All 16 index values survive pack -> unpack in both nibble
        positions, and always come back unsigned (no sign extension)."""
        idx = jnp.asarray(np.stack([np.arange(16), np.arange(15, -1, -1)])
                          .astype(np.int32))
        out = np.asarray(kvq.unpack_vq2(kvq.pack_vq2(idx)))
        np.testing.assert_array_equal(out, np.asarray(idx))
        assert out.min() >= 0 and out.max() <= 15

    def test_assignment_deterministic_and_tie_lowest_index(self):
        """argmin assignment: re-running is bit-identical, and a vector
        equidistant between two entries takes the lower index."""
        cb = jnp.asarray([[[1.0, 0.0], [-1.0, 0.0]] + [[9.0, 9.0]] * 14],
                         jnp.float32)  # (1 kv head, 16, 2)
        # hd=4 -> two d=2 vectors, both (0, 1) after amax normalization:
        # equidistant from entries 0 (1,0) and 1 (-1,0)
        x = jnp.asarray([0.0, 1.0, 0.0, 1.0], jnp.float32).reshape(1, 1, 4)
        c1, _ = kvq.vq_quantize_rows(x, cb)
        c2, _ = kvq.vq_quantize_rows(x, cb)
        np.testing.assert_array_equal(np.asarray(c1), np.asarray(c2))
        assert np.asarray(kvq.unpack_vq2(c1)).reshape(-1).tolist() == [0, 0]

    def test_dequant_is_bitwise_gather(self):
        """The shared decode expression (one-hot matmul) must equal an
        explicit codebook gather bit for bit — that equality is what
        makes kernel == oracle == gather-path exact, not approximate."""
        rng = np.random.default_rng(7)
        KV, hd, n = 3, 16, 40
        cb = jnp.asarray(rng.normal(size=(KV, 16, 2)), jnp.float32)
        x = jnp.asarray(rng.normal(size=(n, KV, hd)), jnp.float32)
        codes, scales = kvq.vq_quantize_rows(x, cb)
        dec = kvq.vq_dequant_rows(codes, scales, cb)
        idx = kvq.unpack_vq2(codes)
        vecs = jax.vmap(lambda c, i: c[i], in_axes=(0, 1), out_axes=1)(
            cb, idx)
        ref = (vecs.reshape(n, KV, hd)
               * scales[..., None].astype(jnp.float32))
        np.testing.assert_array_equal(np.asarray(dec), np.asarray(ref))

    def test_default_codebook_roundtrip_error_bound(self):
        """The uncalibrated 4x4 grid codebook behaves like 2-bit uniform
        quantization of the normalized row: |x - dec| <= amax/3 + eps."""
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.normal(size=(64, 2, 16)), jnp.float32)
        cb = kvq.default_codebook(2)
        codes, scales = kvq.vq_quantize_rows(x, cb)
        dec = kvq.vq_dequant_rows(codes, scales, cb)
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
        err = jnp.max(jnp.abs(dec - x) / jnp.where(amax > 0, amax, 1.0))
        assert float(err) <= 1.0 / 3.0 + 1e-6

    def test_row_bytes_headroom(self):
        """At the bench shape (hd=32, fp32 host) a vq2 row is 12 B vs
        128 B passthrough — the source of the >= 10x page headroom."""
        assert kvq.row_bytes(32, kvq.VQ_BITS) == 12
        assert kvq.row_bytes(32, 16, dtype_bytes=4) == 128
