"""Pallas kernel validation: interpret-mode sweeps vs pure-jnp oracles, plus
consistency with the VQLinear serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import packing
from repro.core.bpv import VQConfig
from repro.core import vq_linear as vql_mod
from repro.kernels import ops, ref

from tests.core.test_quant_core import make_problem

pytestmark = pytest.mark.kernels


def make_vq_inputs(key, *, N, K, d, bits, rows_per_band, group_cols, k_c=None):
    k_c = k_c or 2 ** (d * bits)
    n_cg, n_bands = K // group_cols, N // rows_per_band
    k1, k2 = jax.random.split(key)
    codes = jax.random.randint(k1, (N, K // d), 0, k_c)
    code_bits = max(1, (k_c - 1).bit_length())
    words = jax.vmap(lambda r: packing.pack(r, code_bits))(codes)
    C = jax.random.normal(k2, (n_cg, n_bands, k_c, d))
    return words, C, code_bits


class TestVQDequantMatmul:
    @pytest.mark.parametrize(
        "M,N,K,d,bits,rg,cg",
        [
            (8, 64, 256, 2, 2, 8, 256),
            (16, 128, 512, 2, 2, 8, 256),
            (8, 64, 256, 1, 3, 4, 256),   # 3-bit codes in 4-bit containers
            (8, 64, 512, 4, 2, 16, 256),
            (8, 64, 256, 2, 4, 2, 128),
        ],
    )
    def test_matches_oracle(self, M, N, K, d, bits, rg, cg):
        key = jax.random.PRNGKey(42)
        words, C, code_bits = make_vq_inputs(
            key, N=N, K=K, d=d, bits=bits, rows_per_band=rg, group_cols=cg)
        x = jax.random.normal(jax.random.PRNGKey(1), (M, K))
        from repro.kernels.vq_dequant_matmul import vq_dequant_matmul
        y = vq_dequant_matmul(
            x, words, C, d=d, k_c=2 ** (d * bits), code_bits=code_bits,
            container_bits=packing.container_bits(code_bits),
            rows_per_band=rg, group_cols=cg,
            tile_m=min(8, M), tile_n=min(64, N), tile_k=min(256, K),
            interpret=True)
        y_ref = ref.vq_dequant_matmul_ref(
            x, words, C, d=d, code_bits=code_bits, rows_per_band=rg,
            group_cols=cg)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        key = jax.random.PRNGKey(0)
        words, C, code_bits = make_vq_inputs(
            key, N=64, K=256, d=2, bits=2, rows_per_band=8, group_cols=256)
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 256)).astype(dtype)
        from repro.kernels.vq_dequant_matmul import vq_dequant_matmul
        y = vq_dequant_matmul(
            x, words, C, d=2, k_c=16, code_bits=code_bits,
            container_bits=4, rows_per_band=8, group_cols=256,
            tile_m=8, tile_n=64, tile_k=256, interpret=True)
        y_ref = ref.vq_dequant_matmul_ref(
            x.astype(jnp.float32), words, C, d=2, code_bits=code_bits,
            rows_per_band=8, group_cols=256)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=tol, atol=tol)

    def test_consistent_with_vqlinear_serving_path(self):
        """kernel(x, packed) == x @ dequantize(packed).T for a real quantizer
        output (end-to-end: GPTVQ -> pack -> kernel)."""
        W, X, H, U = make_problem(r=64, c=256)
        cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, em_iters=10,
                       codebook_update_iters=0)
        vql = vql_mod.quantize_array(W, H, cfg)
        x = jax.random.normal(jax.random.PRNGKey(3), (8, 256))
        y_kernel = ops.vql_matmul(x, vql, use_pallas=True, interpret=True,
                                  tile_m=8, tile_n=64, tile_k=256)
        y_dense = x @ vql_mod.dequantize(vql, jnp.float32).T
        np.testing.assert_allclose(np.asarray(y_kernel), np.asarray(y_dense),
                                   rtol=2e-2, atol=2e-2)

    @pytest.mark.parametrize("M", [1, 3, 5, 17])
    def test_decode_shaped_m(self, M):
        """Decode batches (M = 1..batch, not tile-aligned) must not trip the
        tile_m divisibility assert: the wrapper pads M up and slices back."""
        key = jax.random.PRNGKey(6)
        words, C, code_bits = make_vq_inputs(
            key, N=64, K=256, d=2, bits=2, rows_per_band=8, group_cols=256)
        x = jax.random.normal(jax.random.PRNGKey(7), (M, 256))
        from repro.kernels.vq_dequant_matmul import vq_dequant_matmul
        y = vq_dequant_matmul(
            x, words, C, d=2, k_c=16, code_bits=code_bits,
            container_bits=4, rows_per_band=8, group_cols=256,
            tile_m=128, tile_n=64, tile_k=256, interpret=True)
        assert y.shape == (M, 64)
        y_ref = ref.vq_dequant_matmul_ref(
            x, words, C, d=2, code_bits=code_bits, rows_per_band=8,
            group_cols=256)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    def test_ragged_n_k_snap_to_layout(self):
        """N/K not divisible by the requested tile sizes: the wrapper snaps
        tile_n to a band multiple and tile_k to a lane-aligned group
        multiple instead of asserting."""
        key = jax.random.PRNGKey(8)
        words, C, code_bits = make_vq_inputs(
            key, N=96, K=384, d=2, bits=2, rows_per_band=8, group_cols=128)
        x = jax.random.normal(jax.random.PRNGKey(9), (4, 384))
        from repro.kernels.vq_dequant_matmul import vq_dequant_matmul
        y = vq_dequant_matmul(
            x, words, C, d=2, k_c=16, code_bits=code_bits,
            container_bits=4, rows_per_band=8, group_cols=128,
            tile_m=128, tile_n=128, tile_k=256, interpret=True)
        y_ref = ref.vq_dequant_matmul_ref(
            x, words, C, d=2, code_bits=code_bits, rows_per_band=8,
            group_cols=128)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("Ns,tk", [(16, 256), (64, 128), (32, 64)])
    def test_blockwise_scales(self, Ns, tk):
        """scale_block != 0: the pre-expanded (N, K/Ns) normalization plane
        is applied to the decoded tile inside the kernel."""
        key = jax.random.PRNGKey(10)
        words, C, code_bits = make_vq_inputs(
            key, N=64, K=512, d=2, bits=2, rows_per_band=8, group_cols=256)
        scales = jnp.exp2(jax.random.normal(
            jax.random.PRNGKey(11), (64, 512 // Ns)) * 0.5)
        x = jax.random.normal(jax.random.PRNGKey(12), (8, 512))
        from repro.kernels.vq_dequant_matmul import vq_dequant_matmul
        y = vq_dequant_matmul(
            x, words, C, scales, d=2, k_c=16, code_bits=code_bits,
            container_bits=4, rows_per_band=8, group_cols=256,
            scale_block=Ns, tile_m=8, tile_n=64, tile_k=tk, interpret=True)
        y_ref = ref.vq_dequant_matmul_ref(
            x, words, C, scales, d=2, code_bits=code_bits, rows_per_band=8,
            group_cols=256, scale_block=Ns)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=1e-4, atol=1e-4)


class TestFusedVQLinear:
    """prepare_fused / fused_matmul: the engine-load prep pass and the
    per-matmul dispatch that serve/engine.Engine(vq_matmul_impl=...) uses."""

    def _quantized(self, *, scale_block=0, r=64, c=256):
        W, X, H, U = make_problem(r=r, c=c)
        cfg = VQConfig(d=2, bits_per_dim=2, group_size=2048, em_iters=8,
                       codebook_update_iters=0, scale_block=scale_block)
        return vql_mod.quantize_array(W, H, cfg)

    @pytest.mark.parametrize("sb", [0, 8])
    def test_prepare_matches_dequantize(self, sb):
        """fused_dequantize(prepare_fused(v)) == dequantize(v): prep folds
        cb_scale + the exp2 scale plane without changing the weights."""
        vql = self._quantized(scale_block=sb)
        fvl = vql_mod.prepare_fused(vql)
        assert isinstance(fvl, vql_mod.FusedVQLinear)
        assert (fvl.scales is not None) == bool(sb)
        W_f = vql_mod.fused_dequantize(fvl, jnp.float32)
        W_g = vql_mod.dequantize(vql, jnp.float32)
        np.testing.assert_allclose(np.asarray(W_f), np.asarray(W_g),
                                   rtol=1e-6, atol=1e-6)

    @pytest.mark.parametrize("sb", [0, 8])
    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    def test_fused_matmul_matches_dense(self, sb, impl):
        """Both fused impls == x @ dequantize(v).T, with and without
        blockwise normalization, for decode-shaped and prefill-shaped x."""
        vql = self._quantized(scale_block=sb)
        fvl = vql_mod.prepare_fused(vql)
        W = vql_mod.dequantize(vql, jnp.float32)
        for M in (1, 8):
            x = jax.random.normal(jax.random.PRNGKey(M), (M, 256))
            y = vql_mod.fused_matmul(x, fvl, impl=impl, interpret=True,
                                     tile_n=64, tile_k=256)
            np.testing.assert_allclose(
                np.asarray(y), np.asarray(x @ W.T), rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("impl", ["xla", "pallas"])
    def test_stacked_expert_leaves(self, impl):
        """MoE-style stacked leaves (leading E on every array) route through
        models/common.expert_matmul and match the per-expert dense einsum."""
        from repro.models import common as cm
        v1, v2 = self._quantized(), self._quantized(r=64, c=256)
        stacked = jax.tree.map(lambda *a: jnp.stack(a), v1, v2)
        fvl = vql_mod.prepare_fused(stacked, impl=impl)
        assert isinstance(fvl, vql_mod.FusedVQLinear)
        assert fvl.words.shape[0] == 2
        x = jax.random.normal(jax.random.PRNGKey(5), (2, 4, 256))
        y = cm.expert_matmul(x, fvl)
        W = jnp.stack([vql_mod.dequantize(v, jnp.float32).T
                       for v in (v1, v2)])  # (E, in, out)
        y_ref = jnp.einsum("ecd,edf->ecf", x, W)
        np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                                   rtol=2e-4, atol=2e-4)

    def test_dispatch_counters(self, dispatch_counters):
        """The "vq" dispatch counts pin which path traced: fused_matmul
        bumps its impl; dequant_tree bumps "gather" per densified VQLinear
        leaf. The fixture zeroes the registry, so counts are absolute."""
        vql = self._quantized()
        fvl = vql_mod.prepare_fused(vql)
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 256))
        vql_mod.fused_matmul(x, fvl, impl="xla")
        assert dispatch_counters()["vq"]["xla"] == 1
        vql_mod.fused_matmul(x, fvl, impl="pallas", interpret=True,
                             tile_n=64, tile_k=256)
        assert dispatch_counters()["vq"]["pallas"] == 1
        vql_mod.dequant_tree({"w": vql}, jnp.float32)
        assert dispatch_counters()["vq"]["gather"] == 1
        # leaf stamp is the default when no explicit impl is passed
        vql_mod.fused_matmul(x, vql_mod.prepare_fused(vql, impl="xla"))
        assert dispatch_counters()["vq"]["xla"] == 2

    def test_unaligned_rows_stay_gather(self):
        """Rows not packed on uint32 word boundaries (flat-packed leaf):
        prepare_fused must leave the leaf as VQLinear (gather path) rather
        than produce a layout the kernel cannot tile."""
        r, c, d, k = 4, 24, 2, 16  # nspans=12, lanes=8 -> unaligned
        code_bits = 4
        codes = jax.random.randint(jax.random.PRNGKey(1), (r, c // d), 0, k)
        # 48 codes / 8 lanes = 6 words: rows straddle word boundaries, so
        # the pack is flat (1, n_words) rather than per-row
        words = packing.pack(codes.reshape(-1), code_bits).reshape(1, -1)
        vql = vql_mod.VQLinear(
            words=words,
            codebooks=jax.random.randint(
                jax.random.PRNGKey(2), (2, 2, k, d), -127, 128
            ).astype(jnp.int8),
            cb_scale=jnp.full((2, 2), 0.05, jnp.float32),
            scale_sint=jnp.zeros((2, r, 1), jnp.int8),
            scale_a=jnp.zeros((2,), jnp.float32),
            scale_z=jnp.zeros((2,), jnp.float32),
            r=r, c=c, d=d, k=k, group_cols=12, rows_per_band=2)
        out = vql_mod.prepare_fused(vql)
        assert out is vql
        tree = vql_mod.prepare_fused_tree({"w": vql})
        assert isinstance(tree["w"], vql_mod.VQLinear)
        dense = vql_mod.dequant_tree(tree, jnp.float32)
        assert dense["w"].shape == (c, r)


class TestVQAssign:
    @pytest.mark.parametrize("n,d,k", [(256, 2, 16), (1024, 4, 64),
                                       (512, 1, 8), (2048, 2, 256)])
    def test_matches_oracle(self, n, d, k):
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        x = jax.random.normal(ks[0], (n, d))
        hw = jnp.abs(jax.random.normal(ks[1], (n, d))) + 0.1
        C = jax.random.normal(ks[2], (k, d))
        got = ops.assign(x, hw, C, use_pallas=True, interpret=True, tile_n=256)
        want = ref.vq_assign_ref(x, hw, C)
        # ties are legal but measure-zero for continuous data
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_matches_core_codebook_assign(self):
        """Kernel == the core EM E-step used by Algorithm 1."""
        from repro.core import codebook as cb
        ks = jax.random.split(jax.random.PRNGKey(7), 3)
        x = jax.random.normal(ks[0], (512, 2))
        hw = jnp.abs(jax.random.normal(ks[1], (512, 2))) + 0.1
        C = jax.random.normal(ks[2], (16, 2))
        got = ops.assign(x, hw, C, use_pallas=True, interpret=True)
        want = cb.assign(x, hw, C)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestFlashAttentionKernel:
    @pytest.mark.parametrize(
        "B,S,H,KV,hd,bq,bk,causal",
        [
            (2, 256, 8, 4, 64, 64, 64, True),
            (2, 256, 8, 4, 64, 64, 64, False),
            (1, 128, 4, 4, 32, 32, 64, True),   # MHA, uneven blocks
            (2, 128, 8, 2, 64, 128, 32, True),  # G=4 GQA
        ],
    )
    def test_matches_plain_attention(self, B, S, H, KV, hd, bq, bk, causal):
        from repro.kernels.flash_attention import flash_attention_tpu
        from repro.models import attention
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (B, S, H, hd))
        k = jax.random.normal(ks[1], (B, S, KV, hd))
        v = jax.random.normal(ks[2], (B, S, KV, hd))
        o1 = flash_attention_tpu(q, k, v, causal=causal, block_q=bq,
                                 block_k=bk, interpret=True)
        if causal:
            msk = (jnp.arange(S)[None, :] <= jnp.arange(S)[:, None])[
                None, None, None]
        else:
            msk = jnp.ones((1, 1, 1, S, S), bool)
        o2 = attention._plain_attention(q, k, v, msk)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-4, atol=2e-4)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        from repro.kernels.flash_attention import flash_attention_tpu
        from repro.models import attention
        ks = jax.random.split(jax.random.PRNGKey(1), 3)
        q = jax.random.normal(ks[0], (1, 128, 4, 32)).astype(dtype)
        k = jax.random.normal(ks[1], (1, 128, 2, 32)).astype(dtype)
        v = jax.random.normal(ks[2], (1, 128, 2, 32)).astype(dtype)
        o = flash_attention_tpu(q, k, v, causal=True, block_q=64,
                                block_k=64, interpret=True)
        assert o.dtype == dtype
        msk = (jnp.arange(128)[None, :] <= jnp.arange(128)[:, None])[
            None, None, None]
        o2 = attention._plain_attention(q, k, v, msk)
        tol = 2e-4 if dtype == jnp.float32 else 4e-2
        np.testing.assert_allclose(
            np.asarray(o, np.float32), np.asarray(o2, np.float32),
            rtol=tol, atol=tol)

    @pytest.mark.parametrize("Sq,Sk,off", [(64, 64, 32), (64, 192, 128),
                                           (1, 64, 63)])
    def test_q_offset_matches_xla_scan(self, Sq, Sk, off):
        """Causal masking at a nonzero static row offset: the kernel must
        match the XLA two-level scan's q_offset semantics (q row i is
        absolute position off + i; k spans [0, Sk))."""
        from repro.kernels.flash_attention import flash_attention_tpu
        from repro.models import attention
        ks = jax.random.split(jax.random.PRNGKey(2), 3)
        q = jax.random.normal(ks[0], (2, Sq, 4, 32))
        k = jax.random.normal(ks[1], (2, Sk, 2, 32))
        v = jax.random.normal(ks[2], (2, Sk, 2, 32))
        o1 = flash_attention_tpu(q, k, v, causal=True, q_offset=off,
                                 block_q=min(64, Sq), block_k=32,
                                 interpret=True)
        msk = (jnp.arange(Sk)[None, :]
               <= off + jnp.arange(Sq)[:, None])[None, None, None]
        o2 = attention._plain_attention(q, k, v, msk)
        np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                                   rtol=2e-4, atol=2e-4)


class TestFlashDispatch:
    """Regression: a nonzero q_offset with an empty cache prefix
    (Sk == Sq, absolute-position masking) used to silently skip the
    Pallas path. The "flash" dispatch counters (obs/dispatch) pin which
    impl dispatched; the fixture zeroes them per test."""

    def test_q_offset_no_longer_skips_pallas(self, dispatch_counters):
        from repro.models import attention
        ks = jax.random.split(jax.random.PRNGKey(3), 3)
        q = jax.random.normal(ks[0], (1, 64, 4, 32))
        k = jax.random.normal(ks[1], (1, 64, 4, 32))
        v = jax.random.normal(ks[2], (1, 64, 4, 32))
        attention.set_flash_impl("pallas")
        try:
            o_pl = attention.flash_attention(q, k, v, causal=True,
                                             q_offset=16)
            after = dispatch_counters()["flash"]
            assert after["pallas"] == 1, \
                "pallas path was silently skipped"
            assert after["xla"] == 0
            attention.set_flash_impl("xla")
            o_xla = attention.flash_attention(q, k, v, causal=True,
                                              q_offset=16)
            assert dispatch_counters()["flash"]["xla"] == 1
        finally:
            attention.set_flash_impl("xla")
        np.testing.assert_allclose(np.asarray(o_pl), np.asarray(o_xla),
                                   rtol=2e-4, atol=2e-4)

    def test_traced_offset_falls_back_to_xla(self, dispatch_counters):
        """A *traced* q_offset can't parameterize the static kernel mask —
        dispatch must take the XLA scan, not crash."""
        from repro.models import attention
        ks = jax.random.split(jax.random.PRNGKey(4), 3)
        q = jax.random.normal(ks[0], (1, 64, 4, 32))
        k = jax.random.normal(ks[1], (1, 64, 4, 32))
        v = jax.random.normal(ks[2], (1, 64, 4, 32))
        attention.set_flash_impl("pallas")
        try:
            out = jax.jit(
                lambda off: attention.flash_attention(
                    q, k, v, causal=True, q_offset=off))(jnp.int32(16))
            after = dispatch_counters()["flash"]
            assert after["xla"] == 1
            assert after["pallas"] == 0
        finally:
            attention.set_flash_impl("xla")
        ref_o = attention.flash_attention(q, k, v, causal=True, q_offset=16)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref_o),
                                   rtol=2e-4, atol=2e-4)
