"""Unit suite for the obs/ telemetry subsystem: histogram bucketing,
registry snapshot schema, span nesting, JSONL event schema round-trips,
request-record lifecycle (incl. the recompute-style preempt reset), and
the dispatch-counter registry. Everything here is host-only — no jax in
the loop — so the suite doubles as the schema contract for the CI
metrics smoke step.
"""
import json
import time

import pytest

from repro.obs import (
    COUNT_BUCKETS,
    LATENCY_BUCKETS_S,
    EventLog,
    Histogram,
    MetricsRegistry,
    RequestRecord,
    SpanTimer,
    Telemetry,
    read_jsonl,
    validate_event,
    validate_metrics_snapshot,
)
from repro.obs.dispatch import (
    register_dispatch,
    reset_dispatch_counters,
    snapshot_dispatch_counters,
)


class TestHistogram:
    def test_bucketing_edges_and_overflow(self):
        h = Histogram(buckets=(1.0, 2.0, 4.0))
        for v in (0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 100.0):
            h.observe(v)
        # edges are upper-EXCLUSIVE (bisect_right): bucket 0 holds
        # v < 1.0, a value equal to an edge rolls into the next bucket,
        # and v >= the last edge lands in the overflow slot
        assert h.counts == [1, 2, 2, 2]
        assert h.count == 7
        assert h.min == 0.5 and h.max == 100.0
        assert h.sum == pytest.approx(112.0)
        assert h.mean == pytest.approx(112.0 / 7)

    def test_quantiles_bucket_resolution(self):
        h = Histogram(buckets=(1.0, 2.0, 4.0))
        for _ in range(99):
            h.observe(0.5)
        h.observe(50.0)
        assert h.quantile(0.5) == 1.0     # upper edge of the p50 bucket
        assert h.quantile(1.0) == 50.0    # overflow reports the exact max
        # degenerate rank 0 still reports the first nonempty bucket's edge
        assert h.quantile(0.0) == 1.0

    def test_empty_histogram(self):
        h = Histogram()
        assert h.mean == 0.0 and h.quantile(0.5) == 0.0
        j = h.to_json()
        assert j["count"] == 0 and sum(j["counts"]) == 0

    def test_default_buckets_sorted(self):
        assert list(LATENCY_BUCKETS_S) == sorted(LATENCY_BUCKETS_S)
        assert list(COUNT_BUCKETS) == sorted(COUNT_BUCKETS)


class TestRegistry:
    def test_get_or_create_identity(self):
        reg = MetricsRegistry()
        assert reg.counter("a") is reg.counter("a")
        assert reg.gauge("g") is reg.gauge("g")
        assert reg.histogram("h") is reg.histogram("h")

    def test_snapshot_roundtrip_and_validate(self):
        reg = MetricsRegistry()
        reg.counter("tokens").inc(5)
        reg.gauge("depth").set(3)
        reg.histogram("lat").observe(0.01)
        snap = json.loads(json.dumps(reg.snapshot()))  # JSON round-trip
        validate_metrics_snapshot(snap)
        assert snap["tokens"] == 5 and snap["depth"] == 3
        assert snap["lat"]["count"] == 1

    def test_validate_rejects_malformed(self):
        with pytest.raises(ValueError):
            validate_metrics_snapshot(
                {"h": {"buckets": [1.0], "counts": [1], "sum": 1.0,
                       "count": 1}})  # counts missing the overflow slot
        with pytest.raises(ValueError):
            validate_metrics_snapshot(
                {"h": {"buckets": [1.0], "counts": [1, 1], "sum": 1.0,
                       "count": 3}})  # counts don't sum to count

    def test_disabled_registry_is_null(self):
        reg = MetricsRegistry(enabled=False)
        c = reg.counter("x")
        c.inc(100)
        assert c.value == 0
        reg.histogram("h").observe(1.0)
        assert reg.snapshot() == {}


class TestSpans:
    def test_nesting_paths_and_timing(self):
        reg = MetricsRegistry()
        spans = SpanTimer(reg)
        with spans.span("tick"):
            assert spans.current_path == "tick"
            with spans.span("upload"):
                assert spans.current_path == "tick/upload"
                time.sleep(0.002)
            with spans.span("device"):
                pass
        assert spans.current_path == ""
        snap = reg.snapshot()
        assert set(snap) == {"span.tick", "span.tick/upload",
                             "span.tick/device"}
        assert snap["span.tick/upload"]["sum"] >= 0.002
        # parent covers its children
        assert snap["span.tick"]["sum"] >= snap["span.tick/upload"]["sum"]

    def test_stack_unwinds_on_exception(self):
        spans = SpanTimer(MetricsRegistry())
        with pytest.raises(RuntimeError):
            with spans.span("outer"):
                with spans.span("inner"):
                    raise RuntimeError("boom")
        assert spans.current_path == ""

    def test_single_segment_names_enforced(self):
        spans = SpanTimer(MetricsRegistry())
        with pytest.raises(AssertionError):
            with spans.span("a/b"):
                pass

    def test_timed_helper_returns_value(self):
        spans = SpanTimer(MetricsRegistry())
        assert spans.timed("f", lambda x: x + 1, 41) == 42


class TestEvents:
    def test_jsonl_schema_roundtrip(self, tmp_path):
        path = str(tmp_path / "events.jsonl")
        log = EventLog(path)
        log.emit("enqueue", rid=1, prompt_len=5, max_new_tokens=4)
        log.emit("admit", rid=1, slot=0)
        log.emit("first_token", rid=1, ttft_s=0.01)
        log.emit("finish", rid=1, tokens=4, reason="length", ttft_s=0.01,
                 itl_mean_s=0.002, preemptions=0)
        log.close()
        evs = read_jsonl(path)  # validates every line
        assert [e["event"] for e in evs] == ["enqueue", "admit",
                                             "first_token", "finish"]
        # timestamps are monotonic within one log
        ts = [e["ts"] for e in evs]
        assert ts == sorted(ts)

    def test_validate_event_rejects_bad(self):
        with pytest.raises(ValueError):
            validate_event({"event": "nope", "ts": 0.0})
        with pytest.raises(ValueError):
            validate_event({"event": "admit", "ts": 0.0})  # missing fields
        with pytest.raises(ValueError):
            validate_event({"event": "finish", "ts": 0.0, "rid": 1,
                            "tokens": 1, "reason": "whatever",
                            "ttft_s": 0, "itl_mean_s": 0,
                            "preemptions": 0})  # unknown finish reason
        with pytest.raises(ValueError):
            validate_event({"event": "admit", "rid": 1, "slot": 0})  # no ts

    def test_ring_buffer_bounds_memory(self):
        log = EventLog(keep=10)
        for i in range(50):
            log.emit("token", rid=i)
        assert len(log.events) == 10
        assert log.events[-1]["rid"] == 49

    def test_disabled_log_is_free(self, tmp_path):
        path = str(tmp_path / "nope.jsonl")
        log = EventLog(path, enabled=False)
        log.emit("token", rid=1)
        assert log.events == []
        import os
        assert not os.path.exists(path)  # disabled never opens the file


class TestRequestRecord:
    def test_lifecycle_and_preempt_reset(self):
        r = RequestRecord(rid=1, prompt_len=5, max_new_tokens=8)
        r.enqueue_ts = 0.0
        r.first_token_ts = 1.0
        r.last_token_ts = 3.0
        r.tokens = 5
        assert r.ttft_s == 1.0
        assert r.itl_mean_s == pytest.approx(0.5)
        r.on_preempt()  # recompute-style: tokens discarded and replayed
        assert r.preemptions == 1
        assert r.tokens == 0 and r.first_token_ts is None
        assert r.ttft_s is None and r.itl_mean_s is None
        j = r.to_json()
        assert j["rid"] == 1 and j["preemptions"] == 1

    def test_itl_undefined_below_two_tokens(self):
        r = RequestRecord(rid=1)
        r.first_token_ts = r.last_token_ts = 1.0
        r.tokens = 1
        assert r.itl_mean_s is None


class TestTelemetryLifecycle:
    def test_token_accounting_through_preempt(self):
        tel = Telemetry()
        tel.on_enqueue(1, 5, 8)
        tel.on_admit(1, 0)
        for _ in range(3):
            tel.on_token(1)
        assert tel.request_token_total() == 3
        tel.on_preempt(1)
        # recompute-style: the counter and the record reset together
        assert tel.request_token_total() == 0
        assert tel.registry.counter("serve.tokens").value == 0
        for _ in range(8):
            tel.on_token(1)
        tel.on_finish(1, "length")
        assert tel.request_token_total() == 8
        recs = tel.drain_finished()
        assert len(recs) == 1 and recs[0].tokens == 8
        assert recs[0].preemptions == 1
        assert tel.drain_finished() == []  # drained

    def test_direct_admit_without_enqueue(self):
        # bench/fuzz drivers used to call scheduler.submit directly;
        # on_admit must synthesize the record
        tel = Telemetry()
        tel.on_admit(7, 0)
        tel.on_token(7)
        tel.on_finish(7, "eos")
        rec = tel.drain_finished()[0]
        assert rec.rid == 7 and rec.ttft_s is not None

    def test_disabled_telemetry_noops(self):
        tel = Telemetry(enabled=False)
        tel.on_enqueue(1, 5, 8)
        tel.on_admit(1, 0)
        tel.on_token(1)
        tel.on_finish(1, "length")
        assert tel.drain_finished() == []
        assert tel.metrics_snapshot()["metrics"] == {}

    def test_snapshot_has_dispatch_section(self):
        snap = Telemetry().metrics_snapshot()
        assert set(snap) == {"metrics", "dispatch"}
        for source, counts in snap["dispatch"].items():
            assert all(isinstance(v, int) for v in counts.values()), source


class TestDispatchRegistry:
    def test_register_idempotent_and_live(self):
        reset_dispatch_counters()
        c1 = register_dispatch("t_obs", ("a", "b"))
        c2 = register_dispatch("t_obs", ("a", "b"))
        assert c1 is c2  # owners keep bumping the same dict
        c1["a"] += 3
        assert snapshot_dispatch_counters()["t_obs"]["a"] == 3

    def test_snapshot_is_a_copy(self):
        register_dispatch("t_obs2", ("x",))["x"] += 1
        snap = snapshot_dispatch_counters()
        snap["t_obs2"]["x"] += 100
        assert snapshot_dispatch_counters()["t_obs2"]["x"] == 1

    def test_reset_zeros_in_place(self):
        counts = register_dispatch("t_obs3", ("x", "y"))
        counts["x"] += 5
        reset_dispatch_counters()
        assert counts == {"x": 0, "y": 0}  # same dict object, zeroed
        counts["y"] += 1  # owners' references stay live after reset
        assert snapshot_dispatch_counters()["t_obs3"]["y"] == 1
