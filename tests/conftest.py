"""Shared test-session plumbing.

jax 0.4.37's CPU backend segfaults inside ``backend_compile`` once a few
hundred jitted executables accumulate in one process (the unsharded
tier-1 run started crashing at the same test, twice, at ~270 compiled
functions after PR 6 grew the suite past that point; every package-level
subset — including a 164-test kernels+serve+substrate run — passes in
isolation, and the host has >100 GB free, so this is a compiler-state
cliff, not a test bug or OOM). Clearing the compilation caches whenever
the session crosses a test-package boundary keeps the live-executable
count bounded to one package's worth without changing any test; the CI
shards already run packages in separate processes and never hit it.
"""
import jax
import pytest

_last_pkg = [None]


def _package(item) -> str:
    parts = str(item.fspath).split("/")
    if "tests" in parts:
        i = parts.index("tests")
        if i + 2 < len(parts):
            return parts[i + 1]
    return str(item.fspath)


@pytest.fixture(autouse=True)
def _clear_jax_caches_between_packages(request):
    pkg = _package(request.node)
    if _last_pkg[0] is not None and pkg != _last_pkg[0]:
        jax.clear_caches()
    _last_pkg[0] = pkg
    yield


@pytest.fixture
def dispatch_counters():
    """Fresh view over the obs/ dispatch-counter registry (the trace-time
    flash/paged/vq/matmul impl counters). Counters are zeroed before the
    test — so assertions are absolute counts, not before/after deltas —
    and zeroed again afterwards so no test inherits another's tallies.
    Yields ``snapshot_dispatch_counters`` (a deep-copying callable:
    ``counts()["vq"]["pallas"]``)."""
    from repro.obs import reset_dispatch_counters, snapshot_dispatch_counters

    reset_dispatch_counters()
    yield snapshot_dispatch_counters
    reset_dispatch_counters()
